"""Measured wall-clock benchmark tier (ROADMAP item 3, DESIGN.md §9).

Where ``bench_roofline`` is HLO-static (collective counts, byte totals),
this tier times REAL jitted work and commits the numbers to
``BENCH_timing.json`` at the repo root so regressions are visible across
PRs, next to the analytic roofline:

  * train step — per strategy × precision × accum_steps on the LocalComm
    replica simulator (the full strategy/optimizer/exchange pipeline);
  * kernels — every Pallas kernel against its pure-jnp ``kernels/ref.py``
    oracle at matched shapes (plus the fused wire-format variants);
  * exchange — Fabric exchange per compressor, fused vs. jnp dispatch,
    and the compression BREAKEVEN table: the link bandwidth below which
    the measured encode overhead pays for the bytes it saves;
  * optimizer — fused vs. unfused Adam on flat ZeRO-1-style buckets.

Methodology (the §9 rules): every timed callable is jit-compiled, warmed
up (compilation + ``WARMUP`` steady-state calls), then timed over
``ITERS`` calls, each blocking on the FULL output pytree via
``jax.block_until_ready``; we record median/min/max ms.  Train states are
built with ``donate=False`` — a donated buffer cannot be re-fed on the
next timed call.  ``meta.backend`` records where the numbers came from;
off-TPU/GPU the Pallas kernels run in interpret mode (kernels/ops.py), so
absolute kernel numbers are only comparable within a backend.

Smoke mode (``BENCH_TIMING_SMOKE=1`` or ``--smoke``) shrinks shapes and
iteration counts so CI can regenerate and re-validate the file in minutes;
``--validate`` checks the committed file against the schema and exits
non-zero on violations.
"""

from __future__ import annotations

import json
import os
import sys
from functools import partial

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:  # script invocation: benchmarks/ is sys.path[0]
    sys.path.insert(0, ROOT)

from benchmarks.common import emit, time_stats  # noqa: E402
OUT = os.path.join(ROOT, "BENCH_timing.json")

KERNELS = ("flash_attention", "onebit_quant", "topk_sparsify",
           "fused_adam", "mamba_scan")


def _stats_ms(fn, *args, iters, warmup):
    med, lo, hi = time_stats(fn, *args, iters=iters, warmup=warmup)
    return {"median_ms": med / 1e3, "min_ms": lo / 1e3, "max_ms": hi / 1e3}


# ---------------------------------------------------------------------------
# train step: strategy × precision × accum_steps
# ---------------------------------------------------------------------------
def _mlp_setup(rng, w, d, h, batch):
    import jax
    import jax.numpy as jnp

    k1, k2, k3 = jax.random.split(rng, 3)
    params = {"w1": jax.random.normal(k1, (d, h)) * 0.02,
              "w2": jax.random.normal(k2, (h, d)) * 0.02}
    x = jax.random.normal(k3, (w, batch, d))

    def loss_fn(p, xb):
        y = jnp.tanh(xb @ p["w1"]) @ p["w2"]
        return jnp.mean((y - xb) ** 2)

    return params, x, loss_fn


def bench_train_step(smoke: bool, iters: int, warmup: int):
    import jax
    import jax.numpy as jnp
    from repro.core import precision as PR
    from repro.core.comm import LocalComm
    from repro.core.compression import get_compressor
    from repro.core.strategies import get_strategy
    from repro.optim import adam
    from repro.train.loop import init_train_state, make_replica_train_step

    w = 2
    d, h, batch = (64, 64, 8) if smoke else (256, 512, 32)
    comm = LocalComm(w)
    rows = []

    def strategies(policy):
        out = [("sync", get_strategy("sync", policy=policy)),
               ("sync_zero1", get_strategy("sync_zero1", policy=policy)),
               ("local_sgd", get_strategy("local_sgd", policy=policy))]
        if not smoke:
            out += [("sync_onebit",
                     get_strategy("sync", policy=policy,
                                  compressor=get_compressor("onebit"))),
                    ("sync_topk",
                     get_strategy("sync", policy=policy,
                                  compressor=get_compressor(
                                      "topk", ratio=0.01, block=1024)))]
        return out

    for prec in ("f32", "bf16"):
        policy = None if prec == "f32" else PR.get_policy("bf16")
        for accum in (1,) if smoke else (1, 4):
            for sname, strat in strategies(policy):
                if accum > 1 and sname != "sync":
                    continue  # accumulation axis: one strategy suffices
                params, x, loss_fn = _mlp_setup(
                    jax.random.PRNGKey(0), w, d, h, batch)
                params = comm.replicate(params)
                opt = adam(1e-3)
                state = init_train_state(params, opt, strat, comm,
                                         policy=policy)
                step = make_replica_train_step(
                    loss_fn, opt, strat, comm, policy=policy,
                    accum_steps=accum, donate=False)
                xb = x if accum == 1 else jnp.stack([x] * accum)
                st = _stats_ms(step, state, xb, iters=iters, warmup=warmup)
                n_params = sum(p.size for p in jax.tree.leaves(params)) // w
                rows.append({"strategy": sname, "precision": prec,
                             "accum_steps": accum, "workers": w,
                             "n_params": int(n_params),
                             "batch_per_worker": batch, **st})
                emit(f"timing/train_step/{sname}/{prec}/accum{accum}",
                     st["median_ms"] * 1e3, f"workers={w}")
    return rows


# ---------------------------------------------------------------------------
# kernels vs kernels/ref.py
# ---------------------------------------------------------------------------
def bench_kernels(smoke: bool, iters: int, warmup: int):
    import jax
    import jax.numpy as jnp
    from repro.core.compression import pack_signs
    from repro.kernels import ops, ref

    rng = jax.random.PRNGKey(0)
    rows = {}

    def record(name, shape, kfn, rfn, *args):
        ks = _stats_ms(kfn, *args, iters=iters, warmup=warmup)
        rs = _stats_ms(rfn, *args, iters=iters, warmup=warmup)
        rows[name] = {"shape": list(shape),
                      "kernel_ms": ks["median_ms"], "ref_ms": rs["median_ms"],
                      "speedup": rs["median_ms"] / max(ks["median_ms"], 1e-9)}
        emit(f"timing/kernels/{name}", ks["median_ms"] * 1e3,
             f"ref_ms={rs['median_ms']:.3f};speedup={rows[name]['speedup']:.2f}")

    # flash attention
    b, hh, l, dd = (1, 1, 64, 64) if smoke else (1, 2, 256, 64)
    q, k, v = (jax.random.normal(jax.random.fold_in(rng, i), (b, hh, l, dd))
               for i in range(3))
    record("flash_attention", (b, hh, l, dd),
           ops.flash_attention, jax.jit(ref.flash_attention_ref), q, k, v)

    # onebit quant (+ the packed wire-format variant vs ref + pack_signs)
    nb, block = (16, 64) if smoke else (256, 256)
    g = jax.random.normal(rng, (nb, block))
    r = jax.random.normal(jax.random.fold_in(rng, 1), (nb, block)) * 0.1
    record("onebit_quant", (nb, block),
           ops.onebit_quant, jax.jit(ref.onebit_quant_ref), g, r)

    @jax.jit
    def onebit_packed_ref(g, r):
        s, sc, nr = ref.onebit_quant_ref(g, r)
        return pack_signs(s.reshape(-1)), sc.astype(jnp.bfloat16), nr

    record("onebit_quant_packed", (nb, block),
           ops.onebit_quant_packed, onebit_packed_ref, g, r)

    # topk (+ the fused encode+error-feedback variant)
    kk = 4 if smoke else 8
    x = jax.random.normal(rng, (nb, block))
    record("topk_sparsify", (nb, block),
           partial(ops.topk_sparsify, k=kk),
           jax.jit(partial(ref.topk_sparsify_ref, k=kk)), x)

    @jax.jit
    def topk_ef_ref(g, r):
        vals, idx, dense = ref.topk_sparsify_ref(g + r, kk)
        return vals, idx, (g + r) - dense

    record("topk_encode_ef", (nb, block),
           partial(ops.topk_encode_ef, k=kk), topk_ef_ref, g, r)

    # fused adam
    n = 4096 if smoke else 1 << 18
    p, gg, m = (jax.random.normal(jax.random.fold_in(rng, i), (n,))
                for i in range(3))
    vv = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (n,)))
    record("fused_adam", (n,),
           lambda p, g, m, v: ops.fused_adam(p, g, m, v, 1e-3, 1),
           jax.jit(lambda p, g, m, v: ref.fused_adam_ref(p, g, m, v, 1e-3)),
           p, gg, m, vv)

    # mamba scan
    b, l, dch, ns = (1, 16, 32, 8) if smoke else (2, 64, 128, 16)
    u = jax.random.normal(rng, (b, l, dch)) * 0.5
    delta = jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 1),
                                              (b, l, dch)))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 2), (dch, ns)))
    bb = jax.random.normal(jax.random.fold_in(rng, 3), (b, l, ns)) * 0.5
    cc = jax.random.normal(jax.random.fold_in(rng, 4), (b, l, ns)) * 0.5
    ds = jax.random.normal(jax.random.fold_in(rng, 5), (dch,))
    record("mamba_scan", (b, l, dch, ns),
           partial(ops.mamba_scan, d_block=32 if smoke else 64),
           jax.jit(ref.mamba_scan_ref), u, delta, a, bb, cc, ds)
    return rows


# ---------------------------------------------------------------------------
# Fabric exchange + compression breakeven
# ---------------------------------------------------------------------------
def bench_exchange(smoke: bool, iters: int, warmup: int):
    import jax
    import jax.numpy as jnp
    from repro.core.comm import LocalComm
    from repro.core.compression import get_compressor
    from repro.core.fabric import Fabric

    w = 4
    comm = LocalComm(w)
    sizes = [1 << 14] if smoke else [1 << 16, 1 << 20]
    comps = [("none", None),
             ("onebit", get_compressor("onebit")),
             ("int8", get_compressor("int8")),
             ("topk", get_compressor("topk", ratio=0.01, block=1024))]
    rng = jax.random.PRNGKey(0)
    rows, breakeven = [], []
    for n in sizes:
        tree = {"g": jax.random.normal(rng, (w, n))}
        res = {"g": jnp.zeros((w, n), jnp.float32)}
        t_none = bytes_none = None
        for cname, comp in comps:
            fused_modes = [True] if comp is None or comp.fused_encode is None \
                else [True, False]
            for fused in fused_modes:
                fab = Fabric(comm, fused=fused)
                step = jax.jit(lambda t, r, fab=fab, comp=comp:
                               fab.exchange(t, r, comp))
                st = _stats_ms(step, tree, res, iters=iters, warmup=warmup)
                nbytes = fab.wire_bytes(tree, comp)
                rows.append({"compressor": cname, "n": n, "fused": fused,
                             "wire_bytes": nbytes, **st})
                emit(f"timing/exchange/{cname}/n{n}/"
                     + ("fused" if fused else "jnp"),
                     st["median_ms"] * 1e3, f"wire_bytes={nbytes:.0f}")
                if cname == "none":
                    t_none, bytes_none = st["median_ms"], nbytes
                elif fused:
                    over = st["median_ms"] - t_none
                    saved = bytes_none - nbytes
                    bw = (saved / (over / 1e3)) / 1e9 if over > 0 \
                        else float("inf")
                    breakeven.append({
                        "compressor": cname, "n": n,
                        "bytes_none": bytes_none, "bytes_comp": nbytes,
                        "t_none_ms": t_none, "t_comp_ms": st["median_ms"],
                        "encode_overhead_ms": over,
                        "breakeven_gbps": bw})
                    emit(f"timing/breakeven/{cname}/n{n}", over * 1e3,
                         f"breakeven_gbps={bw:.3f}")
    return rows, breakeven


# ---------------------------------------------------------------------------
# fused vs unfused Adam on flat buckets (the ZeRO-1 update boundary)
# ---------------------------------------------------------------------------
def bench_optimizer(smoke: bool, iters: int, warmup: int):
    import jax
    from repro.optim import adam

    n = (1 << 12) if smoke else (1 << 18)
    rng = jax.random.PRNGKey(0)
    buckets = {"b0": jax.random.normal(rng, (n,)),
               "b1": jax.random.normal(jax.random.fold_in(rng, 1), (n,))}
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(rng, 2), p.shape),
        buckets)
    rows = []
    for impl, fused in (("adam", False), ("adam_fused", True)):
        opt = adam(1e-3, fused=fused)
        st0 = opt.init(buckets)
        step = jax.jit(lambda g, s, p: opt.update(g, s, p, 0))
        st = _stats_ms(step, grads, st0, buckets, iters=iters, warmup=warmup)
        rows.append({"impl": impl, "n_per_bucket": n, "buckets": 2, **st})
        emit(f"timing/optimizer/{impl}", st["median_ms"] * 1e3,
             f"n_per_bucket={n}")
    return rows


# ---------------------------------------------------------------------------
# driver + schema validation
# ---------------------------------------------------------------------------
def run(smoke=None):
    import jax

    if smoke is None:
        smoke = os.environ.get("BENCH_TIMING_SMOKE", "") not in ("", "0")
    iters, warmup = (3, 1) if smoke else (20, 3)
    report = {
        "meta": {
            "schema": 1,
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0].device_kind),
            "jax": jax.__version__,
            "smoke": bool(smoke),
            "iters": iters,
            "warmup": warmup,
            "note": ("off-TPU/GPU the Pallas kernels run in interpret "
                     "mode; compare numbers within a backend only"),
        },
        "train_step": bench_train_step(smoke, iters, warmup),
        "kernels": bench_kernels(smoke, iters, warmup),
        "optimizer": bench_optimizer(smoke, iters, warmup),
    }
    report["exchange"], report["breakeven"] = \
        bench_exchange(smoke, iters, warmup)
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)
    emit("timing/report", 0.0, f"out={os.path.basename(OUT)};smoke={smoke}")
    return report


def validate(path=OUT):
    """Schema check for BENCH_timing.json; raises ValueError on violation
    (CI runs this against both the committed and the regenerated file)."""
    from benchmarks.common import (check, load_report, require_keys,
                                   require_positive, require_sections)
    label = "BENCH_timing.json"
    report = load_report(path, "python -m benchmarks.run timing")
    require_sections(report, ("meta", "train_step", "kernels", "exchange",
                              "breakeven", "optimizer"), label)
    require_keys(report["meta"], ("backend",), "meta")
    by_strategy = {}
    for row in report["train_step"]:
        require_keys(row, ("strategy", "precision", "median_ms"),
                     "train_step row")
        require_positive(row, ("median_ms",), "train_step row")
        by_strategy.setdefault(row["strategy"], set()).add(row["precision"])
    full = [s for s, precs in by_strategy.items()
            if {"f32", "bf16"} <= precs]
    check(len(full) >= 3, "need >= 3 strategies timed at both precisions, "
                          f"got {sorted(full)}")
    for name in KERNELS:
        row = report["kernels"].get(name)
        check(row is not None, f"kernels section missing {name!r}")
        require_positive(row, ("kernel_ms", "ref_ms"), f"kernels[{name!r}]")
    comps = {r["compressor"] for r in report["breakeven"]}
    check({"onebit", "topk"} <= comps,
          f"breakeven table incomplete: {sorted(comps)}")
    fused = {r["compressor"] for r in report["exchange"] if r.get("fused")}
    check({"onebit", "topk"} <= fused,
          "exchange section missing fused onebit/topk rows")
    return report


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if "--validate" in argv:
        report = validate()
        n = len(report["train_step"])
        print(f"BENCH_timing.json OK: {n} train-step rows, "
              f"{len(report['kernels'])} kernels, "
              f"{len(report['breakeven'])} breakeven rows "
              f"(smoke={report['meta']['smoke']})")
        return
    run(smoke=True if "--smoke" in argv else None)


if __name__ == "__main__":
    main()
