"""Closed-loop serving load benchmark (DESIGN.md §10).

Commits ``BENCH_serving.json`` at the repo root so serving performance is
machine-readable per PR, following the ``bench_timing`` methodology
(jit + warmup + block_until_ready medians via ``common.time_stats``; a
``meta.backend`` stamp; smoke mode for CI regeneration).

Three sections:

  * ``paged_vs_dense`` — offline throughput at EQUAL slot count: the
    paged chunked-prefill engine vs the dense token-by-token seed engine
    on the same request batch, per prompt-length mix.  The acceptance
    bar (validated for committed non-smoke files) is paged ≥ 2× dense —
    the win is structural: a prompt of length Lp costs ceil(Lp/chunk)
    prefill steps instead of Lp decode steps.
  * ``load`` — a closed-loop load generator sweeping offered QPS ×
    prompt-length mix against the paged engine: seeded-exponential
    arrivals, per-token stamps from the engine.  Reports throughput,
    TTFT / per-output-token / end-to-end p50+p99 latency, cache
    utilization, and eviction counts.
  * ``kernels`` — ``common.time_stats`` medians: the Pallas paged-
    attention kernel vs its jnp gather oracle, and a paged vs dense
    jitted decode step at matched batch/context.

Off-accelerator the Pallas kernel runs in interpret mode (slow, python
loop), so the ENGINE defaults to the jnp gather path on CPU (see
``PagedDecodeEngine.use_kernel``) and the kernel is timed separately
here; absolute numbers are comparable within a backend only.
"""

from __future__ import annotations

import json
import math
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:  # script invocation: benchmarks/ is sys.path[0]
    sys.path.insert(0, ROOT)

from benchmarks.common import emit, time_stats  # noqa: E402

OUT = os.path.join(ROOT, "BENCH_serving.json")

SLOTS = 4
MAX_SEQ = 64
PAGE_SIZE = 8
CHUNK = 16
MIXES = {"short": (4, 16), "long": (24, 48)}  # prompt-length ranges


def _cfg():
    import dataclasses

    from repro.configs.base import get_config

    return dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=64)


def _requests(seed, n, lo, hi, max_new):
    import numpy as np

    from repro.serve.engine import Request

    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=np.asarray(rng.integers(1, 64, size=int(l)),
                                      np.int32),
                    max_new_tokens=max_new)
            for i, l in enumerate(rng.integers(lo, hi, size=n))]


def _warmup(eng, lo):
    """Compile both phases (prefill chunk + decode) outside the timed
    region — one request that spans a chunk boundary does it."""
    import numpy as np

    from repro.serve.engine import Request

    eng.submit(Request(rid=-1, prompt=np.full((lo,), 1, np.int32),
                       max_new_tokens=2))
    eng.run()
    eng.finished.clear()
    eng.steps = 0


def _drain(eng):
    t0 = time.perf_counter()
    eng.run()
    return time.perf_counter() - t0


def _pct(xs, q):
    import numpy as np

    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


# ---------------------------------------------------------------------------
# offline throughput: paged chunked-prefill vs dense token-by-token
# ---------------------------------------------------------------------------
def bench_paged_vs_dense(params, cfg, smoke):
    from repro.serve.engine import DecodeEngine, PagedDecodeEngine

    n = 4 if smoke else 16
    max_new = 4 if smoke else 8
    rows = []
    for mix, (lo, hi) in MIXES.items():
        dense = DecodeEngine(params, cfg, batch_slots=SLOTS, max_seq=MAX_SEQ)
        paged = PagedDecodeEngine(params, cfg, batch_slots=SLOTS,
                                  max_seq=MAX_SEQ, page_size=PAGE_SIZE,
                                  chunk_size=CHUNK)
        _warmup(dense, lo)
        _warmup(paged, lo)
        for r in _requests(100, n, lo, hi, max_new):
            dense.submit(r)
        for r in _requests(100, n, lo, hi, max_new):
            paged.submit(r)
        t_dense = _drain(dense)
        t_paged = _drain(paged)
        toks_d = sum(len(r.generated) for r in dense.finished)
        toks_p = sum(len(r.generated) for r in paged.finished)
        assert toks_d == toks_p, "engines disagree on token counts"
        row = {
            "mix": mix, "prompt_len": [lo, hi], "n_requests": n,
            "max_new_tokens": max_new, "slots": SLOTS,
            "dense_s": t_dense, "paged_s": t_paged,
            "dense_steps": dense.steps, "paged_steps": paged.steps,
            "dense_tok_s": toks_d / t_dense, "paged_tok_s": toks_p / t_paged,
            "speedup": t_dense / t_paged,
        }
        rows.append(row)
        emit(f"serving/paged_vs_dense/{mix}", t_paged * 1e6,
             f"dense_s={t_dense:.3f};speedup={row['speedup']:.2f}")
    return rows


# ---------------------------------------------------------------------------
# closed-loop load generator: offered QPS × prompt mix
# ---------------------------------------------------------------------------
def _closed_loop(eng, reqs, arrivals):
    """Submit each request at its arrival offset (closed loop: the wall
    clock gates admission, the engine steps as fast as it can)."""
    util = []
    t0 = time.perf_counter()
    i, n = 0, len(reqs)
    while i < n or eng.queue or any(p != "idle" for p in eng.phase):
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            eng.submit(reqs[i])
            i += 1
        if eng.queue or any(p != "idle" for p in eng.phase):
            eng.step()
            util.append(eng.utilization())
        elif i < n:
            time.sleep(min(arrivals[i] - now, 0.02))
    return time.perf_counter() - t0, util


def bench_load(params, cfg, smoke):
    import numpy as np

    from repro.serve.engine import PagedDecodeEngine

    qps_sweep = (8.0,) if smoke else (2.0, 8.0, 32.0)
    n = 6 if smoke else 24
    max_new = 4 if smoke else 8
    rows = []
    for mix, (lo, hi) in MIXES.items():
        for qps in qps_sweep:
            eng = PagedDecodeEngine(params, cfg, batch_slots=SLOTS,
                                    max_seq=MAX_SEQ, page_size=PAGE_SIZE,
                                    chunk_size=CHUNK)
            _warmup(eng, lo)
            reqs = _requests(int(qps * 100) + sum(map(ord, mix)), n, lo, hi,
                             max_new)
            arrivals = np.random.default_rng(17).exponential(
                1.0 / qps, size=n).cumsum()
            wall, util = _closed_loop(eng, reqs, list(arrivals))
            done = [r for r in eng.finished if r.token_times]
            ttft = [r.token_times[0] - r.t_submit for r in done]
            tpot = [dt for r in done
                    for dt in np.diff(np.asarray(r.token_times))]
            e2e = [r.token_times[-1] - r.t_submit for r in done]
            toks = sum(len(r.generated) for r in eng.finished)
            row = {
                "mix": mix, "prompt_len": [lo, hi],
                "offered_qps": qps, "n_requests": n,
                "completed": len(done), "max_new_tokens": max_new,
                "wall_s": wall, "throughput_tok_s": toks / wall,
                "ttft_p50_ms": _pct(ttft, 50) * 1e3,
                "ttft_p99_ms": _pct(ttft, 99) * 1e3,
                "tpot_p50_ms": _pct(tpot, 50) * 1e3,
                "tpot_p99_ms": _pct(tpot, 99) * 1e3,
                "e2e_p50_ms": _pct(e2e, 50) * 1e3,
                "e2e_p99_ms": _pct(e2e, 99) * 1e3,
                "cache_util_mean": float(np.mean(util)) if util else 0.0,
                "cache_util_max": float(np.max(util)) if util else 0.0,
                "evictions": sum(r.evictions for r in eng.finished),
            }
            rows.append(row)
            emit(f"serving/load/{mix}/qps{qps:g}",
                 row["tpot_p50_ms"] * 1e3,
                 f"tok_s={row['throughput_tok_s']:.1f};"
                 f"e2e_p99_ms={row['e2e_p99_ms']:.1f}")
    return rows


# ---------------------------------------------------------------------------
# kernel medians (common.time_stats protocol)
# ---------------------------------------------------------------------------
def bench_kernels(params, cfg, smoke, iters, warmup):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ref
    from repro.kernels.paged_attention import paged_attention
    from repro.models import transformer as T

    def stats_ms(fn, *args):
        med, lo, hi = time_stats(fn, *args, iters=iters, warmup=warmup)
        return med / 1e3

    rows = {}
    # paged_attention kernel vs jnp gather oracle
    b, kv, g, dh = (2, 1, 2, 32) if smoke else (4, 2, 4, 64)
    mb = 2 if smoke else 4
    n_pages = 1 + b * mb
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, kv, g, dh))
    kp = jax.random.normal(ks[1], (n_pages, PAGE_SIZE, kv, dh))
    vp = jax.random.normal(ks[2], (n_pages, PAGE_SIZE, kv, dh))
    bt = jnp.asarray(np.arange(1, n_pages).reshape(b, mb).astype(np.int32))
    ctx = jnp.full((b,), mb * PAGE_SIZE, jnp.int32)
    k_ms = stats_ms(paged_attention, q, kp, vp, bt, ctx)
    r_ms = stats_ms(jax.jit(ref.paged_attention_ref), q, kp, vp, bt, ctx)
    rows["paged_attention"] = {
        "shape": [b, kv, g, dh], "pages": [n_pages, PAGE_SIZE],
        "kernel_ms": k_ms, "ref_ms": r_ms,
        "speedup": r_ms / max(k_ms, 1e-9)}
    emit("serving/kernels/paged_attention", k_ms * 1e3,
         f"ref_ms={r_ms:.3f}")

    # paged vs dense jitted decode step at matched batch/context
    bsz = SLOTS
    dense_cache = T.init_cache(cfg, bsz, MAX_SEQ)
    pages_per_seq = math.ceil(MAX_SEQ / PAGE_SIZE)
    paged_cache = T.init_paged_cache(cfg, 1 + bsz * pages_per_seq, PAGE_SIZE)
    btab = jnp.asarray(
        (1 + np.arange(bsz * pages_per_seq))
        .reshape(bsz, pages_per_seq).astype(np.int32))
    tok = jnp.ones((bsz,), jnp.int32)
    pos = jnp.full((bsz,), MAX_SEQ // 2, jnp.int32)
    d_step = jax.jit(lambda p, t, ps, c: T.decode_step(
        p, cfg, token=t, pos=ps, cache=c))
    p_step = jax.jit(lambda p, t, ps, c, b_: T.decode_step_paged(
        p, cfg, t, ps, c, b_, use_kernel=False))
    d_ms = stats_ms(d_step, params, tok, pos, dense_cache)
    p_ms = stats_ms(p_step, params, tok, pos, paged_cache, btab)
    rows["decode_step"] = {
        "batch": bsz, "max_seq": MAX_SEQ,
        "dense_ms": d_ms, "paged_ms": p_ms,
        "paged_over_dense": p_ms / max(d_ms, 1e-9)}
    emit("serving/kernels/decode_step", p_ms * 1e3,
         f"dense_ms={d_ms:.3f}")
    return rows


# ---------------------------------------------------------------------------
# driver + schema validation
# ---------------------------------------------------------------------------
def run(smoke=None):
    import jax

    from repro.models import transformer as T

    if smoke is None:
        smoke = os.environ.get("BENCH_SERVING_SMOKE", "") not in ("", "0")
    iters, warmup = (3, 1) if smoke else (20, 3)
    cfg = _cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    report = {
        "meta": {
            "schema": 1,
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0].device_kind),
            "jax": jax.__version__,
            "smoke": bool(smoke),
            "engine": {"arch": "qwen2-1.5b (reduced tiny)", "slots": SLOTS,
                       "max_seq": MAX_SEQ, "page_size": PAGE_SIZE,
                       "chunk_size": CHUNK},
            "note": ("engine decode uses the jnp gather path off-TPU/GPU "
                     "(interpret-mode Pallas is a python loop); the kernel "
                     "is timed separately in `kernels`.  Compare numbers "
                     "within a backend only."),
        },
        "paged_vs_dense": bench_paged_vs_dense(params, cfg, smoke),
        "load": bench_load(params, cfg, smoke),
        "kernels": bench_kernels(params, cfg, smoke, iters, warmup),
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)
    emit("serving/report", 0.0, f"out={os.path.basename(OUT)};smoke={smoke}")
    return report


def validate(path=OUT):
    """Schema + acceptance check for BENCH_serving.json; raises ValueError
    on violation.  Non-smoke (committed) files must additionally show the
    paged engine ≥ 2× the dense engine on at least one prompt mix."""
    from benchmarks.common import (check, load_report, require_positive,
                                   require_sections)
    label = "BENCH_serving.json"
    report = load_report(path, "python -m benchmarks.run serving")
    require_sections(report, ("meta", "paged_vs_dense", "load", "kernels"),
                     label)
    check("backend" in report["meta"], "meta.backend missing")
    pvd = report["paged_vs_dense"]
    check({r["mix"] for r in pvd} == set(MIXES),
          f"paged_vs_dense must cover mixes {sorted(MIXES)}")
    for r in pvd:
        require_positive(r, ("dense_s", "paged_s", "speedup", "dense_tok_s",
                             "paged_tok_s"), "paged_vs_dense row")
    if not report["meta"]["smoke"]:
        best = max(r["speedup"] for r in pvd)
        check(best >= 2.0,
              f"acceptance: paged must be >= 2x dense, best {best:.2f}x")
    check(report["load"], "load section empty")
    mixes_seen, qps_seen = set(), set()
    for r in report["load"]:
        mixes_seen.add(r["mix"])
        qps_seen.add(r["offered_qps"])
        require_positive(r, ("throughput_tok_s", "ttft_p50_ms",
                             "tpot_p50_ms", "e2e_p50_ms"), "load row")
        for p50, p99 in (("ttft_p50_ms", "ttft_p99_ms"),
                         ("tpot_p50_ms", "tpot_p99_ms"),
                         ("e2e_p50_ms", "e2e_p99_ms")):
            check(r[p99] + 1e-9 >= r[p50],
                  f"percentile order violated in {r}")
        check(0.0 <= r["cache_util_max"] <= 1.0,
              f"cache utilization out of range: {r}")
    check(mixes_seen == set(MIXES),
          f"load must cover mixes {sorted(MIXES)}")
    check(report["meta"]["smoke"] or len(qps_seen) >= 3,
          "non-smoke load sweep needs >= 3 offered QPS points")
    kr = report["kernels"]
    require_positive(kr.get("paged_attention", {}), ("kernel_ms", "ref_ms"),
                     "kernels.paged_attention")
    require_positive(kr.get("decode_step", {}), ("dense_ms", "paged_ms"),
                     "kernels.decode_step")
    return report


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if "--validate" in argv:
        report = validate()
        best = max(r["speedup"] for r in report["paged_vs_dense"])
        print(f"BENCH_serving.json OK: {len(report['load'])} load rows, "
              f"best paged-vs-dense speedup {best:.2f}x "
              f"(smoke={report['meta']['smoke']})")
        return
    run(smoke=True if "--smoke" in argv else None)


if __name__ == "__main__":
    main()
