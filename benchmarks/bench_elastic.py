"""Elastic fleet benchmark tier (ROADMAP item 4, DESIGN.md §13).

Three measured claims behind the elastic layer, committed to
``BENCH_elastic.json`` at the repo root:

  * **resize** — the in-memory W → W′ ZeRO re-partition
    (``launch/elastic.py::resize_state``) vs the checkpoint
    save → ``restore(repartition=True)`` baseline, per ZeRO stage ×
    optimizer × direction (4→2, 2→4).  Every row also re-proves the
    bitwise contract (live result == checkpoint round-trip) and records
    the roofline accounting: ``resize_moved_bytes`` (only owner-changed
    spans move) vs ``checkpoint_roundtrip_bytes`` (every element is
    written AND read).
  * **recovery** — a W=4 fleet with a seeded mid-run kill: training must
    continue on the survivors within the SAME boundary (state commits
    only on success), and we record how many boundaries the surviving
    fleet needs to reconverge to the pre-kill loss.
  * **chaos_loss** — a full chaos schedule (slowdown → straggler
    demotion → flake → kill → rejoin → restore → re-promotion) vs a
    clean run of the same length: final-loss delta bounds the cost of
    surviving the chaos.

Smoke mode (``BENCH_ELASTIC_SMOKE=1`` or ``--smoke``) shrinks the
problem and the horizons so CI can regenerate and re-validate the file
in minutes; ``--validate`` checks the committed file against the schema
(including the bitwise flags) and exits non-zero on violations.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:  # script invocation: benchmarks/ is sys.path[0]
    sys.path.insert(0, ROOT)

from benchmarks.common import emit, time_stats  # noqa: E402
OUT = os.path.join(ROOT, "BENCH_elastic.json")

STAGES = (1, 2, 3)
OPTS = ("sgd", "adam")
DIRECTIONS = ((4, 2), (2, 4))


def _problem(smoke: bool):
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(0)
    d, h = (8, 12) if smoke else (32, 48)
    params = {"w1": jnp.asarray(rng.standard_normal((d, h)), jnp.float32) * 0.2,
              "b1": jnp.zeros((h,), jnp.float32),
              "w2": jnp.asarray(rng.standard_normal((h, 1)), jnp.float32) * 0.2}
    X = rng.standard_normal((8, 6, d)).astype(np.float32)
    tw = rng.standard_normal((d, 1)).astype(np.float32)
    Y = np.tanh(X @ tw)[..., 0].astype(np.float32)

    def loss_fn(p, batch):
        x, y = batch
        pred = (jnp.tanh(x @ p["w1"] + p["b1"]) @ p["w2"])[..., 0]
        return jnp.mean((pred - y) ** 2)

    def batch_fn(view, t):
        idx = np.array([w % len(X) for w in view.members])
        return (jnp.asarray(X[idx]), jnp.asarray(Y[idx]))

    return params, loss_fn, batch_fn


def _make_opt(name):
    from repro.optim import adam, sgd
    return sgd(0.05) if name == "sgd" else adam(1e-2)


# ---------------------------------------------------------------------------
# resize: in-memory vs checkpoint round-trip
# ---------------------------------------------------------------------------
def bench_resize(smoke: bool, iters: int, warmup: int):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core.comm import LocalComm
    from repro.core.fabric import Fabric
    from repro.core.strategies import get_strategy
    from repro.launch.elastic import FleetView, resize_state
    from repro.roofline.analysis import (checkpoint_roundtrip_bytes,
                                         resize_moved_bytes)
    from repro.train.loop import init_train_state, make_replica_train_step

    bb = 4 * 64
    params0, loss_fn, batch_fn = _problem(smoke)
    rows = []
    for stage in STAGES:
        for oname in OPTS:
            for (wf, wt) in DIRECTIONS:
                opt = _make_opt(oname)
                comm = LocalComm(wf)
                strat = get_strategy(f"sync_zero{stage}", bucket_bytes=bb)
                state = init_train_state(comm.replicate(params0), opt,
                                         strat, comm)
                step = make_replica_train_step(loss_fn, opt, strat, comm,
                                               donate=False, bucket_bytes=bb)
                vf = FleetView(0, tuple(range(wf)))
                for _ in range(2):  # non-trivial optimizer state
                    state, _ = step(state, batch_fn(vf, 0))
                vt = FleetView(1, tuple(range(wt)))
                owns = bool(getattr(strat, "owns_params", False))
                full = (strat.gather_params(state["params"], comm)
                        if owns else state["params"])
                play = Fabric(comm, bb).partitioned_layout(full)

                def prime_old():
                    # ZeRO-3 records ONE layout; re-prime the old width so
                    # each timed resize starts from the pre-resize state
                    jax.eval_shape(
                        lambda p: strat.init_params(p, comm), full)

                def live_resize():
                    if owns:
                        prime_old()
                    return resize_state(state, vf, vt, strategy=strat,
                                        bucket_bytes=bb)

                live = live_resize()
                med, _, _ = time_stats(live_resize, iters=iters,
                                       warmup=warmup)

                # checkpoint-restore baseline over the same state
                tree = {"opt_state": state["opt_state"]}
                if owns:
                    tree["param_shards"] = state["params"]
                comm2 = LocalComm(wt)
                strat2 = get_strategy(f"sync_zero{stage}", bucket_bytes=bb)
                t2 = init_train_state(comm2.replicate(params0), opt,
                                      strat2, comm2)
                template = {"opt_state": jax.tree.map(jnp.zeros_like,
                                                      t2["opt_state"])}
                if owns:
                    template["param_shards"] = jax.tree.map(
                        jnp.zeros_like, t2["params"])
                tmpdir = tempfile.mkdtemp(prefix="bench_elastic_")

                def ckpt_roundtrip():
                    save_checkpoint(tmpdir, 0, tree, partition=play.spec())
                    return restore_checkpoint(tmpdir, 0, template,
                                              repartition=True)

                restored = ckpt_roundtrip()
                cmed, _, _ = time_stats(ckpt_roundtrip, iters=iters,
                                        warmup=warmup)

                bitwise = all(
                    np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(jax.tree.leaves(live["opt_state"]),
                                    jax.tree.leaves(restored["opt_state"])))
                if owns:
                    bitwise = bitwise and all(
                        np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(
                            jax.tree.leaves(live["params"]),
                            jax.tree.leaves(restored["param_shards"])))

                sf = {"sgd": 0, "adam": 2}[oname] + (1 if owns else 0)
                sizes = play.layout.bucket_sizes
                rows.append({
                    "zero_stage": stage, "optimizer": oname,
                    "w_from": wf, "w_to": wt,
                    "resize_ms": med / 1e3, "ckpt_ms": cmed / 1e3,
                    "speedup": cmed / max(med, 1e-9),
                    "bitwise": bool(bitwise),
                    "moved_bytes": resize_moved_bytes(
                        sizes, wf, wt, state_floats=max(sf, 1)),
                    "ckpt_bytes": checkpoint_roundtrip_bytes(
                        sizes, state_floats=max(sf, 1)),
                })
                emit(f"elastic/resize/z{stage}/{oname}/{wf}to{wt}",
                     med, f"ckpt_us={cmed:.0f};bitwise={bitwise}")
    return rows


# ---------------------------------------------------------------------------
# recovery: seeded kill mid-run
# ---------------------------------------------------------------------------
def bench_recovery(smoke: bool):
    from repro.core.chaos import ChaosEvent, ChaosSchedule
    from repro.launch.elastic import ElasticFleet

    params0, loss_fn, batch_fn = _problem(smoke)
    horizon = 12 if smoke else 30
    kill_t = 5
    sched = ChaosSchedule((ChaosEvent(kill_t, "kill", 2),))
    fleet = ElasticFleet(params0, loss_fn, _make_opt("adam"), workers=4,
                         chaos=sched, backoff_s=0.0, retries=2)
    logs = fleet.run(horizon, batch_fn)
    loss_pre = logs[kill_t - 1]["loss"]
    reconverge = next((lg["t"] - kill_t for lg in logs[kill_t:]
                       if lg["loss"] <= loss_pre), None)
    kill_log = logs[kill_t]
    row = {
        "workers": 4, "kill_step": kill_t, "horizon": horizon,
        "continued": len(logs) == horizon and kill_log["size_after"] == 3,
        "recovered_within_boundary": kill_log["size_after"] == 3
            and kill_log["attempts"] > 0,
        "boundaries_to_reconverge": reconverge,
        "loss_pre_kill": loss_pre, "loss_final": logs[-1]["loss"],
        "epoch_final": fleet.view.epoch,
    }
    emit("elastic/recovery/kill", 0.0,
         f"reconverge={reconverge};final={row['loss_final']:.4f}")
    return row


# ---------------------------------------------------------------------------
# chaos vs clean loss
# ---------------------------------------------------------------------------
def bench_chaos_loss(smoke: bool):
    from repro.core.chaos import ChaosEvent, ChaosSchedule, FleetClock
    from repro.core.staleness import StragglerPolicy
    from repro.launch.elastic import ElasticFleet

    params0, loss_fn, batch_fn = _problem(smoke)
    horizon = 14 if smoke else 40
    sched = ChaosSchedule((
        ChaosEvent(2, "slowdown", 1, 4.0),
        ChaosEvent(4, "flake", 0),
        ChaosEvent(6, "kill", 3),
        ChaosEvent(10, "restore", 1),
        ChaosEvent(12, "rejoin", 3),
    ))
    policy = StragglerPolicy(patience=2, recovery=3)

    def run(chaos):
        fleet = ElasticFleet(
            params0, loss_fn, _make_opt("adam"), workers=4,
            straggler_policy=policy, resync_every=4,
            chaos=chaos, clock=FleetClock(4, seed=7),
            backoff_s=0.0, retries=2)
        return fleet.run(horizon, batch_fn), fleet

    clean_logs, _ = run(None)
    chaos_logs, fleet = run(sched)
    demoted = sum(len(lg.get("demoted", ())) for lg in chaos_logs)
    promoted = sum(len(lg.get("promoted", ())) for lg in chaos_logs)
    clean, chaos = clean_logs[-1]["loss"], chaos_logs[-1]["loss"]
    initial = clean_logs[0]["loss"]
    row = {
        "horizon": horizon, "workers": 4,
        "loss_initial": initial,
        "clean_final_loss": clean, "chaos_final_loss": chaos,
        "delta": chaos - clean,
        # delta as a fraction of the loss the clean run burned down —
        # well-conditioned even when both runs converge to ~0 (where a
        # raw final-loss ratio blows up)
        "delta_norm": (chaos - clean) / max(initial - clean, 1e-12),
        "ratio": chaos / max(clean, 1e-12),
        "demoted_events": demoted, "promoted_events": promoted,
        "epoch_final": fleet.view.epoch,
        "schedule": sched.spec(),
    }
    emit("elastic/chaos_loss", 0.0,
         f"delta_norm={row['delta_norm']:.4f};demoted={demoted}")
    return row


def run(smoke=None):
    import jax

    if smoke is None:
        smoke = os.environ.get("BENCH_ELASTIC_SMOKE", "") not in ("", "0")
    iters, warmup = (3, 1) if smoke else (10, 2)
    report = {
        "meta": {
            "schema": 1,
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0].device_kind),
            "jax": jax.__version__,
            "smoke": bool(smoke),
            "iters": iters,
            "warmup": warmup,
            "note": ("chaos/recovery runs are fully seeded (replayable); "
                     "resize timings are host+device wall clock on the "
                     "stacked simulator"),
        },
        "resize": bench_resize(smoke, iters, warmup),
        "recovery": bench_recovery(smoke),
        "chaos_loss": bench_chaos_loss(smoke),
    }
    with open(OUT, "w") as f:
        json.dump(report, f, indent=1)
    emit("elastic/report", 0.0, f"out={os.path.basename(OUT)};smoke={smoke}")
    return report


def validate(path=OUT):
    """Schema + contract check for BENCH_elastic.json; raises ValueError on
    violation (CI runs this against the committed and regenerated file)."""
    from benchmarks.common import (check, load_report, require_keys,
                                   require_positive, require_sections)
    label = "BENCH_elastic.json"
    report = load_report(path, "python benchmarks/run.py elastic")
    require_sections(report, ("meta", "resize", "recovery", "chaos_loss"),
                     label)
    require_keys(report["meta"], ("backend", "smoke"), "meta")
    covered = set()
    for row in report["resize"]:
        require_keys(row, ("zero_stage", "optimizer", "w_from", "w_to",
                           "resize_ms", "ckpt_ms", "bitwise",
                           "moved_bytes", "ckpt_bytes"), "resize row")
        require_positive(row, ("resize_ms", "ckpt_ms"), "resize row")
        check(row["bitwise"] is True,
              f"resize row z{row['zero_stage']}/{row['optimizer']}/"
              f"{row['w_from']}to{row['w_to']}: live resize is NOT bitwise "
              "equal to the checkpoint round-trip")
        check(row["moved_bytes"] <= row["ckpt_bytes"],
              "in-memory resize moves more bytes than the checkpoint "
              "round-trip baseline — accounting is broken")
        covered.add((row["zero_stage"], row["optimizer"],
                     (row["w_from"], row["w_to"])))
    want = {(s, o, d) for s in STAGES for o in OPTS for d in DIRECTIONS}
    missing = want - covered
    check(not missing, f"resize coverage incomplete: missing {sorted(missing)}")
    rec = report["recovery"]
    require_keys(rec, ("continued", "recovered_within_boundary",
                       "boundaries_to_reconverge", "loss_final"), "recovery")
    check(rec["continued"] is True,
          "recovery: training did not continue on the surviving fleet")
    check(rec["recovered_within_boundary"] is True,
          "recovery: the kill boundary did not complete on the survivors")
    check(rec["boundaries_to_reconverge"] is not None
          and 0 <= rec["boundaries_to_reconverge"] <= 8,
          f"recovery: reconvergence took "
          f"{rec['boundaries_to_reconverge']!r} boundaries (want <= 8)")
    cl = report["chaos_loss"]
    require_keys(cl, ("loss_initial", "clean_final_loss",
                      "chaos_final_loss", "delta_norm",
                      "demoted_events"), "chaos_loss")
    require_positive(cl, ("loss_initial", "clean_final_loss",
                          "chaos_final_loss"), "chaos_loss")
    check(cl["delta_norm"] <= 0.25,
          f"chaos_loss: chaos run gave back {cl['delta_norm']:.2f} of the "
          "clean run's loss reduction (want <= 0.25)")
    check(cl["demoted_events"] >= 1,
          "chaos_loss: the slowdown never triggered a straggler demotion")
    return report


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--validate", action="store_true",
                    help="check the committed artifact and exit")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    if args.validate:
        validate()
        meta = json.load(open(OUT))["meta"]
        print(f"{os.path.basename(OUT)}: OK — smoke={meta['smoke']}")
        return
    run(smoke=args.smoke or None)


if __name__ == "__main__":
    main()
