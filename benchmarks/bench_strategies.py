"""Benchmark: spectrum-strategy convergence (paper §3's central claim).

Validates: (i) points 1–3 (sync / SSP / downpour) are near-indistinguishable
in convergence on homogeneous fabric; (ii) partial communication (gossip)
still trains while genuinely diverging across replicas; (iii) per-step wire
bytes ranks the strategies.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.data.pipeline import DataConfig, bayes_entropy, worker_batches
from repro.models import transformer as T
from repro.optim import adam
from repro.train.loop import (init_train_state, make_loss_fn,
                              make_replica_train_step)

W, STEPS, SEQ, BPW = 4, 120, 32, 4


def _cfg():
    import dataclasses
    return dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=64)


def run(out_rows=None):
    cfg = _cfg()
    comm = LocalComm(W)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=SEQ,
                      batch_per_worker=BPW, seed=0)
    lf = make_loss_fn(cfg, remat=False)

    def loss_fn(p, toks):
        return lf(p, {"tokens": toks, "labels": toks})

    results = {}
    for name, strat in [
        ("sync", ST.sync()),
        ("ssp_s4", ST.ssp(staleness=4)),
        ("downpour_p4", ST.downpour(push_every=4)),
        ("gossip", ST.gossip()),
        ("local_sgd_h8", ST.local_sgd(sync_every=8)),
    ]:
        opt = adam(3e-3)
        params = comm.replicate(T.init_model(jax.random.PRNGKey(0), cfg))
        state = init_train_state(params, opt, strat, comm)
        step = make_replica_train_step(loss_fn, opt, strat, comm)
        t0, losses, wire = time.perf_counter(), [], 0.0
        for t in range(STEPS):
            state, m = step(state, worker_batches(dcfg, W, t))
            losses.append(float(m["loss"]))
            wire += float(m["wire_bytes"])
        dt = time.perf_counter() - t0
        samples_s = W * BPW * STEPS / dt
        final = float(np.mean(losses[-10:]))
        div = float(m["replica_divergence"])
        results[name] = final
        derived = (f"final_loss={final:.4f};divergence={div:.3e};"
                   f"wireB_per_step={wire/STEPS:.0f};samples_per_s={samples_s:.0f};"
                   f"spectrum_pt={strat.spectrum_point}")
        emit(f"strategies/{name}", dt / STEPS * 1e6, derived)
        if out_rows is not None:
            out_rows.append((name, final, div, wire / STEPS))
    # §3 equivalence check, printed as derived claims
    pts123 = [results["sync"], results["ssp_s4"], results["downpour_p4"]]
    spread = (max(pts123) - min(pts123)) / np.mean(pts123)
    emit("strategies/claim_pts123_equivalent", 0.0,
         f"relative_spread={spread:.3f};claim_holds={spread < 0.35}")
    emit("strategies/claim_gossip_trains", 0.0,
         f"gossip_final={results['gossip']:.4f};"
         f"uniform={np.log(_cfg().vocab_size):.4f};"
         f"floor={bayes_entropy(DataConfig(vocab_size=64, seq_len=SEQ, batch_per_worker=BPW)):.4f}")
    return results


if __name__ == "__main__":
    run()
