"""Benchmark: communication-volume scaling (paper §2.2.4's "the gradient
set easily reaches a few hundred MB").

Reports the gradient-set size of every assigned architecture and the
per-step wire bytes per strategy × worker count × compressor — the
quantity the FAST design exists to manage."""

from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.compression import get_compressor
from repro.launch.dryrun import ALL_ARCHS


def run():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        n = cfg.param_count()
        grad_mb = n * 4 / 2**20  # f32 gradient set, the paper's framing
        emit(f"scaling/gradset_{arch}", 0.0,
             f"params={n};grad_set_MB={grad_mb:.0f};"
             f"paper_claim_few_hundred_MB={'exceeded' if grad_mb > 500 else 'matched'}")
    # wire bytes per sync round per worker under each compressor
    n = get_config("gemma3-1b").param_count()
    for comp_name, comp in [
        ("none", get_compressor("none")),
        ("int8", get_compressor("int8")),
        ("onebit", get_compressor("onebit")),
        ("topk_1pct", get_compressor("topk", ratio=0.01)),
    ]:
        wire_mb = n * comp.wire_bits_per_element / 8 / 2**20
        emit(f"scaling/wire_gemma3-1b_{comp_name}", 0.0,
             f"wire_MB_per_round={wire_mb:.1f};"
             f"reduction_x={32.0/comp.wire_bits_per_element:.1f}")


if __name__ == "__main__":
    run()
