"""Ablation: the paper's §3 open questions, answered empirically.

  * How does convergence degrade with SSP staleness s?         (bounded delay)
  * …with downpour push interval?                                (unbounded-ish)
  * …with gossip mixing frequency?                    (partial communication)
  * Does staleness-aware LR scaling ([40]) help at high staleness?
  * Does DGC momentum correction ([54]) beat plain error feedback at 1%?
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.core.compression import get_compressor
from repro.data.pipeline import DataConfig, worker_batches
from repro.models import transformer as T
from repro.optim import adam
from repro.train.loop import (init_train_state, make_loss_fn,
                              make_replica_train_step)

W, STEPS = 4, 100


def _cfg():
    import dataclasses
    return dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=64)


def _final_loss(strategy):
    cfg = _cfg()
    comm = LocalComm(W)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      batch_per_worker=4, seed=0)
    lf = make_loss_fn(cfg, remat=False)
    opt = adam(3e-3)
    params = comm.replicate(T.init_model(jax.random.PRNGKey(0), cfg))
    state = init_train_state(params, opt, strategy, comm)
    step = make_replica_train_step(
        lambda p, t_: lf(p, {"tokens": t_, "labels": t_}), opt, strategy, comm)
    losses = []
    for t in range(STEPS):
        state, m = step(state, worker_batches(dcfg, W, t))
        losses.append(float(m["loss"]))
    return float(np.mean(losses[-10:])), float(m["replica_divergence"])


def run():
    base, _ = _final_loss(ST.sync())
    emit("ablation/sync_reference", 0.0, f"final_loss={base:.4f}")
    for s in (1, 4, 8, 16):
        fl, div = _final_loss(ST.ssp(staleness=s))
        emit(f"ablation/ssp_s{s}", 0.0,
             f"final_loss={fl:.4f};delta_vs_sync={fl-base:+.4f};div={div:.2e}")
    fl_plain, _ = _final_loss(ST.ssp(staleness=16))
    fl_aware, _ = _final_loss(ST.ssp(staleness=16, staleness_aware_lr=True))
    emit("ablation/staleness_aware_lr_s16", 0.0,
         f"plain={fl_plain:.4f};aware={fl_aware:.4f};"
         f"aware_helps={fl_aware < fl_plain}")
    for pe in (2, 8, 16):
        fl, div = _final_loss(ST.downpour(push_every=pe))
        emit(f"ablation/downpour_p{pe}", 0.0,
             f"final_loss={fl:.4f};delta_vs_sync={fl-base:+.4f};div={div:.2e}")
    for me in (1, 4, 16):
        fl, div = _final_loss(ST.gossip(mix_every=me))
        emit(f"ablation/gossip_m{me}", 0.0,
             f"final_loss={fl:.4f};delta_vs_sync={fl-base:+.4f};div={div:.2e}")
    fl, _ = _final_loss(ST.easgd(alpha=0.2, sync_every=4))
    emit("ablation/easgd", 0.0, f"final_loss={fl:.4f};delta_vs_sync={fl-base:+.4f}")
    topk = get_compressor("topk", ratio=0.01)
    fl_ef, _ = _final_loss(ST.sync(compressor=topk))
    fl_dgc, _ = _final_loss(ST.sync_dgc(topk))
    emit("ablation/topk1pct_ef_vs_dgc", 0.0,
         f"plain_ef={fl_ef:.4f};dgc_momentum={fl_dgc:.4f};"
         f"dgc_helps={fl_dgc < fl_ef}")


if __name__ == "__main__":
    run()
