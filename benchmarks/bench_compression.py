"""Benchmark: gradient compression (paper §2.2.4) — wire bytes vs final
loss, with error feedback.  Validates the ~32× (1-bit) and ~50–100× (top-k)
reductions at bounded accuracy cost, and times the compression ops."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.core.compression import get_compressor, wire_bytes
from repro.data.pipeline import DataConfig, worker_batches
from repro.models import transformer as T
from repro.optim import adam
from repro.train.loop import (init_train_state, make_loss_fn,
                              make_replica_train_step)

W, STEPS = 4, 120


def _cfg():
    import dataclasses
    return dataclasses.replace(
        get_config("qwen2-1.5b").reduced(), num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=64)


def run():
    cfg = _cfg()
    comm = LocalComm(W)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      batch_per_worker=4, seed=0)
    lf = make_loss_fn(cfg, remat=False)

    def loss_fn(p, toks):
        return lf(p, {"tokens": toks, "labels": toks})

    base_bytes = None
    base_loss = None
    for name, comp in [
        ("none", None),
        ("int8", get_compressor("int8")),
        ("onebit", get_compressor("onebit")),
        ("topk_1pct", get_compressor("topk", ratio=0.01)),
    ]:
        opt = adam(3e-3)
        strat = ST.sync(compressor=comp)
        params = comm.replicate(T.init_model(jax.random.PRNGKey(0), cfg))
        state = init_train_state(params, opt, strat, comm)
        step = make_replica_train_step(loss_fn, opt, strat, comm)
        t0, losses, wire = time.perf_counter(), [], 0.0
        for t in range(STEPS):
            state, m = step(state, worker_batches(dcfg, W, t))
            losses.append(float(m["loss"]))
            wire += float(m["wire_bytes"])
        dt = time.perf_counter() - t0
        final = float(np.mean(losses[-10:]))
        per_step = wire / STEPS
        if name == "none":
            base_bytes, base_loss = per_step, final
        emit(f"compression/{name}", dt / STEPS * 1e6,
             f"final_loss={final:.4f};wireB_per_step={per_step:.0f};"
             f"reduction_x={base_bytes/per_step:.1f};"
             f"loss_delta={final-base_loss:+.4f}")

    # raw op timing (pure-jnp reference path, which is what executes on CPU)
    from repro.kernels import ref
    g = jax.random.normal(jax.random.PRNGKey(0), (4096, 256))
    r = jnp.zeros_like(g)
    f_1bit = jax.jit(lambda g, r: ref.onebit_quant_ref(g, r))
    emit("compression/op_onebit_1M", time_fn(f_1bit, g, r),
         "elements=1048576;oracle=ref.onebit_quant_ref")
    f_topk = jax.jit(lambda g: ref.topk_sparsify_ref(g, 8))
    emit("compression/op_topk_1M", time_fn(f_topk, g),
         "elements=1048576;k=8/256;oracle=ref.topk_sparsify_ref")


if __name__ == "__main__":
    run()
