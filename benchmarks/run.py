"""Benchmark harness — one module per paper claim/table.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_strategies   — §3 spectrum convergence (sync/ssp/downpour/gossip)
  bench_compression  — §2.2.4 quantization + sparsification, error feedback
  bench_consistency  — §3 Statement 1 / Figure 3
  bench_staleness    — §3 staleness ⇒ implicit momentum (Mitliagkas)
  bench_scaling      — §2.2.4 gradient-set sizes / wire volumes per arch
  bench_roofline     — dry-run roofline table (deliverable g)
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_ablation, bench_compression,
                            bench_consistency, bench_roofline, bench_scaling,
                            bench_staleness, bench_strategies)

    print("name,us_per_call,derived")
    mods = [
        ("strategies", bench_strategies),
        ("compression", bench_compression),
        ("consistency", bench_consistency),
        ("staleness", bench_staleness),
        ("scaling", bench_scaling),
        ("ablation", bench_ablation),
        ("roofline", bench_roofline),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failed = 0
    for name, mod in mods:
        if only and only != name:
            continue
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failed += 1
            traceback.print_exc()
            print(f"{name}/ERROR,0,failed")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
