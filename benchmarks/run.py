"""Benchmark harness — one module per paper claim/table.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_strategies   — §3 spectrum convergence (sync/ssp/downpour/gossip)
  bench_compression  — §2.2.4 quantization + sparsification, error feedback
  bench_consistency  — §3 Statement 1 / Figure 3
  bench_staleness    — §3 staleness ⇒ implicit momentum (Mitliagkas)
  bench_scaling      — §2.2.4 gradient-set sizes / wire volumes per arch
  bench_roofline     — dry-run roofline table (deliverable g)
  bench_timing       — measured wall-clock tier (DESIGN.md §9)
  bench_serving      — paged-KV serving load benchmark (DESIGN.md §10)
  bench_elastic      — elastic resize / chaos recovery tier (DESIGN.md §13)
"""

from __future__ import annotations

import importlib
import os
import sys
import traceback

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) at
# sys.path[0]; the benchmarks package needs the root
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

# run order; each entry is benchmarks/bench_<name>.py
MODULES = ("strategies", "compression", "consistency", "staleness",
           "scaling", "ablation", "roofline", "timing", "serving",
           "elastic")


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    if only is not None and only not in MODULES:
        print(f"unknown benchmark {only!r}; valid names: "
              + ", ".join(MODULES), file=sys.stderr)
        raise SystemExit(2)
    print("name,us_per_call,derived")
    failed = 0
    for name in MODULES:
        if only and only != name:
            continue
        try:
            # import inside the loop: one module failing to IMPORT still
            # gets its ERROR row and the sweep continues
            mod = importlib.import_module(f"benchmarks.bench_{name}")
            mod.run()
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failed += 1
            traceback.print_exc()
            print(f"{name}/ERROR,0,failed")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
