"""Shared benchmark utilities.

Also home of the one schema-check vocabulary every committed JSON artifact
validator speaks (BENCH_timing.json, BENCH_serving.json, LINT.json,
PLAN.json) — presence, positivity and section checks used to be hand-rolled
per validator; they all raise the same ``ValueError`` shape now so CI
failures read uniformly."""

from __future__ import annotations

import json
import os
import time

import jax


# ---------------------------------------------------------------------------
# schema-check helpers (shared by bench_timing / bench_serving /
# repro.analysis.report / repro.launch.planner validators)
# ---------------------------------------------------------------------------
def load_report(path: str, regen_hint: str) -> dict:
    """Read a committed JSON artifact; a missing file is a schema error
    that tells the reader how to regenerate it."""
    if not os.path.exists(path):
        raise ValueError(f"{path} is missing — run `{regen_hint}`")
    with open(path) as f:
        return json.load(f)


def check(cond, msg: str):
    """One uniform failure shape for every artifact validator."""
    if not cond:
        raise ValueError(msg)


def require_sections(report: dict, names, label: str):
    for key in names:
        check(key in report, f"{label}: missing section {key!r}")


def require_keys(row: dict, fields, label: str):
    for f_ in fields:
        check(f_ in row, f"{label} missing {f_!r}: {row}")


def require_positive(row: dict, fields, label: str):
    for f_ in fields:
        check(row.get(f_, 0) > 0, f"{label} bad (non-positive) {f_!r}: {row}")


def time_fn(fn, *args, iters: int = 20, warmup: int = 3):
    """Median wall time per call in microseconds (jit-compiled path)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_stats(fn, *args, iters: int = 20, warmup: int = 3):
    """(median, min, max) wall µs per call — same protocol as ``time_fn``
    (warmup calls cover compilation, every timed call blocks on the full
    output pytree) but keeping the spread for BENCH_timing.json."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return (times[len(times) // 2] * 1e6, times[0] * 1e6, times[-1] * 1e6)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
