"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 20, warmup: int = 3):
    """Median wall time per call in microseconds (jit-compiled path)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_stats(fn, *args, iters: int = 20, warmup: int = 3):
    """(median, min, max) wall µs per call — same protocol as ``time_fn``
    (warmup calls cover compilation, every timed call blocks on the full
    output pytree) but keeping the spread for BENCH_timing.json."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return (times[len(times) // 2] * 1e6, times[0] * 1e6, times[-1] * 1e6)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
