"""Benchmark: staleness ↔ implicit momentum (paper §3 via Mitliagkas et
al.): fit the effective momentum β̂ of each strategy's weight trajectory
and compare with the β = 1 − 1/W prediction."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.core.staleness import effective_momentum_fit, implicit_momentum
from repro.optim import sgd
from repro.train.loop import init_train_state, make_replica_train_step

DIM, NDATA, STEPS = 16, 128, 150


def run():
    for W in (2, 4, 8):
        key = jax.random.PRNGKey(0)
        Xs = jax.random.normal(key, (W, NDATA, DIM))
        w_true = jax.random.normal(jax.random.PRNGKey(1), (DIM,))
        Ys = Xs @ w_true + 0.01 * jax.random.normal(jax.random.PRNGKey(2), (W, NDATA))

        def loss_fn(params, batch):
            X, Y = batch
            return jnp.mean((X @ params["w"] - Y) ** 2)

        comm = LocalComm(W)
        for name, strat in [
            ("sync", ST.sync()),
            (f"ssp_s{W}", ST.ssp(staleness=W)),
            ("downpour", ST.downpour(push_every=W)),
        ]:
            opt = sgd(0.02)
            params = comm.replicate({"w": jnp.zeros(DIM)})
            state = init_train_state(params, opt, strat, comm)
            step = make_replica_train_step(loss_fn, opt, strat, comm)
            traj = []
            for t in range(STEPS):
                state, m = step(state, (Xs, Ys))
                traj.append(np.asarray(state["params"]["w"][0]))
            beta_hat = effective_momentum_fit(np.stack(traj))
            pred = implicit_momentum(W)
            emit(f"staleness/W{W}_{name}", 0.0,
                 f"beta_hat={beta_hat:.3f};mitliagkas_pred={pred:.3f};"
                 f"stale_has_more_momentum={beta_hat:.3f}")


if __name__ == "__main__":
    run()
