"""Benchmark: Statement 1 at scale (paper §3, Figure 3).

Drives the consistency simulator across worker counts and delay regimes:
complete delivery drains to bit-identical replicas; dropping even one
update breaks consistency.  Derived column reports max divergence."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.consistency import ConsistencySim


def run():
    rng = np.random.default_rng(0)
    for n in (2, 8, 32):
        for regime, delay_fn in [
            ("zero_delay", lambda: 0),
            ("uniform_delay", lambda: int(rng.integers(0, 20))),
            ("extreme_delay", lambda: int(rng.integers(0, 500))),
        ]:
            t0 = time.perf_counter()
            sim = ConsistencySim(n, dim=64, lr=0.05, seed=1)
            for t in range(20):
                for src in range(n):
                    d = {dst: delay_fn() for dst in range(n) if dst != src}
                    sim.produce(src, rng.normal(size=64), t, delays=d)
                sim.step()
            sim.drain()
            dt = (time.perf_counter() - t0) * 1e6
            emit(f"consistency/W{n}_{regime}", dt,
                 f"divergence={sim.max_divergence():.3e};"
                 f"consistent={sim.consistent()};updates={20*n}")
    # the counterexample: drop 1% of deliveries
    sim = ConsistencySim(8, dim=64, lr=0.05, seed=1)
    for t in range(20):
        for src in range(8):
            d = {dst: (None if rng.random() < 0.01 else 0)
                 for dst in range(8) if dst != src}
            sim.produce(src, rng.normal(size=64), t, delays=d)
        sim.step()
    sim.drain()
    emit("consistency/W8_drop1pct", 0.0,
         f"divergence={sim.max_divergence():.3e};"
         f"consistent={sim.consistent()};dropped={sim.dropped}")


if __name__ == "__main__":
    run()
