"""Benchmark: roofline table from the multi-pod dry-run artifacts
(results_singlepod.json / results_multipod.json, produced by
``python -m repro.launch.dryrun --all [--multi-pod] --out ...``), plus the
fabric fusion check: the lowered exchange HLO must contain at most
n_buckets cross-worker collectives (one per leaf before core/fabric.py).

Every check also contributes to ``BENCH_roofline.json`` at the repo root —
the machine-readable perf trajectory (wire bytes, bytes/sample, collective
counts, step-time estimates) tracked across PRs."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FUSION_CHECK = """
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.compression import get_compressor
    from repro.core.fabric import BucketLayout, wire_nbytes
    from repro.core.jax_compat import make_mesh, set_mesh, shard_map
    from repro.launch.exchange import build_exchange
    from repro.roofline.analysis import collective_count, parse_collectives

    PODS, LAYERS = 4, 8
    mesh = make_mesh((PODS,), ("pod",))
    g = {f"l{i}": {"w": jax.ShapeDtypeStruct((PODS, 256, 64), jnp.float32),
                   "b": jax.ShapeDtypeStruct((PODS, 64), jnp.float32)}
         for i in range(LAYERS)}
    bucket_bytes = 4 * 40_000
    view = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((1,) + s.shape[1:], jnp.float32), g)
    lay = BucketLayout.build(view, bucket_bytes, lead_axes=0)
    rows = {"n_leaves": 2 * LAYERS, "n_buckets": lay.n_buckets}
    for name in ("none", "onebit", "int8", "topk"):
        comp = None if name == "none" else get_compressor(name)
        fn = shard_map(build_exchange(comp, bucket_bytes), mesh=mesh,
                       axis_names={"pod"}, in_specs=(P("pod"), P("pod")),
                       out_specs=(P("pod"), P("pod")), check_vma=False)
        with set_mesh(mesh):
            c = jax.jit(fn).lower(g, g).compile()
        pc = parse_collectives(c.as_text())
        est = PODS * sum(wire_nbytes(comp, n) for n in lay.bucket_sizes)
        rows[name] = {"collectives": collective_count(c.as_text()),
                      "hlo_bytes": sum(pc["bytes"].values()),
                      "fabric_bytes": est}
    print("FUSION " + json.dumps(rows))
"""


def check_fusion():
    """Lower the bucketed exchange on 4 forced host devices (subprocess:
    this process must keep the single real device) and emit the
    collective-count / wire-byte evidence."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_FUSION_CHECK)],
        capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        emit("roofline/fusion", 0.0, "error=" + out.stderr[-200:].replace(
            "\n", " ").replace(",", ";"))
        return None
    line = [l for l in out.stdout.splitlines() if l.startswith("FUSION ")][0]
    rows = json.loads(line[len("FUSION "):])
    n_leaves, n_buckets = rows.pop("n_leaves"), rows.pop("n_buckets")
    for name, r in rows.items():
        ok = r["collectives"] <= n_buckets
        ratio = rows["none"]["hlo_bytes"] / max(r["hlo_bytes"], 1)
        emit(f"roofline/fusion/{name}", float(r["collectives"]),
             f"n_leaves={n_leaves};n_buckets={n_buckets};"
             f"collectives={r['collectives']};fused={ok};"
             f"hlo_bytes={r['hlo_bytes']};fabric_bytes={r['fabric_bytes']};"
             f"compression_x={ratio:.1f}")
    return {"n_leaves": n_leaves, "n_buckets": n_buckets,
            "compressors": rows}


_ZERO1_CHECK = """
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import strategies as ST
    from repro.core.comm import ShardComm
    from repro.core.fabric import BucketLayout, Fabric
    from repro.core.jax_compat import make_mesh, set_mesh, shard_map
    from repro.optim import adam
    from repro.roofline.analysis import (exchange_wire_bytes,
                                         opt_state_bytes, parse_collectives)
    from repro.train.loop import zero1_opt_template

    PODS, LAYERS = 4, 8
    mesh = make_mesh((PODS,), ("pod",))
    params = {f"l{i}": {"w": jax.ShapeDtypeStruct((256, 64), jnp.float32),
                        "b": jax.ShapeDtypeStruct((64,), jnp.float32)}
              for i in range(LAYERS)}
    bucket_bytes = 4 * 40_000
    lay = BucketLayout.build(params, bucket_bytes, lead_axes=0)
    opt = adam(1e-3)
    opt_state = zero1_opt_template(params, opt, PODS, bucket_bytes)
    strat = ST.sync_zero1(bucket_bytes=bucket_bytes)
    comm = ShardComm("pod", PODS)

    def body(p, g, s):
        p, s, _, _ = strat.update(p, g, s, {}, jnp.zeros((), jnp.int32),
                                  opt, comm)
        return p, s

    rep = jax.tree.map(lambda _: P(), params)
    ssp = jax.tree.map(lambda _: P("pod"), opt_state)
    fn = shard_map(body, mesh=mesh, axis_names={"pod"},
                   in_specs=(rep, rep, ssp), out_specs=(rep, ssp),
                   check_vma=False)
    with set_mesh(mesh):
        c = jax.jit(fn).lower(params, params, opt_state).compile()
    pc = parse_collectives(c.as_text())
    n = sum(x.size for x in jax.tree.leaves(params))
    shard_elems = sum(x.size for x in jax.tree.leaves(opt_state)) // PODS
    rows = {"n_buckets": lay.n_buckets,
            "counts": pc["counts"],
            "dense_state_bytes": opt_state_bytes(n, opt.state_floats),
            "zero1_state_bytes": 4 * shard_elems,
            "zero1_model_bytes": opt_state_bytes(n, opt.state_floats,
                                                 PODS, partitioned=True),
            "wire_dense": exchange_wire_bytes(4 * n, PODS),
            "wire_zero1": exchange_wire_bytes(4 * n, PODS, partitioned=True)}
    print("ZERO1 " + json.dumps(rows))
"""


def check_zero1():
    """Lower the partitioned (ZeRO-1) exchange on 4 forced host devices and
    emit the reduce-scatter/all-gather counts + the ~W per-worker
    optimizer-state shrink."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_ZERO1_CHECK)],
        capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        emit("roofline/zero1", 0.0, "error=" + out.stderr[-200:].replace(
            "\n", " ").replace(",", ";"))
        return None
    line = [l for l in out.stdout.splitlines() if l.startswith("ZERO1 ")][0]
    rows = json.loads(line[len("ZERO1 "):])
    counts = rows["counts"]
    ok = (0 < counts["reduce-scatter"] <= rows["n_buckets"]
          and 0 < counts["all-gather"] <= rows["n_buckets"]
          and counts["all-reduce"] == 0)
    shrink = rows["dense_state_bytes"] / max(rows["zero1_state_bytes"], 1)
    emit("roofline/zero1", float(counts["reduce-scatter"]),
         f"n_buckets={rows['n_buckets']};rs={counts['reduce-scatter']};"
         f"ag={counts['all-gather']};ar={counts['all-reduce']};"
         f"partitioned={ok};state_shrink_x={shrink:.2f};"
         f"model_shrink_x={rows['dense_state_bytes']/max(rows['zero1_model_bytes'],1):.2f};"
         f"wire_parity={rows['wire_zero1'] == rows['wire_dense']}")
    rows["ok"] = ok
    rows["state_shrink_x"] = shrink
    return rows


_PRECISION_CHECK = """
    import json, re
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import strategies as ST
    from repro.core.comm import ShardComm
    from repro.core.fabric import BucketLayout, Fabric
    from repro.core.jax_compat import make_mesh, set_mesh, shard_map
    from repro.core.precision import get_policy
    from repro.optim import adam
    from repro.roofline.analysis import parse_collectives
    from repro.train.loop import zero1_opt_template

    PODS, LAYERS = 4, 8
    mesh = make_mesh((PODS,), ("pod",))
    bucket_bytes = 4 * 40_000

    def lower(policy_name):
        pol = get_policy(policy_name)
        pdt = pol.param_dt
        params = {f"l{i}": {"w": jax.ShapeDtypeStruct((256, 64), pdt),
                            "b": jax.ShapeDtypeStruct((64,), pdt)}
                  for i in range(LAYERS)}
        opt = adam(1e-3)
        opt_state = zero1_opt_template(params, opt, PODS, bucket_bytes,
                                       policy=None if pol.is_noop else pol)
        strat = ST.sync_zero1(bucket_bytes=bucket_bytes, policy=pol)
        comm = ShardComm("pod", PODS)

        def body(p, g, s):
            p, s, _, _ = strat.update(p, g, s, {}, jnp.zeros((), jnp.int32),
                                      adam(1e-3), comm)
            return p, s

        rep = jax.tree.map(lambda _: P(), params)
        ssp = jax.tree.map(lambda _: P("pod"), opt_state)
        fn = shard_map(body, mesh=mesh, axis_names={"pod"},
                       in_specs=(rep, rep, ssp), out_specs=(rep, ssp),
                       check_vma=False)
        with set_mesh(mesh):
            c = jax.jit(fn).lower(params, params, opt_state).compile()
        txt = c.as_text()
        pc = parse_collectives(txt)
        f32_rs = sum(1 for l in txt.splitlines()
                     if "reduce-scatter(" in l
                     and re.search(r"=\\s*f32\\[", l))
        fab = Fabric(comm, bucket_bytes, wire_dtype=pol.wire_dt)
        lay = BucketLayout.build(params, bucket_bytes, lead_axes=0)
        return {"hlo_bytes": pc["bytes"], "counts": pc["counts"],
                "f32_reduce_scatters": f32_rs,
                "fabric_wire_bytes": fab.flat_bytes(lay)}

    rows = {"f32": lower("f32"), "bf16": lower("bf16")}
    print("PRECISION " + json.dumps(rows))
"""


def check_precision():
    """Lower the ZeRO-1 exchange under the f32 and bf16 policies and emit
    the wire-shrink evidence: the bf16 reduce-scatter/all-gather ship ~2x
    fewer bytes and no f32 reduce-scatter survives in the HLO."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_PRECISION_CHECK)],
        capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        emit("roofline/precision", 0.0, "error=" + out.stderr[-200:].replace(
            "\n", " ").replace(",", ";"))
        return None
    line = [l for l in out.stdout.splitlines()
            if l.startswith("PRECISION ")][0]
    rows = json.loads(line[len("PRECISION "):])
    f32, bf16 = rows["f32"], rows["bf16"]
    shrink = f32["fabric_wire_bytes"] / max(bf16["fabric_wire_bytes"], 1)
    ok = (shrink > 1.99 and bf16["f32_reduce_scatters"] == 0
          and bf16["counts"]["reduce-scatter"] == 0
          and bf16["counts"]["all-to-all"] > 0)
    emit("roofline/precision", shrink,
         f"wire_shrink_x={shrink:.2f};ok={ok};"
         f"f32_rs_in_bf16_hlo={bf16['f32_reduce_scatters']};"
         f"bf16_rs={bf16['counts']['reduce-scatter']};"
         f"bf16_a2a={bf16['counts']['all-to-all']};"
         f"ag_bytes_f32={f32['hlo_bytes']['all-gather']};"
         f"ag_bytes_bf16={bf16['hlo_bytes']['all-gather']}")
    rows["ok"] = ok
    rows["wire_shrink_x"] = shrink
    return rows


_ACCUM_CHECK = """
    import json
    import jax, jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.comm import ShardComm
    from repro.core.fabric import BucketLayout, Fabric
    from repro.core.jax_compat import make_mesh, set_mesh, shard_map
    from repro.optim import adam
    from repro.roofline.analysis import parse_collectives, wire_bytes_per_sample
    from repro.train.loop import zero1_opt_template

    PODS, LAYERS, B = 4, 8, 8  # B = per-pod samples per microbatch
    mesh = make_mesh((PODS,), ("pod",))
    bucket_bytes = 4 * 40_000
    params = {f"l{i}": {"w": jnp.zeros((256, 64)), "b": jnp.zeros((64,))}
              for i in range(LAYERS)}
    lay = BucketLayout.build(params, bucket_bytes, lead_axes=0)
    comm = ShardComm("pod", PODS)
    opt = adam(1e-3)
    opt_state = zero1_opt_template(params, opt, PODS, bucket_bytes)

    def loss_fn(p, mb):
        # toy but differentiable-in-every-leaf loss with a real batch dep
        s = sum(jnp.vdot(l, l) for l in jax.tree.leaves(p))
        return s * jnp.mean(mb ** 2)

    def accum(fab, p, batch, k, play=None):
        la = fab.layout(p)
        def micro(carry, mb):
            acc, ls = carry
            l, g = jax.value_and_grad(loss_fn)(p, mb)
            return (fab.accumulate(acc, g, la, play=play), ls + l), None
        (acc, ls), _ = lax.scan(
            micro, (fab.init_accum(la, play), jnp.zeros(())), batch)
        return [a / k for a in acc], ls / k

    def lower(path, k):
        fab = Fabric(comm, bucket_bytes)
        if path == "dense":
            def body(p, batch):
                acc, _ = accum(fab, p, batch, k)
                g, _, _ = fab.exchange_accumulated(acc, lay)
                return jax.tree.map(lambda x, gg: x - 0.1 * gg, p, g)
            specs = (jax.tree.map(lambda _: P(), params), P(None, "pod"))
            outs = jax.tree.map(lambda _: P(), params)
            args = (params, jnp.zeros((k, PODS * B, 16)))
        else:
            play = fab.partitioned_layout(params)
            def body(p, batch, s):
                acc, _ = accum(fab, p, batch, k, play=play)
                g_sh, _ = fab.exchange_partitioned_accumulated(acc, play)
                p_sh, s = opt.update(g_sh, s, fab.shard_params(p, play), 0)
                return fab.unpartition(p_sh, play), s
            ssp = jax.tree.map(lambda _: P("pod"), opt_state)
            specs = (jax.tree.map(lambda _: P(), params), P(None, "pod"), ssp)
            outs = (jax.tree.map(lambda _: P(), params), ssp)
            args = (params, jnp.zeros((k, PODS * B, 16)), opt_state)
        fn = shard_map(body, mesh=mesh, axis_names={"pod"},
                       in_specs=specs, out_specs=outs, check_vma=False)
        with set_mesh(mesh):
            c = jax.jit(fn).lower(*args).compile()
        pc = parse_collectives(c.as_text())
        n = sum(x.size for x in jax.tree.leaves(params))
        return {"counts": pc["counts"],
                "hlo_bytes": sum(pc["bytes"].values()),
                "wire_bytes_per_sample": wire_bytes_per_sample(
                    4 * n, PODS, B, accum_steps=k)}

    rows = {"n_buckets": lay.n_buckets,
            "dense": {k: lower("dense", k) for k in (1, 4)},
            "zero1": {k: lower("zero1", k) for k in (1, 4)}}
    print("ACCUM " + json.dumps(rows))
"""


def check_accum():
    """Lower the microbatched boundary step (k=1 vs k=4) on both the dense
    sync and ZeRO-1 paths and emit the accumulation proof: wire bytes per
    SAMPLE shrink by exactly accum_steps while the step HLO still carries
    one exchange's worth of collectives (≤ n_buckets, the fused-Fabric
    bound) per boundary — the scan body is collective-free."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_ACCUM_CHECK)],
        capture_output=True, text=True, env=env, timeout=560)
    if out.returncode != 0:
        emit("roofline/accum", 0.0, "error=" + out.stderr[-200:].replace(
            "\n", " ").replace(",", ";"))
        return None
    line = [l for l in out.stdout.splitlines() if l.startswith("ACCUM ")][0]
    rows = json.loads(line[len("ACCUM "):])
    nb = rows["n_buckets"]
    oks = {}
    for path in ("dense", "zero1"):
        r1, r4 = rows[path]["1"], rows[path]["4"]
        ratio = r1["wire_bytes_per_sample"] / r4["wire_bytes_per_sample"]
        c1, c4 = r1["counts"], r4["counts"]
        exchange_ops = (c4["all-reduce"] if path == "dense"
                        else max(c4["reduce-scatter"], c4["all-gather"]))
        ok = (abs(ratio - 4.0) < 1e-9          # 4x fewer bytes per sample
              and c1 == c4                     # collectives don't scale in k
              and r1["hlo_bytes"] == r4["hlo_bytes"]  # nor do wire bytes
              and 0 < exchange_ops <= nb)      # one fused exchange/boundary
        oks[path] = ok
        emit(f"roofline/accum/{path}", ratio,
             f"n_buckets={nb};bytes_per_sample_x={ratio:.1f};ok={ok};"
             f"k4_counts=" + "/".join(f"{k}:{v}" for k, v in c4.items()
                                      if v) + ";"
             f"hlo_bytes_k1={r1['hlo_bytes']};hlo_bytes_k4={r4['hlo_bytes']}")
    rows["ok"] = all(oks.values())
    return rows


def run():
    report = {
        "fusion": check_fusion(),
        "zero1": check_zero1(),
        "precision": check_precision(),
        "accum": check_accum(),
        "dryrun": {},
    }
    for fname, mesh in (("results_singlepod.json", "16x16"),
                        ("results_multipod.json", "2x16x16")):
        path = os.path.join(ROOT, fname)
        if not os.path.exists(path):
            emit(f"roofline/{mesh}", 0.0, "missing=run repro.launch.dryrun --all")
            continue
        rows = json.load(open(path))
        ok = [r for r in rows if r["status"] == "ok"]
        for r in ok:
            ro = r["roofline"]
            # step-time estimate: the binding roofline term
            step_s = max(ro["compute_s"], ro["memory_s"],
                         ro["collective_s"])
            report["dryrun"].setdefault(mesh, []).append({
                "arch": r["arch"], "shape": r["shape"],
                "step_time_s_est": step_s, "dominant": ro["dominant"],
                "collective_bytes": ro["collective_bytes"],
                "collective_counts": ro["collective_counts"],
                "peak_per_device_gb": r["memory"]["peak_per_device_gb"],
                "accum_steps": r.get("accum_steps", 1),
            })
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                 ro["compute_s"] * 1e6,
                 f"dominant={ro['dominant']};compute_ms={ro['compute_s']*1e3:.2f};"
                 f"memory_ms={ro['memory_s']*1e3:.2f};"
                 f"collective_ms={ro['collective_s']*1e3:.2f};"
                 f"useful_flop_ratio={ro['useful_flops_ratio']:.2f};"
                 f"gb_per_device={r['memory']['peak_per_device_gb']:.2f}")
        nskip = sum(1 for r in rows if r["status"] == "skip")
        nerr = sum(1 for r in rows if r["status"] == "error")
        emit(f"roofline/{mesh}/summary", 0.0,
             f"ok={len(ok)};skip={nskip};error={nerr}")
    out = os.path.join(ROOT, "BENCH_roofline.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    emit("roofline/json", 0.0, f"wrote={os.path.basename(out)}")
    return report


if __name__ == "__main__":
    run()
