"""Benchmark: roofline table from the multi-pod dry-run artifacts
(results_singlepod.json / results_multipod.json, produced by
``python -m repro.launch.dryrun --all [--multi-pod] --out ...``)."""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run():
    for fname, mesh in (("results_singlepod.json", "16x16"),
                        ("results_multipod.json", "2x16x16")):
        path = os.path.join(ROOT, fname)
        if not os.path.exists(path):
            emit(f"roofline/{mesh}", 0.0, "missing=run repro.launch.dryrun --all")
            continue
        rows = json.load(open(path))
        ok = [r for r in rows if r["status"] == "ok"]
        for r in ok:
            ro = r["roofline"]
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                 ro["compute_s"] * 1e6,
                 f"dominant={ro['dominant']};compute_ms={ro['compute_s']*1e3:.2f};"
                 f"memory_ms={ro['memory_s']*1e3:.2f};"
                 f"collective_ms={ro['collective_s']*1e3:.2f};"
                 f"useful_flop_ratio={ro['useful_flops_ratio']:.2f};"
                 f"gb_per_device={r['memory']['peak_per_device_gb']:.2f}")
        nskip = sum(1 for r in rows if r["status"] == "skip")
        nerr = sum(1 for r in rows if r["status"] == "error")
        emit(f"roofline/{mesh}/summary", 0.0,
             f"ok={len(ok)};skip={nskip};error={nerr}")


if __name__ == "__main__":
    run()
