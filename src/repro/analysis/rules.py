"""The lint rules (DESIGN.md §11): pure functions over compiled/traced
artifacts — HLO text, jaxprs, alias tables, jit cache sizes, pytree
snapshots.  No rule builds or runs jax programs; ``repro.analysis.rigs``
produces the artifacts, tests and the ``repro.launch.lint`` CLI feed
them here, so every perf contract has exactly ONE proof implementation.

Each function returns a ``RuleResult`` (pass / fail+findings / skip).
"""

from __future__ import annotations

from collections import Counter
from typing import List

from repro.analysis.report import RuleResult, result
from repro.roofline.analysis import iter_collective_instrs

# Collectives above this output size are "wire" traffic charged against
# the bucket budget; at or below it they are scalar control traffic (the
# loss pmean, the finite-flag pmin under loss scaling) which every
# production step is allowed a small number of.
SCALAR_BYTES_OK = 64
SCALAR_COUNT_OK = 4


def _split_wire_scalar(hlo_text: str, scalar_bytes_ok: int):
    instrs = list(iter_collective_instrs(hlo_text))
    wire = [i for i in instrs if i["bytes"] > scalar_bytes_ok]
    scalar = [i for i in instrs if i["bytes"] <= scalar_bytes_ok]
    return wire, scalar


# ---------------------------------------------------------------------------
# collective-budget — ≤ n_buckets collectives per exchange, per op type
# ---------------------------------------------------------------------------
def collective_budget(hlo_text: str, contract: dict,
                      scalar_bytes_ok: int = SCALAR_BYTES_OK,
                      scalar_count_ok: int = SCALAR_COUNT_OK,
                      require_wire: bool = True) -> RuleResult:
    """Lint compiled HLO against a ``Fabric.collective_contract``:
    every wire-sized collective op must stay within its per-op budget,
    ops absent from the contract must not appear at all, and scalar
    control traffic stays under a small count allowance.

    ``require_wire``: a non-empty contract must produce at least one
    wire collective — an exchange optimised away entirely is as much a
    contract violation as an extra all-reduce."""
    wire, scalar = _split_wire_scalar(hlo_text, scalar_bytes_ok)
    counts = Counter(i["op"] for i in wire)
    findings: List[str] = []
    for op, n in sorted(counts.items()):
        cap = int(contract.get(op, 0))
        if n > cap:
            findings.append(
                f"{op}: {n} wire instruction(s) exceed budget {cap}")
    if require_wire and contract and not wire:
        findings.append(
            "no wire collective compiled for a non-empty contract "
            f"{contract}")
    if len(scalar) > scalar_count_ok:
        findings.append(
            f"{len(scalar)} scalar collectives exceed allowance "
            f"{scalar_count_ok}")
    return result("collective-budget", findings,
                  {"counts": dict(counts), "scalar": len(scalar),
                   "contract": {k: int(v) for k, v in contract.items()}})


# ---------------------------------------------------------------------------
# tp-collective-budget — explicit TP combines stay within the "tp" contract
# ---------------------------------------------------------------------------
def tp_collective_budget(hlo_text: str, contract: dict, tp_degree: int,
                         scalar_bytes_ok: int = SCALAR_BYTES_OK,
                         scalar_count_ok: int = SCALAR_COUNT_OK) -> RuleResult:
    """Lint a TP-rank program against ``collective_contract(..., "tp")``:
    the tensor-parallel activation combines of models/tensor_parallel.py
    must lower to at most the budgeted all-reduce count (2 per layer ×
    fwd+bwd, + the replicated-grad finalize), no other collective type
    may appear, and at least one combine must survive compilation — a TP
    model whose combines were optimised away computes garbage silently.
    ``tp_degree <= 1`` skips: there is nothing to combine."""
    if tp_degree <= 1:
        return result("tp-collective-budget", [],
                      skip="tp_degree=1: no tensor-parallel combines")
    wire, scalar = _split_wire_scalar(hlo_text, scalar_bytes_ok)
    counts = Counter(i["op"] for i in wire)
    findings: List[str] = []
    for op, n in sorted(counts.items()):
        cap = int(contract.get(op, 0))
        if n > cap:
            findings.append(
                f"{op}: {n} wire instruction(s) exceed tp budget {cap}")
    if contract and not wire:
        findings.append("no wire collective compiled for a non-empty tp "
                        f"contract {contract}")
    if len(scalar) > scalar_count_ok:
        findings.append(
            f"{len(scalar)} scalar collectives exceed allowance "
            f"{scalar_count_ok}")
    return result("tp-collective-budget", findings,
                  {"counts": dict(counts), "scalar": len(scalar),
                   "tp_degree": int(tp_degree),
                   "contract": {k: int(v) for k, v in contract.items()}})


# ---------------------------------------------------------------------------
# promotion-proof — no f32 payload on the wire when wire_dtype is narrow
# ---------------------------------------------------------------------------
def promotion_proof(hlo_text: str, narrow_wire: bool,
                    scalar_bytes_ok: int = SCALAR_BYTES_OK) -> RuleResult:
    """XLA convert-promotes narrow collectives back to f32 unless the op
    is expressed in promotion-proof form (all-to-all decomposition,
    bitcast-u16 gathers — core/fabric.py).  Under a narrow wire no
    collective above the scalar allowance may carry an f32/f64 payload."""
    if not narrow_wire:
        return result("promotion-proof", [],
                      skip="f32 wire: nothing to promote")
    wire, _ = _split_wire_scalar(hlo_text, scalar_bytes_ok)
    # Tuple-shaped instrs are exempt: XLA:CPU materializes a narrow
    # all-to-all as a tuple-of-f32 instruction even when the StableHLO
    # carries bf16 (per-peer buffers, backend-internal widening) — same
    # semantics as the repo's `=\s*f32\[` non-tuple wire checks.
    findings = [
        f"{i['op']}: f32 payload ({i['bytes']} B) on a narrow wire"
        for i in wire
        if not i.get("tuple")
        and any(dt in ("f32", "f64") for dt in i["dtypes"])]
    return result("promotion-proof", findings,
                  {"wire_instrs": len(wire)})


# ---------------------------------------------------------------------------
# donation-aliasing — donated train state aliases input↔output buffers
# ---------------------------------------------------------------------------
def donation_aliasing(alias_bytes: int, donated_bytes: int,
                      min_frac: float = 0.5) -> RuleResult:
    """``alias_bytes`` from ``compiled.memory_analysis()`` must cover at
    least ``min_frac`` of the donated train-state bytes — donation that
    silently fails to alias doubles peak memory without any error."""
    findings: List[str] = []
    frac = alias_bytes / max(1, donated_bytes)
    if alias_bytes <= 0:
        findings.append("no input/output aliasing in the compiled module "
                        "(donation had no effect)")
    elif frac < min_frac:
        findings.append(
            f"aliased {alias_bytes} of {donated_bytes} donated bytes "
            f"({frac:.1%} < {min_frac:.0%})")
    return result("donation-aliasing", findings,
                  {"alias_bytes": int(alias_bytes),
                   "donated_bytes": int(donated_bytes),
                   "frac": round(frac, 4)})


# ---------------------------------------------------------------------------
# cond-gating — gated strategies keep collectives under lax.cond branches
# ---------------------------------------------------------------------------
# jaxpr-level primitives that lower to collectives
COLLECTIVE_PRIMS = frozenset((
    "psum", "pmin", "pmax", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "psum_scatter", "reduce_scatter",
))


def _subjaxprs(v):
    if hasattr(v, "eqns") or hasattr(v, "jaxpr"):  # Jaxpr / ClosedJaxpr
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _subjaxprs(x)


def iter_jaxpr_collectives(jaxpr, _in_cond: bool = False):
    """Yield ``(primitive_name, under_cond)`` for every collective
    primitive reachable from ``jaxpr`` (walks scan/while/pjit/shard_map
    bodies; ``under_cond`` is True once any enclosing eqn is a cond)."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jx.eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            yield name, _in_cond
        sub_in_cond = _in_cond or name == "cond"
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_jaxpr_collectives(sub, sub_in_cond)


def cond_gating(jaxpr, gated: bool) -> RuleResult:
    """A ``gated=True`` strategy traced with a *traced* step counter must
    keep every collective primitive inside a ``lax.cond`` branch — a
    ``jnp.where``-style gate ships the bytes every step and discards
    them, silently multiplying wire traffic by sync_every."""
    if not gated:
        return result("cond-gating", [],
                      skip="strategy communicates unconditionally")
    hits = list(iter_jaxpr_collectives(jaxpr))
    findings = [f"collective {name!r} outside any lax.cond branch"
                for name, under in hits if not under]
    if not hits:
        findings.append("no collective found at all — the gated exchange "
                        "was traced away")
    return result("cond-gating", findings,
                  {"collectives": len(hits),
                   "under_cond": sum(1 for _, u in hits if u)})


def elastic_demotion_gated(jaxpr) -> RuleResult:
    """The straggler-demotion resync (launch/elastic.py::demoted_resync)
    traced with a *traced* boundary counter must keep its consensus
    collective inside a ``lax.cond`` branch.  Demotion exists to REDUCE a
    straggler's wire cost — a ``jnp.where``-style resync would ship the
    pull every boundary and silently restore the full sync traffic for
    the whole fleet.  Runs on ``rigs.elastic_artifacts`` (the ShardComm
    trace of the resync path alone), not the per-cell sweep matrix."""
    hits = list(iter_jaxpr_collectives(jaxpr))
    findings = [f"collective {name!r} outside any lax.cond branch"
                for name, under in hits if not under]
    if not hits:
        findings.append("no collective found at all — the gated resync "
                        "was traced away")
    return result("elastic-demotion-gated", findings,
                  {"collectives": len(hits),
                   "under_cond": sum(1 for _, u in hits if u)})


def gating_ratio(bytes_ungated: float, bytes_gated: float,
                 sync_every: int, slack: float = 0.75) -> RuleResult:
    """Wire-byte side of the gating contract: summed over sync_every
    consecutive steps, a gated schedule must ship ≤ 1/(slack·sync_every)
    of the every-step bytes (slack absorbs per-sync constant traffic)."""
    findings: List[str] = []
    if bytes_ungated <= 0:
        findings.append("ungated baseline shipped zero bytes")
    else:
        ratio = bytes_ungated / max(1.0, bytes_gated)
        if ratio < slack * sync_every:
            findings.append(
                f"gated bytes only {ratio:.2f}x below every-step bytes "
                f"(expected ≥ {slack * sync_every:.2f}x for "
                f"sync_every={sync_every})")
    return result("cond-gating", findings,
                  {"bytes_ungated": float(bytes_ungated),
                   "bytes_gated": float(bytes_gated),
                   "sync_every": sync_every})


# ---------------------------------------------------------------------------
# fused-dispatch — compressed exchanges go through the Pallas kernels
# ---------------------------------------------------------------------------
def fused_dispatch(jaxpr_text: str, codec_calls: int,
                   expect_fused: bool = True) -> RuleResult:
    """On a ``Fabric(fused=True)`` compressed path the traced program
    must contain ``pallas_call`` (the fused encode+error-feedback
    kernel) and must never have invoked the jnp pack/codec fallback."""
    if not expect_fused:
        return result("fused-dispatch", [], skip="fused dispatch disabled")
    findings: List[str] = []
    if "pallas_call" not in jaxpr_text:
        findings.append("no pallas_call in the traced exchange "
                        "(fused kernel not dispatched)")
    if codec_calls:
        findings.append(f"jnp codec invoked {codec_calls} time(s) on the "
                        "fused path")
    return result("fused-dispatch", findings, {"codec_calls": codec_calls})


# ---------------------------------------------------------------------------
# retrace-detector — zero jit cache misses after step 0
# ---------------------------------------------------------------------------
def retrace(cache_sizes: List[int]) -> RuleResult:
    """``cache_sizes[i]`` is the step fn's jit cache size after call i of
    a steady-state run: it must be exactly 1 throughout — every growth
    is a silent recompilation in the training loop."""
    findings: List[str] = []
    if not cache_sizes:
        findings.append("no steps recorded")
    else:
        if cache_sizes[0] != 1:
            findings.append(
                f"cache size {cache_sizes[0]} after first step (≠ 1)")
        for i, n in enumerate(cache_sizes[1:], start=1):
            if n != cache_sizes[0]:
                findings.append(f"retrace at step {i}: cache grew "
                                f"{cache_sizes[0]} → {n}")
                break
    return result("retrace-detector", findings,
                  {"cache_sizes": list(cache_sizes)})


# ---------------------------------------------------------------------------
# state-aliasing — strategy.update must not mutate its comm_state arg
# ---------------------------------------------------------------------------
def tree_snapshot(tree):
    """Structural identity snapshot of a pytree-ish value: container ids
    + keys + leaf object ids.  Taken before/after a call, a diff proves
    in-place mutation of the argument (the comm_state aliasing bug class
    fixed in PR 2: update wrote into the caller's dict, corrupting saved
    state that resume/re-step paths rely on)."""
    if isinstance(tree, dict):
        return ("dict", id(tree),
                tuple(sorted((k, tree_snapshot(v)) for k, v in tree.items())))
    if isinstance(tree, (list, tuple)):
        return (type(tree).__name__, id(tree),
                tuple(tree_snapshot(v) for v in tree))
    return ("leaf", id(tree))


def _diff(before, after, path: str, out: List[str]):
    if before[0] != after[0]:
        out.append(f"{path or '<root>'}: container type changed "
                   f"{before[0]} → {after[0]}")
        return
    if before[0] == "leaf":
        if before[1] != after[1]:
            out.append(f"{path or '<root>'}: leaf object replaced in place")
        return
    if before[1] != after[1]:
        out.append(f"{path or '<root>'}: container object replaced")
        return
    if before[0] == "dict":
        bk = {k: v for k, v in before[2]}
        ak = {k: v for k, v in after[2]}
        for k in sorted(set(bk) | set(ak)):
            if k not in ak:
                out.append(f"{path}[{k!r}]: key deleted from the argument")
            elif k not in bk:
                out.append(f"{path}[{k!r}]: key inserted into the argument")
            else:
                _diff(bk[k], ak[k], f"{path}[{k!r}]", out)
    else:
        for i, (b, a) in enumerate(zip(before[2], after[2])):
            _diff(b, a, f"{path}[{i}]", out)


def state_aliasing(snap_before, snap_after) -> RuleResult:
    findings: List[str] = []
    _diff(snap_before, snap_after, "comm_state", findings)
    return result("state-aliasing", findings)
