"""Report schema for the static-analysis linter (DESIGN.md §11).

One ``RuleResult`` per (rule, matrix cell), one ``Report`` per sweep.
``LINT.json`` is the committed artifact — validated in CI exactly like
the bench tiers (validate → smoke rerun → re-validate): a missing file,
a malformed record, or any ``fail`` status turns the job red.

Statuses:

  pass  the compiled/traced artifact satisfies the contract
  fail  a violation — ``findings`` carries one message per offence
  skip  the rule does not apply to this cell (e.g. promotion-proof on an
        f32 wire); never counts against the sweep
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import List, Optional

# every lint-matrix CELL carries exactly these rules (the committed
# LINT.json sweep runs them per config × strategy × precision × accum)
CELL_RULES = (
    "collective-budget",
    "tp-collective-budget",
    "promotion-proof",
    "donation-aliasing",
    "cond-gating",
    "fused-dispatch",
    "retrace-detector",
    "state-aliasing",
)

# the full rule vocabulary: CELL_RULES plus rules proven once on their
# own rig rather than per cell (elastic-demotion-gated runs on the
# elastic resync trace — rigs.elastic_artifacts — not the sweep matrix)
RULES = CELL_RULES + (
    "elastic-demotion-gated",
)


def _schema_helpers():
    """The shared artifact-validator vocabulary (benchmarks/common.py).
    ``benchmarks`` is a repo-root package while this module lives under
    src/, so direct import only works with the repo root on sys.path (the
    lint CLI's cwd); fall back to an explicit path for other callers."""
    try:
        from benchmarks import common
    except ImportError:
        import pathlib
        import sys
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[3]))
        from benchmarks import common
    return common

STATUSES = ("pass", "fail", "skip")


@dataclass
class RuleResult:
    rule: str
    status: str
    findings: List[str] = field(default_factory=list)
    details: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown rule {self.rule!r}")
        if self.status not in STATUSES:
            raise ValueError(f"unknown status {self.status!r}")
        if self.status == "fail" and not self.findings:
            raise ValueError(f"{self.rule}: fail with no findings")

    def to_json(self) -> dict:
        return {"rule": self.rule, "status": self.status,
                "findings": list(self.findings), "details": self.details}


def result(rule: str, findings: List[str], details: Optional[dict] = None,
           skip: Optional[str] = None) -> RuleResult:
    """Build a RuleResult: ``skip`` (a reason string) wins, else the
    presence of findings decides pass/fail."""
    if skip is not None:
        return RuleResult(rule, "skip", [], {"reason": skip,
                                             **(details or {})})
    return RuleResult(rule, "fail" if findings else "pass",
                      findings, details or {})


@dataclass
class Cell:
    config: str
    strategy: str
    precision: str
    accum: int
    rules: List[RuleResult]

    def to_json(self) -> dict:
        return {"config": self.config, "strategy": self.strategy,
                "precision": self.precision, "accum": self.accum,
                "rules": [r.to_json() for r in self.rules]}


def build_report(cells: List[Cell], meta: dict) -> dict:
    counts = {"pass": 0, "fail": 0, "skip": 0}
    for c in cells:
        for r in c.rules:
            counts[r.status] += 1
    return {
        "meta": {"schema": 1, **meta},
        "cells": [c.to_json() for c in cells],
        "summary": {"cells": len(cells), **counts,
                    "violations": counts["fail"]},
    }


def violations(report: dict) -> List[str]:
    """Flat '<config>/<strategy>/<precision>/accum<k>: <rule>: <msg>'
    lines for every failing rule in the report."""
    out = []
    for c in report.get("cells", []):
        tag = (f"{c['config']}/{c['strategy']}/{c['precision']}"
               f"/accum{c['accum']}")
        for r in c["rules"]:
            if r["status"] == "fail":
                for f in r["findings"] or ["(no message)"]:
                    out.append(f"{tag}: {r['rule']}: {f}")
    return out


def validate(report: dict, path: str = "LINT.json") -> dict:
    """Schema + acceptance check; raises ValueError on any problem.

    Acceptance (all files, smoke or full): zero ``fail`` statuses — the
    lint contracts must hold on whatever slice was swept."""
    C = _schema_helpers()
    C.require_sections(report, ("meta", "cells", "summary"), path)
    meta = report["meta"]
    C.check(meta.get("schema") == 1,
            f"{path}: unsupported schema {meta.get('schema')}")
    C.require_keys(meta, ("backend", "jax", "smoke", "workers"),
                   f"{path}: meta")
    cells = report["cells"]
    C.check(cells, f"{path}: empty cell list")
    seen = set()
    for c in cells:
        C.require_keys(c, ("config", "strategy", "precision", "accum",
                           "rules"), f"{path}: cell")
        tag = (c["config"], c["strategy"], c["precision"], c["accum"])
        C.check(tag not in seen, f"{path}: duplicate cell {tag}")
        seen.add(tag)
        C.check(c["rules"], f"{path}: cell {tag} has no rule results")
        names = [r.get("rule") for r in c["rules"]]
        for r in c["rules"]:
            C.check(r.get("rule") in RULES,
                    f"{path}: unknown rule {r.get('rule')!r}")
            C.check(r.get("status") in STATUSES,
                    f"{path}: bad status {r.get('status')!r} in {tag}")
        missing = set(CELL_RULES) - set(names)
        C.check(not missing,
                f"{path}: cell {tag} missing rules {sorted(missing)}")
    bad = violations(report)
    C.check(not bad, f"{path}: {len(bad)} rule violation(s); first: "
                     + (bad[0] if bad else ""))
    summ = report["summary"]
    C.check(summ.get("cells") == len(cells),
            f"{path}: summary cell count mismatch")
    return report


def validate_file(path: str) -> dict:
    try:
        with open(path) as f:
            report = json.load(f)
    except FileNotFoundError:
        raise ValueError(f"{path}: missing — run "
                         f"`python -m repro.launch.lint --all` and commit "
                         f"the artifact") from None
    except json.JSONDecodeError as e:
        raise ValueError(f"{path}: not valid JSON ({e})") from None
    return validate(report, path)
