"""Matrix sweep: evaluate every lint rule for every
(config × strategy × precision × accum) cell and assemble the Report.

Artifact reuse is deliberate and recorded in each rule's details:

  * exchange artifacts are per (config, strategy, precision) — the
    boundary exchange is by construction identical at every
    ``accum_steps`` (the loop calls ``strategy.update`` exactly once
    per boundary; tests/test_accum.py proves it on the production
    step), so accum cells lint the same compiled exchange.
  * loop artifacts (donation, retrace) and eager artifacts
    (state-aliasing, fused-dispatch codec counting) prove contracts of
    train/loop.py and the strategy code that do not depend on the
    model, so they are shared across configs.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.analysis import rigs, rules
from repro.analysis.report import Cell, RuleResult, build_report, result
from repro.configs.base import get_config, list_configs
from repro.core import strategies as ST

# the 10 registered archs + the sliding-window long-context variant
# (launch/specs.py resolves it for the long_500k shape)
LINT_CONFIGS = tuple(sorted(list_configs())) + ("qwen2.5-14b-swa",)
LINT_STRATEGIES = tuple(sorted(ST.REGISTRY))
LINT_PRECISIONS = ("f32", "bf16")
LINT_ACCUMS = (1, 4)

SMOKE_CONFIGS = ("gemma3-1b", "qwen2-1.5b")


class _Cache(dict):
    def get_or(self, key, build):
        if key not in self:
            self[key] = build()
        return self[key]


def _exchange_rules(cache: _Cache, cfg_name: str, strategy: str,
                    precision: str, accum: int) -> List[RuleResult]:
    pol = rigs.rig_policy(precision)

    def build():
        params = rigs.param_sds(get_config(cfg_name), pol)
        return rigs.exchange_artifacts(params, strategy, precision)

    ex = cache.get_or(("exchange", cfg_name, strategy, precision), build)
    strat = ex["strategy"]
    budget = rules.collective_budget(ex["hlo"], ex["contract"])
    budget.details["n_buckets"] = ex["layout"].n_buckets
    if accum > 1:
        budget.details["accum_note"] = (
            "boundary exchange is accum-invariant: the loop calls "
            "strategy.update once per boundary (tests/test_accum.py)")
    promo = rules.promotion_proof(ex["hlo"], ex["narrow_wire"])
    gating = rules.cond_gating(ex["jaxpr"], strat.gated)
    if strat.gated:
        gating.details["sync_every"] = strat.sync_every
    return [budget, promo, gating]


def _fused_rule(cache: _Cache, cfg_name: str, strategy: str,
                precision: str) -> RuleResult:
    # only compressed wire profiles dispatch the fused codec kernels
    if strategy != "sync_dgc":
        return result("fused-dispatch", [],
                      skip="uncompressed wire (no codec on this path)")

    def build():
        pol = rigs.rig_policy(precision)
        params = rigs.param_sds(get_config(cfg_name), pol)
        return rigs.fused_artifacts(params, precision)

    art = cache.get_or(("fused", cfg_name, precision), build)
    return rules.fused_dispatch(art["jaxpr_text"], art["codec_calls"])


def _tp_rule(cache: _Cache, precision: str) -> RuleResult:
    art = cache.get_or(("tp", precision),
                       lambda: rigs.tp_artifacts(precision))
    rr = rules.tp_collective_budget(art["hlo"], art["contract"],
                                    art["tp_degree"])
    rr.details["shared_rig"] = "per precision (model-level contract)"
    return rr


def _loop_rules(cache: _Cache, strategy: str, precision: str,
                accum: int) -> List[RuleResult]:
    art = cache.get_or(
        ("loop", strategy, precision, accum),
        lambda: rigs.loop_artifacts(strategy, precision, accum))
    donation = rules.donation_aliasing(art["alias_bytes"],
                                       art["donated_bytes"])
    donation.details["shared_rig"] = "per (strategy, precision, accum)"
    retrace = rules.retrace(art["cache_sizes"])
    return [donation, retrace]


def _state_rule(cache: _Cache, strategy: str, precision: str) -> RuleResult:
    art = cache.get_or(
        ("state", strategy, precision),
        lambda: rigs.state_aliasing_artifacts(strategy, precision))
    findings: List[str] = []
    for before, after in art["snapshots"]:
        findings.extend(rules.state_aliasing(before, after).findings)
    return result("state-aliasing", findings,
                  {"update_calls": len(art["snapshots"])})


def evaluate_cell(cache: _Cache, cfg_name: str, strategy: str,
                  precision: str, accum: int) -> Cell:
    rr = _exchange_rules(cache, cfg_name, strategy, precision, accum)
    rr.append(_tp_rule(cache, precision))
    rr.append(_fused_rule(cache, cfg_name, strategy, precision))
    rr.extend(_loop_rules(cache, strategy, precision, accum))
    rr.append(_state_rule(cache, strategy, precision))
    return Cell(cfg_name, strategy, precision, accum, rr)


def sweep(configs: Optional[Tuple[str, ...]] = None,
          strategies: Tuple[str, ...] = LINT_STRATEGIES,
          precisions: Tuple[str, ...] = LINT_PRECISIONS,
          accums: Tuple[int, ...] = LINT_ACCUMS,
          smoke: bool = False,
          progress=None) -> Tuple[List[Cell], Dict]:
    """Evaluate the matrix; returns (cells, rig-cache stats)."""
    if configs is None:
        configs = SMOKE_CONFIGS if smoke else LINT_CONFIGS
    cache = _Cache()
    cells: List[Cell] = []
    for cfg_name in configs:
        for strategy in strategies:
            for precision in precisions:
                for accum in accums:
                    cells.append(evaluate_cell(cache, cfg_name, strategy,
                                               precision, accum))
                    if progress is not None:
                        progress(cells[-1])
    return cells, {"rigs_built": len(cache)}


def run(configs: Optional[Tuple[str, ...]] = None, smoke: bool = False,
        progress=None) -> dict:
    import jax

    cells, stats = sweep(configs=configs, smoke=smoke, progress=progress)
    meta = {
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "smoke": bool(smoke),
        "workers": rigs.WORKERS,
        "configs": sorted({c.config for c in cells}),
        "strategies": list(LINT_STRATEGIES),
        "precisions": list(LINT_PRECISIONS),
        "accums": list(LINT_ACCUMS),
        **stats,
    }
    return build_report(cells, meta)
