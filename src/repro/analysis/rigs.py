"""Rig builders: the small jax programs whose traced/compiled artifacts
the lint rules (repro.analysis.rules) check.

Three cost tiers, matched to what each contract actually depends on:

  * **exchange rigs** — shard_map of ``strategy.update`` over a
    ``ShardComm`` with the config's (reduced-scale) parameter tree:
    per (config × strategy × precision).  These need
    ``--xla_force_host_platform_device_count`` ≥ ``workers``; the lint
    CLI (launch/lint.py) and the subprocess tests set it before
    importing jax.
  * **loop rigs** (donation / retrace) and **eager rigs**
    (state-aliasing, fused-dispatch) — LocalComm stacked-replica
    programs on a tiny synthetic problem: the contracts they prove live
    in the train-step machinery and the strategy code, not the model,
    so they are evaluated once per (strategy × precision × accum) and
    shared across configs.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import rules
from repro.core import compression as C
from repro.core import strategies as ST
from repro.core.comm import LocalComm, ShardComm
from repro.core.fabric import BucketLayout, Fabric
from repro.core.jax_compat import make_mesh, set_mesh, shard_map
from repro.core.precision import PrecisionPolicy, cast_floats, get_policy
from repro.optim import sgd
from repro.train.loop import (
    init_train_state,
    jit_cache_size,
    make_replica_train_step,
    zero1_opt_template,
    zero3_param_template,
)

WORKERS = 4  # mesh/replica width of every rig


def rig_policy(precision: str) -> Optional[PrecisionPolicy]:
    """'f32' rides the policy-less production path (the f32 policy is a
    proven bitwise no-op, and passing None matches how launch/specs
    builds the step)."""
    pol = get_policy(precision)
    return None if pol.is_noop else pol


def build_strategy(name: str, policy: Optional[PrecisionPolicy],
                   bucket_bytes: int) -> ST.Strategy:
    kw = dict(bucket_bytes=bucket_bytes, policy=policy)
    if name == "sync_dgc":
        kw["compressor"] = C.get_compressor("topk", ratio=0.25)
    return ST.get_strategy(name, **kw)


def param_sds(cfg, policy: Optional[PrecisionPolicy]):
    """Reduced-scale parameter ShapeDtypeStructs for a config, float
    leaves at the policy's param dtype (what the production sharded step
    hands the strategy)."""
    from repro.launch.specs import model_sds

    sds = model_sds(cfg.reduced() if hasattr(cfg, "reduced") else cfg)
    if policy is None:
        return sds
    dt = policy.param_dt

    def cast(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, dt)
        return s

    return jax.tree.map(cast, sds)


def pick_bucket_bytes(tree, target_buckets: int = 6) -> int:
    """Bucket size giving a handful of buckets at rig scale, so the
    ≤ n_buckets budgets are exercised with n_buckets > 1 while the HLO
    stays small."""
    total = sum(math.prod(s.shape) for s in jax.tree.leaves(tree))
    return max(4 * 2000, 4 * -(-total // target_buckets))


# ---------------------------------------------------------------------------
# exchange rig — compiled HLO + jaxpr of one strategy.update on a mesh
# ---------------------------------------------------------------------------
def exchange_artifacts(params, strategy_name: str, precision: str,
                       workers: int = WORKERS,
                       bucket_bytes: Optional[int] = None) -> dict:
    """Lower ``strategy.update`` (traced step counter, so schedule gates
    become lax.cond) under shard_map over a ``workers``-wide 'pod' axis.

    Returns the artifacts every HLO/jaxpr rule consumes:
    ``hlo`` text, ``jaxpr``, the bucket ``layout``, the fabric
    ``contract`` for the strategy's declared wire profile, and the
    ``strategy`` itself."""
    pol = rig_policy(precision)
    if bucket_bytes is None:
        bucket_bytes = pick_bucket_bytes(params)
    strat = build_strategy(strategy_name, pol, bucket_bytes)
    owns_params = getattr(strat, "owns_params", False)
    comm = ShardComm("pod", workers)
    mesh = make_mesh((workers,), ("pod",))
    opt = sgd(0.1)
    rep = jax.tree.map(lambda _: P(), params)
    if owns_params:
        # ZeRO-3: the train state's params are flat shard buckets (the
        # production zero3_param_template shapes), sharded over the pod
        # axis; the dense tree only appears as the gradient input.
        p_state = zero3_param_template(params, workers, bucket_bytes)
        p_spec = jax.tree.map(lambda _: P("pod"), p_state)
    else:
        p_state, p_spec = params, rep
    if strat.init_opt is not None:
        # stage-3 f32 param shards double as the master: no policy split
        opt_state = zero1_opt_template(params, opt, workers, bucket_bytes,
                                       policy=None if owns_params else pol)
        opt_spec = jax.tree.map(lambda _: P("pod"), opt_state)
    else:
        opt_state = jax.eval_shape(opt.init, params)
        opt_spec = jax.tree.map(lambda _: P(), opt_state)
    cstate = jax.eval_shape(lambda p: strat.init(p, comm), params)
    t_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def body(p, g, s, c, t):
        p2, s2, c2, _ = strat.update(p, g, s, c, t, opt, comm)
        return p2, s2, c2

    crep = jax.tree.map(lambda _: P(), cstate)
    fn = shard_map(body, mesh=mesh, axis_names={"pod"},
                   in_specs=(p_spec, rep, opt_spec, crep, P()),
                   out_specs=(p_spec, opt_spec, crep),
                   check_vma=False)
    args = (p_state, params, opt_state, cstate, t_sds)
    jaxpr = jax.make_jaxpr(fn)(*args)
    with set_mesh(mesh):
        hlo = jax.jit(fn).lower(*args).compile().as_text()
    fab = Fabric(comm, bucket_bytes,
                 wire_dtype=pol.wire_dt if pol is not None else None)
    lay = BucketLayout.build(params, bucket_bytes, lead_axes=0)
    contract = fab.collective_contract(lay, strat.wire_profile,
                                       events=strat.wire_events)
    return {"hlo": hlo, "jaxpr": jaxpr, "layout": lay,
            "contract": contract, "strategy": strat,
            "narrow_wire": pol is not None and pol.narrow_wire,
            "bucket_bytes": bucket_bytes}


# ---------------------------------------------------------------------------
# loop rig — donation aliasing + retrace on the replica train step
# ---------------------------------------------------------------------------
def _tiny_problem(workers: int, accum: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    kw, kx, ky = jax.random.split(key, 3)
    params = {"w": jax.random.normal(kw, (8, 16), jnp.float32),
              "b": jnp.zeros((16,), jnp.float32)}
    lead = (accum, workers) if accum > 1 else (workers,)
    batch = {"x": jax.random.normal(kx, lead + (4, 8), jnp.float32),
             "y": jax.random.normal(ky, lead + (4, 16), jnp.float32)}

    def loss_fn(p, b):
        pred = b["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - b["y"]) ** 2)

    return params, batch, loss_fn


def _state_nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def loop_artifacts(strategy_name: str, precision: str, accum: int,
                   workers: int = WORKERS, steps: int = 3) -> dict:
    """Build the production replica train step (jitted, donated) on a
    tiny synthetic problem; compile it for the donation proof, then run
    ``steps`` boundaries for the retrace proof.

    These contracts live in train/loop.py + the strategy, not the model,
    so one evaluation covers every config."""
    pol = rig_policy(precision)
    comm = LocalComm(workers)
    opt = sgd(0.05)
    base, batch, loss_fn = _tiny_problem(workers, accum)
    params = comm.replicate(base)
    if pol is not None:
        params = cast_floats(params, pol.param_dt)
    strat = build_strategy(strategy_name, pol, bucket_bytes=4 * 256)
    state = init_train_state(params, opt, strat, comm, policy=pol)
    step = make_replica_train_step(loss_fn, opt, strat, comm, policy=pol,
                                   accum_steps=accum,
                                   bucket_bytes=4 * 256)
    donated_bytes = _state_nbytes(state)
    compiled = step.lower(state, batch).compile()
    mem = compiled.memory_analysis()
    alias_bytes = int(getattr(mem, "alias_size_in_bytes", 0) or 0)
    cache_sizes = []
    for _ in range(steps):
        state, _ = step(state, batch)
        cache_sizes.append(jit_cache_size(step))
    return {"alias_bytes": alias_bytes, "donated_bytes": donated_bytes,
            "cache_sizes": cache_sizes,
            "hlo": compiled.as_text()}


# ---------------------------------------------------------------------------
# tp rig — tensor-parallel activation combines on a 'model' mesh
# ---------------------------------------------------------------------------
TP_DEGREE = 2


def tp_artifacts(precision: str, tp_degree: int = TP_DEGREE) -> dict:
    """Lower one TP training step (forward + backward + replicated-grad
    finalize) of a tiny ``tp_degree``-split transformer under shard_map
    over a 'model' mesh.  The TP combine contract lives entirely in
    models/layers.py + models/tensor_parallel.py — not the config or the
    strategy — so one rig per precision covers every lint cell.

    Returns the compiled ``hlo``, the op->count ``contract`` (activation
    combines from ``tp_collective_contract`` plus the finalize_grads
    bucket budget) and ``tp_degree``."""
    import dataclasses

    from repro.configs.base import get_config
    from repro.models import tensor_parallel as TP
    from repro.models import transformer as T

    pol = rig_policy(precision)
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, tp_degree=tp_degree)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    if pol is not None:
        params = cast_floats(params, pol.param_dt)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    targets = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab_size)
    shards = TP.tp_split_params(params, tp_degree)

    def loss_of(p):
        logits, _ = T.forward(p, cfg, tokens)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, targets[..., None],
                                             axis=-1))

    def rank_step(sh):
        p = jax.tree.map(lambda v: v[0], sh)
        with TP.tp_context(tp_degree):
            loss, grads = jax.value_and_grad(loss_of)(p)
            grads = TP.current_tp().finalize_grads(grads)
        loss = jax.lax.pmean(loss, "model")
        return loss, jax.tree.map(lambda v: v[None], grads)

    mesh = make_mesh((tp_degree,), ("model",))
    spec = jax.tree.map(lambda _: P("model"), shards)
    fn = shard_map(rank_step, mesh=mesh, axis_names={"model"},
                   in_specs=(spec,), out_specs=(P(), spec),
                   check_vma=False)
    with set_mesh(mesh):
        hlo = jax.jit(fn).lower(shards).compile().as_text()
    act = jax.ShapeDtypeStruct(
        (2, 8, cfg.d_model), jnp.float32 if pol is None else pol.param_dt)
    contract = dict(TP.tp_collective_contract(cfg, act))
    # finalize_grads ships the replicated-leaf grads as one bucketed
    # all-sum on the same fabric — extend the combine budget by its
    # bucket count.
    rep, _ = TP._partition_replicated(
        jax.tree.map(lambda v: v[0], shards), "stack")
    fab = Fabric(ShardComm("model", tp_degree))
    contract["all-reduce"] = (contract.get("all-reduce", 0)
                              + fab.layout(rep).n_buckets)
    return {"hlo": hlo, "contract": contract, "tp_degree": tp_degree}


# ---------------------------------------------------------------------------
# eager rig — comm_state mutation detector
# ---------------------------------------------------------------------------
def state_aliasing_artifacts(strategy_name: str, precision: str,
                             workers: int = WORKERS) -> dict:
    """Run ``strategy.update`` eagerly on concrete arrays at several
    schedule phases (t hitting and missing sync boundaries) and snapshot
    the input comm_state around every call — any structural diff is an
    in-place mutation of the caller's tree."""
    pol = rig_policy(precision)
    comm = LocalComm(workers)
    opt = sgd(0.05)
    base, _, _ = _tiny_problem(workers, accum=1)
    params = comm.replicate(base)
    if pol is not None:
        params = cast_floats(params, pol.param_dt)
    strat = build_strategy(strategy_name, pol, bucket_bytes=4 * 256)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    if getattr(strat, "owns_params", False):
        # ZeRO-3 state params are shard buckets; grads stay dense
        params = strat.init_params(params, comm)
    if strat.init_opt is not None:
        opt_state = strat.init_opt(params, opt, comm)
    else:
        opt_state = opt.init(params)
    cstate = strat.init(params, comm)
    snaps = []
    for t in range(max(2, strat.sync_every)):
        before = rules.tree_snapshot(cstate)
        _, opt_state, new_c, _ = strat.update(
            params, grads, opt_state, cstate, t, opt, comm)
        snaps.append((before, rules.tree_snapshot(cstate)))
        cstate = new_c
    return {"snapshots": snaps}


# ---------------------------------------------------------------------------
# eager rig — fused compressed dispatch (pallas_call, no jnp codec)
# ---------------------------------------------------------------------------
def fused_artifacts(params, precision: str, workers: int = WORKERS,
                    bucket_bytes: Optional[int] = None,
                    fused: bool = True) -> dict:
    """Trace the compressed ``Fabric.exchange_dgc`` (the sync_dgc wire)
    on stacked replicas, counting jnp codec entries while tracing: the
    fused path must dispatch ``pallas_call`` and never touch the jnp
    pack/codec fallback."""
    pol = rig_policy(precision)
    if bucket_bytes is None:
        bucket_bytes = pick_bucket_bytes(params)
    comp = C.get_compressor("topk", ratio=0.25)
    fab = Fabric(LocalComm(workers), bucket_bytes,
                 wire_dtype=pol.wire_dt if pol is not None else None,
                 fused=fused)
    calls = {"n": 0}
    orig_fallback = fab._bucket_mean_compressed

    def counting_fallback(target, compressor):
        # the jnp codec dispatch point: the fused path must never enter
        # the per-bucket compress→pack fallback.  (compressor.compress
        # alone is NOT a reliable probe — wire accounting
        # (compression.packed_nbytes) eval_shapes it for metrics without
        # shipping anything.)
        calls["n"] += 1
        return orig_fallback(target, compressor)

    fab._bucket_mean_compressed = counting_fallback
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((workers,) + s.shape, s.dtype),
        params)
    dgc = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
        {"velocity": stacked, "residual": stacked})

    def ex(g, st):
        out, new_st, _ = fab.exchange_dgc(g, st, comp)
        return out, new_st

    jaxpr = jax.make_jaxpr(ex)(stacked, dgc)
    return {"jaxpr_text": str(jaxpr), "codec_calls": calls["n"]}


# ---------------------------------------------------------------------------
# elastic rig: the demoted-tier resync path (DESIGN.md §13)
# ---------------------------------------------------------------------------
def elastic_artifacts(workers: int = WORKERS, resync_every: int = 4) -> dict:
    """Trace ONLY the demoted-tier resync of ``launch/elastic.py`` over a
    ShardComm with a TRACED boundary counter and participation mask — the
    jaxpr the ``elastic-demotion-gated`` rule walks.

    The masked boundary exchange itself is intentionally UNGATED (it
    fires every boundary); the contract is that the resync's consensus
    pull — the only collective a demoted worker's recovery adds — sits
    under ``lax.cond``.  ``make_jaxpr(axis_env=...)`` keeps the rig
    device-free: the rule is jaxpr-level, no mesh compile needed."""
    from repro.launch.elastic import demoted_resync

    comm = ShardComm("pod", workers)
    fab = Fabric(comm, 4 * 64)
    params = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32),
              "b": jax.ShapeDtypeStruct((16,), jnp.float32)}

    def body(p, mask, t):
        out, _ = demoted_resync(fab, p, mask, t, resync_every)
        return out

    jaxpr = jax.make_jaxpr(body, axis_env=[("pod", workers)])(
        params, jax.ShapeDtypeStruct((workers,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32))
    return {"jaxpr": jaxpr, "resync_every": resync_every}
