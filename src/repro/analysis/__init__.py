"""repro.analysis — static analysis over jaxprs and compiled HLO
(DESIGN.md §11).

Every performance/correctness contract the repo has accumulated is a
named lint rule with ONE implementation (repro.analysis.rules), fed by
rig builders (repro.analysis.rigs), swept over the production
config × strategy × precision × accum matrix (repro.analysis.sweep),
reported in a single schema (repro.analysis.report), and driven by
``python -m repro.launch.lint`` whose committed ``LINT.json`` CI
validates like the bench tiers.
"""

from repro.analysis.report import (  # noqa: F401
    CELL_RULES,
    RULES,
    Cell,
    RuleResult,
    build_report,
    result,
    validate,
    validate_file,
    violations,
)
from repro.analysis.rules import (  # noqa: F401
    collective_budget,
    cond_gating,
    donation_aliasing,
    elastic_demotion_gated,
    fused_dispatch,
    gating_ratio,
    iter_jaxpr_collectives,
    promotion_proof,
    retrace,
    state_aliasing,
    tree_snapshot,
)
