from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, momentum, adam, delay_compensated_sgd,
    cosine_schedule, warmup_cosine, constant_schedule,
)
