"""In-house optimizers (no optax dependency).

SGD / momentum / Adam, plus the staleness-aware variant the paper's §3
discussion calls for: delay-compensated SGD (Zheng et al., cited as [41]),
which first-order-corrects a stale gradient toward the current weights.

Shard-aware by construction (ZeRO-1, core/strategies.py::sync_zero1):
every ``init``/``update`` here is a pure elementwise ``jax.tree.map``, so
the same optimizer runs unchanged on the fabric's flat f32 *shard buckets*
(a list of 1/W chunks) — state built from shards IS the partitioned
optimizer state, at 1/W of the dense per-worker footprint.  ``t`` (Adam
bias correction) and the learning-rate schedule are replicated scalars, so
shard updates agree exactly with the dense update on the same elements.

Precision (core/precision.py, DESIGN.md §4): every update runs in f32
against the (possibly wider "master") params it is handed — gradients and
params are upcast, the arithmetic is f32, and only the final result is
cast back to the incoming param dtype.  For f32 params this is the
identical op sequence (bitwise-tested); for bf16 working params the
f32 master shards of the ZeRO-1 path flow through unchanged.
``state_floats`` on each Optimizer records how many f32 state values it
keeps per parameter (roofline memory accounting — a kept master copy adds
``master_floats`` on top, see roofline/analysis.py::opt_state_bytes), and
``state_template`` builds an allocation-free, dtype-exact state skeleton
for checkpoint re-sharding.

``adam(..., fused=True)`` routes the elementwise update chain through the
Pallas kernel in kernels/fused_adam.py (one VMEM pass per tile instead of
10+ HLO ops; ref/interpret fallback on CPU) — parity-tested against the
pure-JAX path in tests/test_kernels.py.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# learning-rate schedules
# ---------------------------------------------------------------------------
def constant_schedule(lr):
    return lambda t: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr, total_steps, final_frac=0.1):
    def f(t):
        frac = jnp.clip(t / max(1, total_steps), 0.0, 1.0)
        c = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr * (final_frac + (1 - final_frac) * c)
    return f


def warmup_cosine(lr, warmup, total_steps, final_frac=0.1):
    cos = cosine_schedule(lr, total_steps - warmup, final_frac)
    def f(t):
        w = jnp.minimum(1.0, (t + 1) / max(1, warmup))
        return jnp.where(t < warmup, lr * w, cos(t - warmup))
    return f


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Optimizer:
    init: Callable  # params -> opt_state
    update: Callable  # (grads, opt_state, params, t) -> (new_params, opt_state)
    state_floats: int = 0  # f32 state values kept per parameter element


def state_template(opt: Optimizer, params):
    """Shape/dtype skeleton of ``opt.init(params)`` with NO allocation.

    Works on ShapeDtypeStruct trees as well as real arrays — builds the
    dry-run state specs (launch/specs.py) and the global ZeRO-1
    shard-state template (train/loop.py::zero1_opt_template) without
    materializing a dense state.  Dtype-aware: the skeleton's dtypes are
    exactly what ``init`` would allocate for the given params."""
    return jax.eval_shape(opt.init, params)


def _as_sched(lr):
    return lr if callable(lr) else constant_schedule(lr)


def _f32(x):
    return x.astype(jnp.float32)


def sgd(lr, weight_decay: float = 0.0) -> Optimizer:
    lr = _as_sched(lr)

    def init(params):
        return {}

    def update(grads, state, params, t):
        step = lr(t)

        def one(p, g):
            return (_f32(p) - step * (_f32(g) + weight_decay * _f32(p))
                    ).astype(p.dtype)

        return jax.tree.map(one, params, grads), state

    return Optimizer(init, update, state_floats=0)


def momentum(lr, beta: float = 0.9, nesterov: bool = False,
             weight_decay: float = 0.0) -> Optimizer:
    lr = _as_sched(lr)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, t):
        step = lr(t)
        m = jax.tree.map(lambda m_, g: beta * m_ + _f32(g),
                         state["m"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m_, g: beta * m_ + _f32(g), m, grads)
        else:
            upd = m

        def one(p, u):
            return (_f32(p) - step * (u + weight_decay * _f32(p))
                    ).astype(p.dtype)

        return jax.tree.map(one, params, upd), {"m": m}

    return Optimizer(init, update, state_floats=1)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, fused: bool = False) -> Optimizer:
    """``fused=True`` runs the (p, m, v) read-modify-write chain through
    the Pallas kernel (kernels/fused_adam.py) leaf-by-leaf on the
    flattened view.  The kernel carries no weight-decay term, so fusion is
    only offered for ``weight_decay=0``."""
    lr = _as_sched(lr)
    if fused and weight_decay:
        raise ValueError("fused adam does not implement weight_decay; "
                         "use fused=False")

    def init(params):
        def z(p):
            return jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, t):
        tt = t.astype(jnp.float32) + 1.0 if hasattr(t, "astype") else float(t) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * _f32(g),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(_f32(g)),
                         state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** tt), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** tt), v)
        step = lr(t)

        def one(p, m_, v_):
            return (_f32(p) - step * (m_ / (jnp.sqrt(v_) + eps)
                                      + weight_decay * _f32(p))
                    ).astype(p.dtype)

        return jax.tree.map(one, params, mh, vh), {"m": m, "v": v}

    def update_fused(grads, state, params, t):
        from repro.kernels import ops

        step = lr(t)
        tt = t.astype(jnp.float32) + 1.0 if hasattr(t, "astype") else float(t) + 1.0
        ps, tdef = jax.tree.flatten(params)
        gs = jax.tree.leaves(grads)
        ms = jax.tree.leaves(state["m"])
        vs = jax.tree.leaves(state["v"])
        new_p, new_m, new_v = [], [], []
        for p, g, m_, v_ in zip(ps, gs, ms, vs):
            p1, m1, v1 = ops.fused_adam(
                p.reshape(-1), _f32(g).reshape(-1), m_.reshape(-1),
                v_.reshape(-1), step, tt, b1=b1, b2=b2, eps=eps)
            new_p.append(p1.reshape(p.shape))
            new_m.append(m1.reshape(m_.shape))
            new_v.append(v1.reshape(v_.shape))
        return (jax.tree.unflatten(tdef, new_p),
                {"m": jax.tree.unflatten(tdef, new_m),
                 "v": jax.tree.unflatten(tdef, new_v)})

    return Optimizer(init, update_fused if fused else update, state_floats=2)


def delay_compensated_sgd(lr, lam: float = 0.04) -> Optimizer:
    """DC-ASGD (Zheng et al. 2016): g̃ = g + λ · g ⊙ g ⊙ (w − w_bak).

    ``w_bak`` is the weight snapshot the gradient was computed against;
    the optimizer state carries it and the *caller* (an async strategy)
    refreshes it via ``state["w_bak"]`` when it ships a gradient.
    """
    lr = _as_sched(lr)

    def init(params):
        return {"w_bak": jax.tree.map(lambda p: p.astype(jnp.float32), params)}

    def update(grads, state, params, t):
        step = lr(t)

        def comp(p, g, wb):
            gf = _f32(g)
            corr = gf + lam * gf * gf * (_f32(p) - wb)
            return (_f32(p) - step * corr).astype(p.dtype)

        new = jax.tree.map(comp, params, grads, state["w_bak"])
        new_bak = jax.tree.map(lambda p: p.astype(jnp.float32), new)
        return new, {"w_bak": new_bak}

    return Optimizer(init, update, state_floats=1)
