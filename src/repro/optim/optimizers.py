"""In-house optimizers (no optax dependency).

SGD / momentum / Adam, plus the staleness-aware variant the paper's §3
discussion calls for: delay-compensated SGD (Zheng et al., cited as [41]),
which first-order-corrects a stale gradient toward the current weights.

Shard-aware by construction (ZeRO-1, core/strategies.py::sync_zero1):
every ``init``/``update`` here is a pure elementwise ``jax.tree.map``, so
the same optimizer runs unchanged on the fabric's flat f32 *shard buckets*
(a list of 1/W chunks) — state built from shards IS the partitioned
optimizer state, at 1/W of the dense per-worker footprint.  ``t`` (Adam
bias correction) and the learning-rate schedule are replicated scalars, so
shard updates agree exactly with the dense update on the same elements.
``state_floats`` on each Optimizer records how many f32 state values it
keeps per parameter (roofline memory accounting), and ``state_template``
builds an allocation-free state skeleton for checkpoint re-sharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# learning-rate schedules
# ---------------------------------------------------------------------------
def constant_schedule(lr):
    return lambda t: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr, total_steps, final_frac=0.1):
    def f(t):
        frac = jnp.clip(t / max(1, total_steps), 0.0, 1.0)
        c = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr * (final_frac + (1 - final_frac) * c)
    return f


def warmup_cosine(lr, warmup, total_steps, final_frac=0.1):
    cos = cosine_schedule(lr, total_steps - warmup, final_frac)
    def f(t):
        w = jnp.minimum(1.0, (t + 1) / max(1, warmup))
        return jnp.where(t < warmup, lr * w, cos(t - warmup))
    return f


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Optimizer:
    init: Callable  # params -> opt_state
    update: Callable  # (grads, opt_state, params, t) -> (new_params, opt_state)
    state_floats: int = 0  # f32 state values kept per parameter element


def state_template(opt: Optimizer, params):
    """Shape/dtype skeleton of ``opt.init(params)`` with NO allocation.

    Works on ShapeDtypeStruct trees as well as real arrays — builds the
    dry-run state specs (launch/specs.py) and the global ZeRO-1
    shard-state template (train/loop.py::zero1_opt_template) without
    materializing a dense state."""
    return jax.eval_shape(opt.init, params)


def _as_sched(lr):
    return lr if callable(lr) else constant_schedule(lr)


def sgd(lr, weight_decay: float = 0.0) -> Optimizer:
    lr = _as_sched(lr)

    def init(params):
        return {}

    def update(grads, state, params, t):
        step = lr(t)
        new = jax.tree.map(
            lambda p, g: p - step * (g + weight_decay * p).astype(p.dtype),
            params, grads)
        return new, state

    return Optimizer(init, update, state_floats=0)


def momentum(lr, beta: float = 0.9, nesterov: bool = False,
             weight_decay: float = 0.0) -> Optimizer:
    lr = _as_sched(lr)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, t):
        step = lr(t)
        m = jax.tree.map(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                               m, grads)
        else:
            upd = m
        new = jax.tree.map(
            lambda p, u: p - step * (u + weight_decay * p).astype(p.dtype),
            params, upd)
        return new, {"m": m}

    return Optimizer(init, update, state_floats=1)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    lr = _as_sched(lr)

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, t):
        tt = t.astype(jnp.float32) + 1.0 if hasattr(t, "astype") else float(t) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** tt), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** tt), v)
        step = lr(t)
        new = jax.tree.map(
            lambda p, m_, v_: p - step * (m_ / (jnp.sqrt(v_) + eps)
                                          + weight_decay * p.astype(jnp.float32)).astype(p.dtype),
            params, mh, vh)
        return new, {"m": m, "v": v}

    return Optimizer(init, update, state_floats=2)


def delay_compensated_sgd(lr, lam: float = 0.04) -> Optimizer:
    """DC-ASGD (Zheng et al. 2016): g̃ = g + λ · g ⊙ g ⊙ (w − w_bak).

    ``w_bak`` is the weight snapshot the gradient was computed against;
    the optimizer state carries it and the *caller* (an async strategy)
    refreshes it via ``state["w_bak"]`` when it ships a gradient.
    """
    lr = _as_sched(lr)

    def init(params):
        return {"w_bak": jax.tree.map(lambda p: p.astype(jnp.float32), params)}

    def update(grads, state, params, t):
        step = lr(t)

        def comp(p, g, wb):
            gf = g.astype(jnp.float32)
            corr = gf + lam * gf * gf * (p.astype(jnp.float32) - wb)
            return p - (step * corr).astype(p.dtype)

        new = jax.tree.map(comp, params, grads, state["w_bak"])
        new_bak = jax.tree.map(lambda p: p.astype(jnp.float32), new)
        return new, {"w_bak": new_bak}

    return Optimizer(init, update, state_floats=1)
