"""Gradient compression with error feedback (paper §2.2.4).

Two families, exactly the two the paper surveys:

  * quantization — 1-bit SGD (Seide et al. [55]): per-block sign + scale,
    with the error-feedback residual that makes it converge; plus an int8
    variant.
  * sparsification — top-k with residual accumulation (Strom [39], Deep
    Gradient Compression [54]), realized as *block-local* top-k which is
    the TPU-friendly form (no global sort; see DESIGN.md §2).

Every compressor is a pair (encode, decode) threaded through an
error-feedback wrapper:   c = encode(g + r);  r ← (g + r) − decode(c).
The communicated object is ``decode(encode(·))`` — strategies communicate
the *decompressed* tensor (wire format is an implementation detail of the
transport; the wire-size accounting lives in ``wire_bytes``).

The hot loops have Pallas TPU kernels in ``repro/kernels`` (onebit_quant,
topk_sparsify); this module dispatches to the pure-jnp reference, which is
numerically identical (kernels are validated against it in tests).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Compressor:
    name: str
    compress: Callable  # (x) -> (wire, meta)  [wire: what's transmitted]
    decompress: Callable  # (wire, meta, shape, dtype) -> x_hat
    wire_bits_per_element: float  # accounting for benchmarks


# ---------------------------------------------------------------------------
# identity
# ---------------------------------------------------------------------------
def none_compressor() -> Compressor:
    return Compressor(
        name="none",
        compress=lambda x: (x, None),
        decompress=lambda w, m, shape, dtype: w,
        wire_bits_per_element=32.0,
    )


# ---------------------------------------------------------------------------
# 1-bit quantization (sign + per-block mean-|x| scale)
# ---------------------------------------------------------------------------
def onebit_compressor(block: int = 256) -> Compressor:
    def compress(x):
        flat = x.reshape(-1).astype(jnp.float32)
        n = flat.shape[0]
        pad = (-n) % block
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, block)
        sign = jnp.where(blocks >= 0, 1.0, -1.0)
        scale = jnp.mean(jnp.abs(blocks), axis=-1, keepdims=True)
        return (sign.astype(jnp.int8), scale), None

    def decompress(wire, meta, shape, dtype):
        sign, scale = wire
        n = 1
        for s in shape:
            n *= s
        flat = (sign.astype(jnp.float32) * scale).reshape(-1)[:n]
        return flat.reshape(shape).astype(dtype)

    # 1 bit per element + one fp32 scale per block
    return Compressor("onebit", compress, decompress,
                      wire_bits_per_element=1.0 + 32.0 / block)


# ---------------------------------------------------------------------------
# int8 linear quantization (per-block max-abs scale)
# ---------------------------------------------------------------------------
def int8_compressor(block: int = 256) -> Compressor:
    def compress(x):
        flat = x.reshape(-1).astype(jnp.float32)
        pad = (-flat.shape[0]) % block
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, block)
        scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)), -127, 127)
        return (q.astype(jnp.int8), scale), None

    def decompress(wire, meta, shape, dtype):
        q, scale = wire
        n = 1
        for s in shape:
            n *= s
        flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
        return flat.reshape(shape).astype(dtype)

    return Compressor("int8", compress, decompress,
                      wire_bits_per_element=8.0 + 32.0 / block)


# ---------------------------------------------------------------------------
# block-local top-k sparsification (DGC-style)
# ---------------------------------------------------------------------------
def topk_compressor(ratio: float = 0.01, block: int = 1024) -> Compressor:
    if block > 1 << 16:
        raise ValueError(  # the packed wire format uses uint16 indices
            f"topk block must be <= 65536 (got {block}); in-block indices "
            "are shipped as uint16 (core/fabric.py)")
    k = max(1, int(round(block * ratio)))

    def compress(x):
        flat = x.reshape(-1).astype(jnp.float32)
        pad = (-flat.shape[0]) % block
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, block)
        vals, idx = jax.lax.top_k(jnp.abs(blocks), k)
        taken = jnp.take_along_axis(blocks, idx, axis=-1)
        return (taken, idx.astype(jnp.int32)), None

    def decompress(wire, meta, shape, dtype):
        taken, idx = wire
        n = 1
        for s in shape:
            n *= s
        nblocks = idx.shape[0]
        blocks = jnp.zeros((nblocks, block), jnp.float32).at[
            jnp.arange(nblocks)[:, None], idx].set(taken)
        return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)

    # k values (32b) + k indices (16b suffices for block≤64k) per block
    return Compressor(f"topk{ratio}", compress, decompress,
                      wire_bits_per_element=ratio * (32.0 + 16.0))


REGISTRY = {
    "none": none_compressor,
    "onebit": onebit_compressor,
    "int8": int8_compressor,
    "topk": topk_compressor,
}


def get_compressor(name: str, **kw) -> Compressor:
    return REGISTRY[name](**kw)


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------
def ef_init(params):
    """Error-feedback residual state (one per communicated leaf)."""
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def ef_compress_tree(comp: Compressor, grads, residual):
    """Apply compressor with error feedback leaf-wise.

    Returns (g_hat, new_residual): ``g_hat`` is what gets communicated
    (already decompressed — see module docstring), residual carries the
    compression error to the next round."""

    def one(g, r):
        target = g.astype(jnp.float32) + r
        wire, meta = comp.compress(target)
        g_hat = comp.decompress(wire, meta, g.shape, jnp.float32)
        return g_hat.astype(g.dtype), target - g_hat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    g_hat = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in out])
    return g_hat, new_r


def wire_bytes(comp: Compressor, tree) -> float:
    """Bytes on the wire to ship ``tree`` once under ``comp``."""
    n = sum(x.size for x in jax.tree.leaves(tree))
    return n * comp.wire_bits_per_element / 8.0


# ---------------------------------------------------------------------------
# Deep Gradient Compression momentum correction (Lin et al. [54], §2.2.4):
# accumulate MOMENTUM (not raw gradients) into the residual before top-k,
# so sparsified-away velocity keeps accumulating instead of being lost.
# ---------------------------------------------------------------------------
def dgc_init(params):
    z = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
    return {"velocity": jax.tree.map(z, params),
            "residual": jax.tree.map(z, params)}


def dgc_compress_tree(comp: Compressor, grads, state, momentum: float = 0.9):
    """Returns (g_hat, new_state): g_hat is the communicated (decompressed)
    sparse velocity; velocity/residual carry what wasn't sent."""

    def one(g, u, r):
        u1 = momentum * u + g.astype(jnp.float32)
        target = r + u1
        wire, meta = comp.compress(target)
        sent = comp.decompress(wire, meta, g.shape, jnp.float32)
        # what was sent leaves both accumulators (DGC eq. 4-5)
        mask = (sent != 0).astype(jnp.float32)
        return sent.astype(g.dtype), u1 * (1 - mask), target - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_u = jax.tree.leaves(state["velocity"])
    flat_r = jax.tree.leaves(state["residual"])
    outs = [one(g, u, r) for g, u, r in zip(flat_g, flat_u, flat_r)]
    g_hat = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_state = {
        "velocity": jax.tree.unflatten(treedef, [o[1] for o in outs]),
        "residual": jax.tree.unflatten(treedef, [o[2] for o in outs]),
    }
    return g_hat, new_state


def pack_signs(sign_int8):
    """True 1-bit wire format: pack 8 int8 signs into one uint8 (the step
    the Pallas kernel leaves to XLA; DESIGN.md §2 table)."""
    bits = (sign_int8 > 0).astype(jnp.uint8).reshape(-1, 8)
    weights = jnp.asarray([1, 2, 4, 8, 16, 32, 64, 128], jnp.uint8)
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def unpack_signs(packed, n):
    weights = jnp.asarray([1, 2, 4, 8, 16, 32, 64, 128], jnp.uint8)
    bits = (packed[:, None] & weights) > 0
    sign = jnp.where(bits.reshape(-1)[:n], 1, -1).astype(jnp.int8)
    return sign
