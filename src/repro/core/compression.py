"""Gradient compression with error feedback (paper §2.2.4).

Two families, exactly the two the paper surveys:

  * quantization — 1-bit SGD (Seide et al. [55]): per-block sign + scale,
    with the error-feedback residual that makes it converge; plus an int8
    variant.
  * sparsification — top-k with residual accumulation (Strom [39], Deep
    Gradient Compression [54]), realized as *block-local* top-k which is
    the TPU-friendly form (no global sort; see DESIGN.md §2).

Every compressor is a pair (encode, decode) threaded through an
error-feedback wrapper:   c = encode(g + r);  r ← (g + r) − decode(c).
The communicated object is ``decode(encode(·))`` — strategies communicate
the *decompressed* tensor (wire format is an implementation detail of the
transport; the wire-size accounting lives in ``wire_bytes``).

The hot loops have Pallas kernels in ``repro/kernels`` (onebit_quant,
topk_sparsify).  ``compress``/``decompress`` are the pure-jnp reference;
``fused_encode`` (when present) is the production encode+error-feedback
round dispatched to the fused kernel — one VMEM pass computing
``t = g + r``, the narrowed wire arrays (packed sign bytes / top-k
values+indices) and the residual update, bitwise identical to the jnp
path (tests/test_fused_compression.py).  ``core/fabric.py`` dispatches
to it by default.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class Compressor:
    name: str
    compress: Callable  # (x) -> (wire, meta)  [wire: what's transmitted]
    decompress: Callable  # (wire, meta, shape, dtype) -> x_hat
    wire_bits_per_element: float  # analytic bits/elem (see wire_bytes)
    # (g, r) flat f32 arrays of shape lead + (n,) -> (narrow_arrs, widen,
    # new_residual): the fused kernel encode+error-feedback round.
    # ``narrow_arrs`` match the _narrow_wire output for compress(g + r)
    # byte-for-byte; ``widen(arrs)`` maps ONE replica's narrow arrays back
    # to what ``decompress`` expects.  None -> no fused path (jnp only).
    fused_encode: Optional[Callable] = None


# ---------------------------------------------------------------------------
# identity
# ---------------------------------------------------------------------------
def none_compressor() -> Compressor:
    return Compressor(
        name="none",
        compress=lambda x: (x, None),
        decompress=lambda w, m, shape, dtype: w,
        wire_bits_per_element=32.0,
    )


# ---------------------------------------------------------------------------
# 1-bit quantization (sign + per-block mean-|x| scale)
# ---------------------------------------------------------------------------
def onebit_compressor(block: int = 256) -> Compressor:
    def compress(x):
        flat = x.reshape(-1).astype(jnp.float32)
        n = flat.shape[0]
        pad = (-n) % block
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, block)
        sign = jnp.where(blocks >= 0, 1.0, -1.0)
        scale = jnp.mean(jnp.abs(blocks), axis=-1, keepdims=True)
        return (sign.astype(jnp.int8), scale), None

    def decompress(wire, meta, shape, dtype):
        sign, scale = wire
        n = 1
        for s in shape:
            n *= s
        flat = (sign.astype(jnp.float32) * scale).reshape(-1)[:n]
        return flat.reshape(shape).astype(dtype)

    # 1 bit per element + one fp32 scale per block
    return Compressor("onebit", compress, decompress,
                      wire_bits_per_element=1.0 + 32.0 / block,
                      fused_encode=(_fused_onebit(block)
                                    if block % 8 == 0 else None))


# ---------------------------------------------------------------------------
# int8 linear quantization (per-block max-abs scale)
# ---------------------------------------------------------------------------
def int8_compressor(block: int = 256) -> Compressor:
    def compress(x):
        flat = x.reshape(-1).astype(jnp.float32)
        pad = (-flat.shape[0]) % block
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, block)
        scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-30)), -127, 127)
        return (q.astype(jnp.int8), scale), None

    def decompress(wire, meta, shape, dtype):
        q, scale = wire
        n = 1
        for s in shape:
            n *= s
        flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
        return flat.reshape(shape).astype(dtype)

    return Compressor("int8", compress, decompress,
                      wire_bits_per_element=8.0 + 32.0 / block)


# ---------------------------------------------------------------------------
# block-local top-k sparsification (DGC-style)
# ---------------------------------------------------------------------------
def topk_compressor(ratio: float = 0.01, block: int = 1024) -> Compressor:
    if block > 1 << 16:
        raise ValueError(  # the packed wire format uses uint16 indices
            f"topk block must be <= 65536 (got {block}); in-block indices "
            "are shipped as uint16 (core/fabric.py)")
    k = max(1, int(round(block * ratio)))

    def compress(x):
        flat = x.reshape(-1).astype(jnp.float32)
        pad = (-flat.shape[0]) % block
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, block)
        vals, idx = jax.lax.top_k(jnp.abs(blocks), k)
        taken = jnp.take_along_axis(blocks, idx, axis=-1)
        return (taken, idx.astype(jnp.int32)), None

    def decompress(wire, meta, shape, dtype):
        taken, idx = wire
        n = 1
        for s in shape:
            n *= s
        nblocks = idx.shape[0]
        blocks = jnp.zeros((nblocks, block), jnp.float32).at[
            jnp.arange(nblocks)[:, None], idx].set(taken)
        return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)

    # k values (32b) + k indices (16b suffices for block≤64k) per block
    return Compressor(f"topk{ratio}", compress, decompress,
                      wire_bits_per_element=ratio * (32.0 + 16.0),
                      fused_encode=_fused_topk(k, block))


# ---------------------------------------------------------------------------
# fused kernel encode+error-feedback rounds (the production Fabric path)
# ---------------------------------------------------------------------------
def _kernel_rows(rows: int) -> int:
    """rows_per_step for the block-row kernels: interpret mode unrolls the
    Pallas grid at trace time, so cap the grid at ~64 steps while keeping
    the (8, 128) sublane alignment."""
    per_step = -(-rows // 64)  # ceil: grid ≤ 64
    return max(8, -(-per_step // 8) * 8)  # round up to sublane multiple


def _fold_blocks(g, r, block: int):
    """lead + (n,) f32 pair → (rows, block) kernel inputs.  Replica lead
    axes fold into kernel rows AFTER per-replica zero-padding to a block
    multiple, so a compression block never mixes values from two
    replicas (the same guarantee as the vmapped jnp path)."""
    n = g.shape[-1]
    pad = (-n) % block
    g2 = g.astype(jnp.float32).reshape((-1, n))
    r2 = r.astype(jnp.float32).reshape((-1, n))
    if pad:
        g2 = jnp.pad(g2, ((0, 0), (0, pad)))
        r2 = jnp.pad(r2, ((0, 0), (0, pad)))
    nb = (n + pad) // block
    rows = g2.shape[0] * nb
    return g2.reshape(rows, block), r2.reshape(rows, block), nb, pad


def _unfold_residual(newr, lead, n: int, pad: int):
    """Kernel residual rows → lead + (n,) (padded tail dropped — the jnp
    path never materializes it either)."""
    return newr.reshape((-1, n + pad))[:, :n].reshape(lead + (n,))


def _fused_onebit(block: int):
    def fused_encode(g, r):
        from repro.kernels import ops
        lead, n = g.shape[:-1], g.shape[-1]
        gb, rb, nb, pad = _fold_blocks(g, r, block)
        packed, scale, newr = ops.onebit_quant_packed(
            gb, rb, rows_per_step=_kernel_rows(gb.shape[0]))
        arrs = [packed.reshape(lead + (nb * (block // 8),)),
                scale.reshape(lead + (nb, 1))]

        def widen(a):  # one replica's narrow arrays → decompress wire
            p, s = a
            sign = unpack_signs(p.reshape(-1), nb * block)
            return sign.reshape(nb, block), s.astype(jnp.float32)

        return arrs, widen, _unfold_residual(newr, lead, n, pad)

    return fused_encode


def _fused_topk(k: int, block: int):
    def fused_encode(g, r):
        from repro.kernels import ops
        lead, n = g.shape[:-1], g.shape[-1]
        gb, rb, nb, pad = _fold_blocks(g, r, block)
        vals, idx, newr = ops.topk_encode_ef(
            gb, rb, k, rows_per_step=_kernel_rows(gb.shape[0]))
        arrs = [vals.reshape(lead + (nb, k)),
                idx.astype(jnp.uint16).reshape(lead + (nb, k))]

        def widen(a):
            return a[0], a[1].astype(jnp.int32)

        return arrs, widen, _unfold_residual(newr, lead, n, pad)

    return fused_encode


REGISTRY = {
    "none": none_compressor,
    "onebit": onebit_compressor,
    "int8": int8_compressor,
    "topk": topk_compressor,
}


def get_compressor(name: str, **kw) -> Compressor:
    return REGISTRY[name](**kw)


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------
def ef_init(params):
    """Error-feedback residual state (one per communicated leaf)."""
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def ef_compress_tree(comp: Compressor, grads, residual):
    """Apply compressor with error feedback leaf-wise.

    Returns (g_hat, new_residual): ``g_hat`` is what gets communicated
    (already decompressed — see module docstring), residual carries the
    compression error to the next round."""

    def one(g, r):
        target = g.astype(jnp.float32) + r
        wire, meta = comp.compress(target)
        g_hat = comp.decompress(wire, meta, g.shape, jnp.float32)
        return g_hat.astype(g.dtype), target - g_hat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    g_hat = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in out])
    return g_hat, new_r


def wire_bytes(comp: Compressor, tree) -> float:
    """EXACT bytes on the wire to ship ``tree`` once under ``comp``:
    each leaf is compressed independently (the leaf-wise contract of
    ``ef_compress_tree``/``dgc_compress_tree``), so padded tail blocks
    ship their full scale/index payloads and are charged here.  Derived
    from the actual packing code (``packed_nbytes``), matching
    ``fabric.wire_nbytes`` by construction; ``wire_bits_per_element``
    remains the analytic (padding-free) figure for scaling models."""
    return float(sum(packed_nbytes(comp, x.size)
                     for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# Deep Gradient Compression momentum correction (Lin et al. [54], §2.2.4):
# accumulate MOMENTUM (not raw gradients) into the residual before top-k,
# so sparsified-away velocity keeps accumulating instead of being lost.
# ---------------------------------------------------------------------------
def dgc_init(params):
    z = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
    return {"velocity": jax.tree.map(z, params),
            "residual": jax.tree.map(z, params)}


def dgc_compress_tree(comp: Compressor, grads, state, momentum: float = 0.9):
    """Returns (g_hat, new_state): g_hat is the communicated (decompressed)
    sparse velocity; velocity/residual carry what wasn't sent."""

    def one(g, u, r):
        u1 = momentum * u + g.astype(jnp.float32)
        target = r + u1
        wire, meta = comp.compress(target)
        sent = comp.decompress(wire, meta, g.shape, jnp.float32)
        # what was sent leaves both accumulators (DGC eq. 4-5)
        mask = (sent != 0).astype(jnp.float32)
        return sent.astype(g.dtype), u1 * (1 - mask), target - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_u = jax.tree.leaves(state["velocity"])
    flat_r = jax.tree.leaves(state["residual"])
    outs = [one(g, u, r) for g, u, r in zip(flat_g, flat_u, flat_r)]
    g_hat = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_state = {
        "velocity": jax.tree.unflatten(treedef, [o[1] for o in outs]),
        "residual": jax.tree.unflatten(treedef, [o[2] for o in outs]),
    }
    return g_hat, new_state


def pack_signs(sign_int8):
    """True 1-bit wire format: pack 8 int8 signs into one uint8.  This is
    the jnp reference codec; the fused kernel (onebit_quant_packed) emits
    the same bytes from inside VMEM — no separate XLA pack op on the
    fused Fabric path (DESIGN.md §2 table)."""
    bits = (sign_int8 > 0).astype(jnp.uint8).reshape(-1, 8)
    weights = jnp.asarray([1, 2, 4, 8, 16, 32, 64, 128], jnp.uint8)
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint8)


def unpack_signs(packed, n):
    weights = jnp.asarray([1, 2, 4, 8, 16, 32, 64, 128], jnp.uint8)
    bits = (packed[:, None] & weights) > 0
    sign = jnp.where(bits.reshape(-1)[:n], 1, -1).astype(jnp.int8)
    return sign


# ---------------------------------------------------------------------------
# wire codecs: compressor wire tuple ↔ one packed uint8 buffer.
# The narrowing IS the wire format (packed sign bits, bf16 scales, uint16
# top-k indices); core/fabric.py ships exactly these bytes per bucket.
# ---------------------------------------------------------------------------
def _to_bytes(x):
    """Any array → flat uint8 view."""
    if x.dtype == jnp.uint8:
        return x.reshape(-1)
    return lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)


def _from_bytes(buf, shape, dtype):
    dtype = jnp.dtype(dtype)
    if dtype.itemsize == 1:
        seg = buf.reshape(shape)
        return seg if dtype == jnp.uint8 \
            else lax.bitcast_convert_type(seg, dtype)
    return lax.bitcast_convert_type(
        buf.reshape(tuple(shape) + (dtype.itemsize,)), dtype)


def _narrow_wire(name: str, wire):
    """Narrow a compressor's wire tuple to its true on-the-wire dtypes.

    Returns (arrays, widen) where ``widen`` maps the narrowed arrays back
    to the structure ``Compressor.decompress`` expects.  Unknown
    compressors fall through to an identity codec."""
    if name == "onebit":
        sign, scale = wire
        n = sign.size
        flat = sign.reshape(-1)
        pad = (-n) % 8
        if pad:
            flat = jnp.concatenate([flat, jnp.ones((pad,), flat.dtype)])
        packed = pack_signs(flat)

        def widen(arrs):
            p, s = arrs
            return (unpack_signs(p, n).reshape(sign.shape),
                    s.astype(jnp.float32))

        return [packed, scale.astype(jnp.bfloat16)], widen
    if name == "int8":
        q, scale = wire

        def widen(arrs):
            return (arrs[0], arrs[1].astype(jnp.float32))

        return [q, scale.astype(jnp.bfloat16)], widen
    if name.startswith("topk"):
        taken, idx = wire  # blocks ≤ 64k ⇒ uint16 indices

        def widen(arrs):
            return (arrs[0], arrs[1].astype(jnp.int32))

        return [taken, idx.astype(jnp.uint16)], widen
    arrs, tdef = jax.tree.flatten(wire)
    return arrs, lambda a: jax.tree.unflatten(tdef, list(a))


def _pack(arrs):
    """Arrays → (uint8 buffer, static segment specs)."""
    bufs = [_to_bytes(a) for a in arrs]
    specs = [(a.shape, a.dtype, b.shape[-1]) for a, b in zip(arrs, bufs)]
    buf = bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs, axis=-1)
    return buf, specs


def _unpack(buf, specs):
    out, off = [], 0
    for shape, dtype, nb in specs:
        seg = lax.slice_in_dim(buf, off, off + nb, axis=buf.ndim - 1)
        out.append(_from_bytes(seg, shape, dtype))
        off += nb
    return out


def packed_nbytes(comp: Optional[Compressor], n: int) -> int:
    """Exact packed-wire bytes to ship ``n`` f32 elements once under
    ``comp`` — derived from the actual packing code via eval_shape, so it
    equals the size of the uint8 buffer an exchange really gathers
    (padded tail blocks included)."""
    if comp is None or comp.name == "none":
        return 4 * n

    def f(t):
        wire, _ = comp.compress(t)
        arrs, _ = _narrow_wire(comp.name, wire)
        buf, _ = _pack(arrs)
        return buf

    return int(jax.eval_shape(
        f, jax.ShapeDtypeStruct((n,), jnp.float32)).shape[0])
