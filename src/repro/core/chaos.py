"""Seeded, schedulable fault injection for elastic fleet training.

The chaos harness (DESIGN.md §13) grows ``examples/edge_async_sim.py``
into a real test rig: instead of eyeballing divergence under a clean
run, tests and benches drive ``launch/elastic.py::ElasticFleet`` with a
deterministic event schedule and assert on membership epochs, retry
logs, and loss trajectories.

Event schema (``ChaosEvent``): ``t`` is the optimizer-boundary index the
event fires at, ``worker`` a *global* worker id (stable across resizes —
ranks are reassigned per ``FleetView`` epoch, ids never are), ``kind``:

  * ``kill``     — the worker dies mid-collective: the boundary exchange
                   raises :class:`ExchangeFailure`, retries exhaust, and
                   the controller drops the worker from the next epoch.
  * ``preempt``  — an ANNOUNCED departure (spot reclaim warning): the
                   controller resizes down gracefully before the
                   exchange, no failed collective.
  * ``flake``    — a transient exchange failure (network blip): fails
                   the first attempt, succeeds on retry; membership is
                   unchanged.
  * ``slowdown`` — the worker's boundary wall-time is multiplied by
                   ``factor`` until restored (feeds the straggler
                   detector, ``core/staleness.py``).
  * ``restore``  — clears a ``slowdown``.
  * ``rejoin``   — the worker (re)joins the fleet at this boundary.

Everything is seeded (``ChaosSchedule.from_seed``, ``FleetClock``) so a
chaos run is exactly replayable — the property the hierarchical-strategy
determinism test pins down.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

KINDS = ("kill", "preempt", "flake", "slowdown", "restore", "rejoin")


class ExchangeFailure(RuntimeError):
    """A boundary collective failed for ``workers``.

    ``transient=True`` marks a blip expected to clear on retry; a
    non-transient failure means the workers are gone and the fleet must
    degrade to the survivors."""

    def __init__(self, msg: str, workers=(), transient: bool = False):
        super().__init__(msg)
        self.workers = frozenset(workers)
        self.transient = transient


@dataclass(frozen=True, order=True)
class ChaosEvent:
    t: int
    kind: str
    worker: int
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r} "
                             f"(expected one of {KINDS})")

    def spec(self) -> dict:
        return {"t": self.t, "kind": self.kind, "worker": self.worker,
                "factor": self.factor}


@dataclass(frozen=True)
class ChaosSchedule:
    """An immutable, time-sorted event list."""

    events: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(sorted(self.events)))

    def at(self, t: int) -> list:
        return [e for e in self.events if e.t == t]

    def horizon(self) -> int:
        return max((e.t for e in self.events), default=0)

    def spec(self) -> list:
        return [e.spec() for e in self.events]

    @staticmethod
    def from_seed(seed: int, horizon: int, n_workers: int, *,
                  p_kill: float = 0.01, p_flake: float = 0.02,
                  p_slowdown: float = 0.02, slow_factor: float = 3.0,
                  rejoin_after: int = 4) -> "ChaosSchedule":
        """Deterministic random schedule: same seed ⇒ same events.

        At most one kill total (keeps small test fleets alive); each
        killed worker rejoins ``rejoin_after`` boundaries later; slowdowns
        are paired with a restore."""
        rng = np.random.default_rng(seed)
        events = []
        killed = False
        for t in range(1, horizon):
            for w in range(n_workers):
                r = rng.random()
                if not killed and r < p_kill:
                    events.append(ChaosEvent(t, "kill", w))
                    if t + rejoin_after < horizon:
                        events.append(ChaosEvent(t + rejoin_after, "rejoin", w))
                    killed = True
                elif r < p_kill + p_flake:
                    events.append(ChaosEvent(t, "flake", w))
                elif r < p_kill + p_flake + p_slowdown:
                    dur = int(rng.integers(2, 6))
                    events.append(ChaosEvent(t, "slowdown", w, slow_factor))
                    if t + dur < horizon:
                        events.append(ChaosEvent(t + dur, "restore", w))
        return ChaosSchedule(tuple(events))


@dataclass
class FleetClock:
    """Simulated per-worker boundary wall-times (seconds).

    ``boundary_times`` returns one time per fleet member: a common base,
    the worker's current slowdown factor, and seeded jitter.  Feeds the
    straggler detector so demotion tests don't depend on real wall time."""

    n_workers: int
    base_s: float = 1.0
    jitter: float = 0.05
    seed: int = 0
    factor: np.ndarray = field(default=None, repr=False)

    def __post_init__(self):
        self.factor = np.ones(self.n_workers)
        self._rng = np.random.default_rng(self.seed)

    def apply(self, events) -> None:
        for e in events:
            if e.kind == "slowdown":
                self.factor[e.worker] = e.factor
            elif e.kind == "restore":
                self.factor[e.worker] = 1.0

    def boundary_times(self, members) -> dict:
        jit = 1.0 + self.jitter * self._rng.random(len(members))
        return {w: float(self.base_s * self.factor[w] * jit[i])
                for i, w in enumerate(members)}
