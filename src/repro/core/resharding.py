"""Worker-count re-partitioning for ZeRO shard-bucket state.

ONE implementation serves both resize paths (DESIGN.md §13):

  * the **checkpoint path** — ``restore_checkpoint(repartition=True)``
    re-shards saved bucket leaves against a template built at a
    different worker count, and
  * the **live path** — ``launch/elastic.py::resize_state`` re-partitions
    the in-memory optimizer/parameter shards when the fleet resizes at
    an optimizer boundary, with no disk round-trip.

Both call :func:`reshard_bucket` (lifted out of
``checkpoint/checkpointer.py``, which re-exports it), so the online
resize is bitwise-equal to a save → restore round-trip by construction.

Shard chunks are stored in rank order: a stacked simulator leaf (W, C)
and a global flat leaf (padded,) both flatten to
chunk_0 ‖ chunk_1 ‖ … ‖ old_padding, so "drop the old padding, zero-pad
for the new worker count, reshape" is the whole transition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _prod(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def reshard_bucket(arr: np.ndarray, true_size: int, target_shape) -> np.ndarray:
    """Re-shard one saved ZeRO bucket to a new partition.

    Works for both layouts because shard chunks are stored in rank order:
    a stacked simulator leaf (W, C) and a global flat leaf (padded,) both
    flatten to chunk_0‖chunk_1‖…‖old_padding.  Drop the old padding
    (``true_size`` live elements), zero-pad for the new worker count, and
    reshape to the template."""
    flat = np.asarray(arr).reshape(-1)[:true_size]
    out = np.zeros((_prod(target_shape),), flat.dtype)
    out[:true_size] = flat
    return out.reshape(target_shape)


def _is_bucket_list(node, n_buckets: int) -> bool:
    return (n_buckets > 0 and isinstance(node, (list, tuple))
            and len(node) == n_buckets
            and all(getattr(x, "ndim", 0) in (1, 2)
                    and hasattr(x, "dtype") for x in node))


def _reshard_one(x, true_size: int, n_new: int):
    padded = -(-true_size // n_new) * n_new
    # stacked simulator shard (W, C) keeps its 2-d layout at the new
    # width; a global flat shard (padded,) stays flat
    target = (n_new, padded // n_new) if x.ndim == 2 else (padded,)
    out = reshard_bucket(np.asarray(x), true_size, target)
    return jnp.asarray(out) if isinstance(x, jax.Array) else out


def repartition_tree(tree, bucket_sizes, n_new: int):
    """Re-partition every shard-bucket list in a ZeRO state tree W → W′.

    A *shard-bucket list* is a list/tuple whose length equals
    ``len(bucket_sizes)`` and whose elements are all 1-d (flat) or 2-d
    (stacked ``(W, C)``) arrays — exactly the layout ``Fabric.shard_params``
    and the ZeRO ``init_opt`` hooks produce.  Each bucket ``i`` carries
    ``bucket_sizes[i]`` live elements (the ``PartitionedLayout.spec()``
    record); the rest is padding and is dropped/regrown per worker count.

    Only apply to ZeRO shard-state trees (opt_state of ``sync_zero*``,
    ZeRO-3 parameter shards): any other list that happens to match the
    bucket count would be resharded too.  Non-list leaves (scalars,
    dense arrays outside a bucket list) pass through untouched — dense
    replica-stacked state is resized by row instead
    (``launch/elastic.py::resize_dense_tree``)."""
    nb = len(bucket_sizes)

    def go(node):
        if isinstance(node, dict):
            return {k: go(v) for k, v in node.items()}
        if _is_bucket_list(node, nb):
            return type(node)(_reshard_one(x, n, n_new)
                              for x, n in zip(node, bucket_sizes))
        if isinstance(node, (list, tuple)):
            return type(node)(go(v) for v in node)
        return node

    return go(tree)
