"""Version-tolerant spellings of the jax mesh / shard_map surface.

The repo is written against the modern API (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``, ``get_abstract_mesh``); the
pinned container may carry an older jax (0.4.x) where the same machinery
lives under ``jax.experimental.shard_map`` and the mesh context manager.
Every call site imports from here so the rest of the codebase stays
single-spelling.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # noqa: F401
except ImportError:  # pragma: no cover - exercised on old jax only
    class AxisType:  # minimal stand-in: only .Auto is ever referenced
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where supported."""
    try:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
    except TypeError:  # jax < 0.5: no axis_types kwarg
        return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh):
    """Context manager activating ``mesh`` for sharding constraints."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # old jax: Mesh is itself a context manager


def get_abstract_mesh():
    """The mesh of the current sharding context (None/empty when absent)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh


def manual_axis_names(mesh):
    """Axis names currently in Manual mode (inside a shard_map over them).

    New jax records this on the abstract mesh's axis_types; old jax has no
    axis_types, but any mesh axis bound in the tracing axis env is mapped
    (old shard_map is full-manual over its mesh), which is what we need to
    know to drop those axes from sharding constraints."""
    types = getattr(mesh, "axis_types", None)
    if types is not None:
        return {n for n, t in zip(mesh.axis_names, types)
                if "Manual" in str(t)}
    try:
        from jax._src.core import get_axis_env
        bound = set(get_axis_env().axis_sizes)
    except Exception:
        return set()
    return bound & set(mesh.axis_names)


def cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict (old jax returns a list)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def axis_size(name):
    """Static size of a named mapped axis (inside shard_map/pmap)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)  # old jax: folded to a constant at trace


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """Map ``f`` over ``mesh``; manual over ``axis_names`` (all axes when
    None), with replication checking off by default (both jax spellings)."""
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    # Old jax: partial-auto shard_map trips an XLA SPMD partitioner CHECK,
    # so lower full-manual — axes outside in_specs are simply replicated.
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
