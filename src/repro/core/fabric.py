"""Bucketed flat-buffer exchange fabric (DESIGN.md §3).

The paper's tensor-moving interface (``Comm``) decouples strategies from
transport, but a naive realization still issues one collective per
parameter leaf — hundreds of tiny transfers for a real model.  Following
the fusion argument of cuDNN/DLL (many small ops → few large ops), the
``Fabric`` flattens a gradient pytree into size-capped flat f32 *buckets*
and drives every ``Comm`` primitive once per bucket:

    tree (n_leaves) --bucketize--> [b0, b1, ...] (n_buckets ≤ n_leaves)
                     --collective / compress+pack → wire--> ...
                     --debucketize--> tree

Compression (1-bit / int8 / top-k with error feedback) runs on the flat
buffer, and the wire format is genuinely packed: every wire component is
serialized into ONE uint8 buffer per bucket (8 signs/byte, bf16 scales,
uint16 top-k indices), so a compressed exchange is a single all-gather of
bytes per bucket — no per-leaf metadata soup.  ``wire_nbytes`` reports the
exact size of that buffer (it is derived from the same packing code via
``jax.eval_shape``), so strategy metrics match the bytes on the wire by
construction.

Replica safety: ``comm.lead_axes`` leading axes (worker stacking in the
LocalComm simulator, pods×workers in the hierarchy) are preserved through
flattening and the per-replica compression is vmapped over them — a
compression block never mixes values from two replicas.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.comm import Comm, ShardComm
from repro.core.compression import (Compressor, _from_bytes,  # noqa: F401
                                    _narrow_wire, _pack, _to_bytes, _unpack,
                                    pack_signs, packed_nbytes, unpack_signs)

DEFAULT_BUCKET_BYTES = 4 << 20  # 4 MiB of f32 per bucket


def _prod(shape):
    n = 1
    for s in shape:
        n *= s
    return n


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BucketLayout:
    """Static description of tree ↔ flat-bucket correspondence.

    Leaves are assigned greedily, in tree order, to f32 buckets holding at
    most ``bucket_bytes`` (a leaf larger than the cap gets its own
    bucket — leaves are never split).  ``lead_shape`` is the common shape
    of the leading replica axes; offsets/sizes are in trailing elements."""

    treedef: Any
    lead_shape: tuple
    shapes: tuple  # per-leaf trailing shape
    dtypes: tuple  # per-leaf original dtype
    sizes: tuple  # per-leaf trailing element count
    bucket_of: tuple  # leaf index -> bucket index
    offsets: tuple  # leaf offset inside its bucket (elements)
    bucket_sizes: tuple  # elements per bucket
    bucket_bytes: int

    @property
    def n_leaves(self) -> int:
        return len(self.sizes)

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    @property
    def total_elements(self) -> int:
        return sum(self.bucket_sizes)

    @staticmethod
    def build(tree, bucket_bytes: int = DEFAULT_BUCKET_BYTES,
              lead_axes: int = 0) -> "BucketLayout":
        leaves, treedef = jax.tree.flatten(tree)
        lead_shape = tuple(leaves[0].shape[:lead_axes]) if leaves else ()
        for x in leaves:
            if tuple(x.shape[:lead_axes]) != lead_shape:
                raise ValueError(
                    f"inconsistent replica axes: {x.shape[:lead_axes]} vs "
                    f"{lead_shape} (lead_axes={lead_axes})")
        shapes = tuple(tuple(x.shape[lead_axes:]) for x in leaves)
        dtypes = tuple(x.dtype for x in leaves)
        sizes = tuple(_prod(s) for s in shapes)
        cap = max(1, bucket_bytes // 4)  # elements of f32
        bucket_of, offsets, bucket_sizes = [], [], []
        cur = -1  # no open bucket
        for sz in sizes:
            if cur < 0 or (bucket_sizes[cur] > 0
                           and bucket_sizes[cur] + sz > cap):
                bucket_sizes.append(0)
                cur += 1
            bucket_of.append(cur)
            offsets.append(bucket_sizes[cur])
            bucket_sizes[cur] += sz
        return BucketLayout(treedef, lead_shape, shapes, dtypes, sizes,
                            tuple(bucket_of), tuple(offsets),
                            tuple(bucket_sizes), bucket_bytes)

    # -- tree <-> buckets ---------------------------------------------------
    def bucketize(self, tree):
        """Tree → list of f32 buckets of shape lead_shape + (n_b,)."""
        leaves = jax.tree.leaves(tree)
        flats = [x.astype(jnp.float32).reshape(self.lead_shape + (-1,))
                 for x in leaves]
        out = []
        for b in range(self.n_buckets):
            segs = [flats[i] for i in range(self.n_leaves)
                    if self.bucket_of[i] == b]
            out.append(segs[0] if len(segs) == 1
                       else jnp.concatenate(segs, axis=-1))
        return out

    def debucketize(self, buckets, cast: bool = True):
        """Buckets → tree (cast back to original leaf dtypes unless
        ``cast=False``, which keeps f32 — used for residual state)."""
        leaves = []
        for i in range(self.n_leaves):
            b = buckets[self.bucket_of[i]]
            seg = lax.slice_in_dim(b, self.offsets[i],
                                   self.offsets[i] + self.sizes[i],
                                   axis=b.ndim - 1)
            seg = seg.reshape(self.lead_shape + self.shapes[i])
            leaves.append(seg.astype(self.dtypes[i]) if cast else seg)
        return jax.tree.unflatten(self.treedef, leaves)


# ---------------------------------------------------------------------------
# partitioned (ZeRO-1) layout: every bucket padded to a multiple of W
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PartitionedLayout:
    """BucketLayout + the ZeRO-1 partition: each flat f32 bucket is
    zero-padded to a multiple of ``n_parts`` and worker w owns chunk w.

    Per-worker footprint of anything kept in shard form (optimizer state,
    master shards) is ``sum(shard_sizes)`` ≈ ``total_elements / n_parts``
    instead of ``total_elements`` — the O(W) memory lever.  The wire cost
    of one partitioned exchange (reduce-scatter + all-gather) equals the
    ring all-reduce of the dense path."""

    layout: BucketLayout
    n_parts: int
    padded_sizes: tuple  # per-bucket elements after padding

    @staticmethod
    def build(layout: BucketLayout, n_parts: int) -> "PartitionedLayout":
        """THE padding rule (single definition): each bucket rounds up to
        the next multiple of ``n_parts`` — runtime shard shapes and the
        global opt-state template must agree element-for-element."""
        padded = tuple(-(-n // n_parts) * n_parts
                       for n in layout.bucket_sizes)
        return PartitionedLayout(layout, n_parts, padded)

    @property
    def shard_sizes(self) -> tuple:
        return tuple(p // self.n_parts for p in self.padded_sizes)

    def spec(self) -> dict:
        """JSON-able partition description for checkpoint re-sharding."""
        return {"n_parts": self.n_parts,
                "bucket_sizes": list(self.layout.bucket_sizes)}

    def with_parts(self, n_parts: int) -> "PartitionedLayout":
        """Re-pad the SAME bucket layout for a different worker count —
        the elastic-resize primitive (launch/elastic.py): bucket contents
        (``layout.bucket_sizes``) are invariant across a W → W′
        transition, only the per-bucket padding and chunk width change."""
        return PartitionedLayout.build(self.layout, n_parts)


# ---------------------------------------------------------------------------
# wire accounting (codec itself lives in core/compression.py)
# ---------------------------------------------------------------------------
def wire_nbytes(compressor: Optional[Compressor], n: int,
                wire_dtype=jnp.float32) -> int:
    """Exact packed-wire size (bytes) to ship ``n`` f32 elements once.

    Derived from the actual packing code via eval_shape
    (``compression.packed_nbytes``), so it equals the size of the uint8
    buffer a ShardComm exchange really gathers.  An uncompressed exchange
    ships raw ``wire_dtype`` buckets (2 bytes/elem under the bf16
    policy); compressors own their packed format and ignore
    ``wire_dtype``."""
    if compressor is None or compressor.name == "none":
        return jnp.dtype(wire_dtype).itemsize * n
    return packed_nbytes(compressor, n)


# ---------------------------------------------------------------------------
# fabric
# ---------------------------------------------------------------------------
class Fabric:
    """Bucket-fused tensor moving over a ``Comm``.

    Every public op issues at most ONE collective per bucket (and exactly
    one all-gather of packed bytes per bucket on the compressed ShardComm
    path).  Residual / DGC state stays param-shaped f32 trees, so existing
    checkpoint and sharding-spec machinery is untouched."""

    def __init__(self, comm: Comm, bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 wire_dtype=None, fused: bool = True):
        self.comm = comm
        self.bucket_bytes = bucket_bytes
        # dtype of the UNCOMPRESSED wire (PrecisionPolicy.wire_dtype):
        # buckets are rounded to it before every collective.  f32 (the
        # default) leaves every path bit-for-bit unchanged.
        self.wire_dtype = (jnp.dtype(wire_dtype) if wire_dtype is not None
                           else jnp.dtype(jnp.float32))
        # dispatch compressed exchanges through the fused Pallas
        # encode+error-feedback kernels when the compressor has one
        # (Compressor.fused_encode) — bitwise identical to the jnp path
        self.fused = fused

    def _wire_cast(self, buckets):
        """Round flat f32 buckets to the wire dtype.  On the stacked
        simulator the rounded values are upcast back to f32 so the axis
        reduction accumulates in f32 (the reference semantics of a bf16
        wire with f32 ring accumulation); a ShardComm ships the narrow
        buffer itself and the TPU reduction accumulates on-chip."""
        if self.wire_dtype == jnp.float32:
            return buckets
        narrowed = [b.astype(self.wire_dtype) for b in buckets]
        if isinstance(self.comm, ShardComm):
            return narrowed
        return [b.astype(jnp.float32) for b in narrowed]

    def layout(self, tree) -> BucketLayout:
        return BucketLayout.build(tree, self.bucket_bytes,
                                  self.comm.lead_axes)

    # -- plain (uncompressed) fused collectives -----------------------------
    @property
    def _narrow_sharded(self) -> bool:
        """Narrow wire on a per-shard realization: XLA convert-promotes a
        bf16 all-reduce/reduce-scatter/all-gather back to an f32 wire, so
        every narrow ShardComm op must be expressed in promotion-proof
        form (all-to-all of narrow chunks + local f32 accumulate, and
        bitcast-uint16 gathers/permutes)."""
        return (self.wire_dtype.itemsize == 2
                and isinstance(self.comm, ShardComm))

    def _bitcast_u16(self, buckets):
        return [lax.bitcast_convert_type(b.astype(self.wire_dtype),
                                         jnp.uint16) for b in buckets]

    def _reduce_narrow_sharded(self, buckets, mean: bool):
        """All-reduce(-mean) semantics per flat bucket with a provably
        narrow wire: pad to a multiple of W, ship the narrowed chunks with
        ONE all-to-all (ring bytes of a reduce-scatter), accumulate the W
        received chunks locally in f32, and all-gather the reduced shard's
        bitcast-uint16 wire image back (ring bytes of an all-gather).
        RS + AG move exactly the bytes of the all-reduce they replace."""
        w = self.comm.size
        out = []
        for b in buckets:
            n = b.shape[-1]
            p = -(-n // w) * w
            bb = b if n == p else jnp.pad(
                b, [(0, 0)] * (b.ndim - 1) + [(0, p - n)])
            (stacked,) = self.comm.gather_chunks(
                [bb.astype(self.wire_dtype)])
            red = jnp.sum(stacked.astype(jnp.float32), axis=0)
            if mean:
                red = red / w
            (full,) = self.comm.all_gather(self._bitcast_u16([red]),
                                           tiled=True)
            full = lax.bitcast_convert_type(full, self.wire_dtype)
            out.append(lax.slice_in_dim(full.astype(jnp.float32), 0, n,
                                        axis=full.ndim - 1))
        return out

    def all_mean(self, tree):
        return self._reduce(tree, mean=True)

    def all_sum(self, tree):
        return self._reduce(tree, mean=False)

    def _reduce(self, tree, mean: bool):
        lay = self.layout(tree)
        if lay.n_leaves == 0:
            return tree
        gb = lay.bucketize(tree)
        if self._narrow_sharded:
            return lay.debucketize(self._reduce_narrow_sharded(gb, mean))
        op = self.comm.all_mean if mean else self.comm.all_sum
        return lay.debucketize(op(self._wire_cast(gb)))

    def ppermute(self, tree, shift: int = 1):
        lay = self.layout(tree)
        if lay.n_leaves == 0:
            return tree
        gb = lay.bucketize(tree)
        if self._narrow_sharded:  # pure data movement: permute the bytes
            out = self.comm.ppermute(self._bitcast_u16(gb), shift)
            out = [lax.bitcast_convert_type(b, self.wire_dtype)
                   for b in out]
            return lay.debucketize(out)
        return lay.debucketize(self.comm.ppermute(self._wire_cast(gb),
                                                  shift))

    # -- wire accounting ----------------------------------------------------
    def flat_bytes(self, tree_or_layout) -> float:
        """Uncompressed wire-dtype bytes to ship the tree once (all
        replicas) — halves under a bf16 wire."""
        lay = tree_or_layout if isinstance(tree_or_layout, BucketLayout) \
            else self.layout(tree_or_layout)
        return float(self.wire_dtype.itemsize * lay.total_elements
                     * _prod(lay.lead_shape))

    def wire_bytes(self, tree_or_layout, compressor=None) -> float:
        """Packed bytes to ship the tree once (all replicas)."""
        lay = tree_or_layout if isinstance(tree_or_layout, BucketLayout) \
            else self.layout(tree_or_layout)
        per = sum(wire_nbytes(compressor, n, self.wire_dtype)
                  for n in lay.bucket_sizes)
        return float(per * _prod(lay.lead_shape))

    def metrics(self, nbytes, events=1.0):
        ev = jnp.asarray(events, jnp.float32)
        return {"wire_bytes": jnp.asarray(nbytes, jnp.float32) * ev,
                "comm_events": ev}

    def collective_contract(self, tree_or_layout, profile: str,
                            events: int = 1) -> dict:
        """Expected HLO collective budget for ONE exchange of the tree —
        the introspection hook ``repro.analysis`` lints compiled programs
        against.  Maps collective op name -> max instruction count; ops
        absent from the mapping must not appear at all (scalar control
        traffic is budgeted separately by the rules).

        ``profile`` names the wire shape a strategy declares
        (``Strategy.wire_profile``):

          dense        all-reduce(-mean/-sum) of the full tree
          partitioned  ZeRO-1/2/3 reduce-scatter + all-gather per bucket
          compressed   packed uint8 all-gather per bucket (codec wire)
          ring         neighbour ppermute, ``events`` hops per exchange
          tp           tensor parallelism: one dense all-reduce of the
                       layer activation per row-parallel combine
                       (attention out-projection + MLP down-projection);
                       ``events`` counts the combines in the compiled
                       program (forward AND backward — the column-split
                       input grads all-reduce too)
          none         no wire traffic at all
        """
        lay = (tree_or_layout
               if isinstance(tree_or_layout, BucketLayout)
               else self.layout(tree_or_layout))
        nb = lay.n_buckets
        narrow = self._narrow_sharded
        if profile == "none":
            return {}
        if profile == "compressed":
            # packed bytes ride one all-gather per bucket at every width
            return {"all-gather": nb}
        if profile == "dense":
            if narrow:  # a2a decomposition + bitcast-u16 gather-back
                return {"all-to-all": nb, "all-gather": nb}
            return {"all-reduce": nb}
        if profile == "partitioned":
            if narrow:
                return {"all-to-all": nb, "all-gather": nb}
            return {"reduce-scatter": nb, "all-gather": nb}
        if profile == "ring":
            return {"collective-permute": int(events) * nb}
        if profile == "tp":
            if narrow:
                return {"all-to-all": int(events) * nb,
                        "all-gather": int(events) * nb}
            return {"all-reduce": int(events) * nb}
        raise ValueError(f"unknown wire profile {profile!r}")

    # -- compression plumbing ----------------------------------------------
    def _vmap_replicas(self, fn):
        for _ in range(self.comm.lead_axes):
            fn = jax.vmap(fn)
        return fn

    def _self_decode(self, target, compressor):
        """Per-replica compress → pack → unpack → decode of a flat bucket.

        The pack/unpack roundtrip is included on purpose: the simulator
        then sees exactly the wire numerics (bf16 scales etc.) that the
        sharded realization ships."""

        def one(t):
            wire, meta = compressor.compress(t)
            arrs, widen = _narrow_wire(compressor.name, wire)
            buf, specs = _pack(arrs)
            return compressor.decompress(_w(widen, buf, specs), meta,
                                         t.shape, jnp.float32)

        def _w(widen, buf, specs):
            return widen(_unpack(buf, specs))

        return self._vmap_replicas(one)(target)

    def _bucket_mean_compressed(self, target, compressor):
        """(mean of per-replica decodes, own decode) for one flat bucket.

        ShardComm: ONE all-gather of the packed byte buffer, then decode
        every peer locally.  LocalComm: decode per replica (vmapped), then
        one axis-mean — numerically identical."""
        if isinstance(self.comm, ShardComm):
            def enc(t):
                wire, meta = compressor.compress(t)
                arrs, widen = _narrow_wire(compressor.name, wire)
                buf, specs = _pack(arrs)
                dec = lambda bb: compressor.decompress(  # noqa: E731
                    widen(_unpack(bb, specs)), meta, t.shape, jnp.float32)
                (gathered,) = self.comm.all_gather([buf])
                decs = [dec(gathered[i]) for i in range(self.comm.size)]
                return sum(decs) / self.comm.size, dec(buf)

            return enc(target)
        dec_self = self._self_decode(target, compressor)
        (mean,) = self.comm.all_mean([dec_self])
        return mean, dec_self

    def _bucket_ef_round(self, g, r, compressor):
        """One full compressed error-feedback round for a flat bucket:
        (mean of per-replica decodes, own decode, new residual).

        Fused path (the default): ``compressor.fused_encode`` runs the
        whole encode — t = g + r, narrow wire arrays, residual update —
        as ONE Pallas kernel pass; the packed byte buffer shipped is
        byte-identical to the jnp path's, so both realizations stay
        bitwise equal (tests/test_fused_compression.py)."""
        fe = compressor.fused_encode if self.fused else None
        if fe is None:
            t = g + r
            mean, dec_self = self._bucket_mean_compressed(t, compressor)
            return mean, dec_self, t - dec_self
        arrs, widen, new_r = fe(g, r)
        n = g.shape[-1]

        def dec(a):  # one replica's narrow arrays → decoded flat bucket
            return compressor.decompress(widen(a), None, (n,), jnp.float32)

        if isinstance(self.comm, ShardComm):
            buf, specs = _pack(arrs)
            (gathered,) = self.comm.all_gather([buf])
            decs = [dec(_unpack(gathered[i], specs))
                    for i in range(self.comm.size)]
            return sum(decs) / self.comm.size, dec(arrs), new_r
        dec_self = self._vmap_replicas(dec)(arrs)
        (mean,) = self.comm.all_mean([dec_self])
        return mean, dec_self, new_r

    # -- flat-bucket gradient accumulation ----------------------------------
    # The microbatched train step (train/loop.py, DESIGN.md §8) keeps its
    # gradient accumulator in BUCKET space: one flatten per microbatch
    # (``accumulate``), no per-microbatch unflatten, and the boundary
    # exchange consumes the accumulated buckets directly
    # (``exchange_accumulated`` / ``exchange_partitioned_accumulated``) —
    # compression, error feedback and the collective all compose at the
    # boundary only.

    def init_accum(self, lay: BucketLayout,
                   play: Optional[PartitionedLayout] = None):
        """Zeroed flat f32 accumulator buckets (padded when ``play`` is
        given, so the boundary reduce-scatter needs no re-pad)."""
        sizes = play.padded_sizes if play is not None else lay.bucket_sizes
        return [jnp.zeros(lay.lead_shape + (n,), jnp.float32) for n in sizes]

    def accumulate(self, acc, tree, lay: BucketLayout,
                   play: Optional[PartitionedLayout] = None):
        """acc + bucketize(tree): ONE flatten, elementwise adds — a scan
        over microbatches carries only these buckets.  Under
        ``donate_argnums`` the adds are in-place buffer reuse."""
        gb = lay.bucketize(tree)
        if play is not None:
            gb = self._pad_buckets(gb, play)
        return [a + g for a, g in zip(acc, gb)]

    # ZeRO-2 (gradient sharding): the accumulator itself lives in the
    # PartitionedLayout — every microbatch's gradient is reduce-scattered
    # and only the local 1/W shard accumulates, so the full gradient is
    # never resident.  The trade: one RS per bucket per MICROBATCH (vs one
    # per boundary for ZeRO-1) against a W× smaller accumulator — exactly
    # the wire-vs-memory axis the launch planner costs.

    def init_accum_partitioned(self, play: PartitionedLayout):
        """Zeroed 1/W shard-bucket f32 accumulator (ZeRO-2)."""
        lead = play.layout.lead_shape
        return [jnp.zeros(lead + (n,), jnp.float32)
                for n in play.shard_sizes]

    def accumulate_partitioned(self, acc, tree, play: PartitionedLayout):
        """acc + reduce_scatter_mean(tree): the shard-space microbatch
        add of ZeRO-2.  Accumulates per-microbatch cross-worker MEANS, so
        the boundary divides by accum_steps only.  Returns
        (shard_buckets, metrics); the metrics charge the RS half of the
        partitioned exchange (the boundary all-gather is charged by
        ``unpartition``'s caller)."""
        gb = self._pad_buckets(play.layout.bucketize(tree), play)
        shards, _ = self.exchange_partitioned_accumulated(gb, play)
        return ([a + s for a, s in zip(acc, shards)],
                self.metrics(self.flat_bytes(play.layout) / 2.0))

    # -- fused exchanges ----------------------------------------------------
    def exchange(self, grads, residual=None, compressor=None, events=1.0):
        """Fused all-mean of ``grads`` with optional compression + error
        feedback.  Returns (mean_tree, new_residual_tree, metrics)."""
        lay = self.layout(grads)
        return self.exchange_accumulated(lay.bucketize(grads), lay,
                                         residual=residual,
                                         compressor=compressor, events=events)

    def exchange_accumulated(self, buckets, lay: BucketLayout, residual=None,
                             compressor=None, events=1.0):
        """The exchange of ``exchange`` starting from flat f32 buckets
        (e.g. a microbatch accumulator) instead of a tree.  Exactly one
        collective per bucket fires here — the microbatch loop that built
        ``buckets`` issued none.  Returns (mean_tree, new_residual_tree,
        metrics)."""
        if compressor is None or compressor.name == "none":
            out = (self._reduce_narrow_sharded(buckets, mean=True)
                   if self._narrow_sharded
                   else self.comm.all_mean(self._wire_cast(buckets)))
            return (lay.debucketize(out), residual,
                    self.metrics(self.flat_bytes(lay), events))
        rb = lay.bucketize(residual)
        g_out, r_out = [], []
        for g, r in zip(buckets, rb):
            mean, _, new_r = self._bucket_ef_round(g, r, compressor)
            g_out.append(mean)
            r_out.append(new_r)
        return (lay.debucketize(g_out),
                lay.debucketize(r_out, cast=False),
                self.metrics(self.wire_bytes(lay, compressor), events))

    def exchange_dgc(self, grads, state, compressor, momentum: float = 0.9,
                     events=1.0):
        """Fused all-mean with DGC momentum correction (Lin et al. [54]):
        velocity accumulates into the residual before top-k, and whatever
        was sent leaves both accumulators.  ``state`` = {"velocity",
        "residual"} param-shaped f32 trees."""
        lay = self.layout(grads)
        gb = lay.bucketize(grads)
        ub = lay.bucketize(state["velocity"])
        rb = lay.bucketize(state["residual"])
        g_out, u_out, r_out = [], [], []
        for g, u, r in zip(gb, ub, rb):
            u1 = momentum * u + g
            mean, sent, new_r = self._bucket_ef_round(u1, r, compressor)
            mask = (sent != 0).astype(jnp.float32)
            g_out.append(mean)
            u_out.append(u1 * (1 - mask))
            r_out.append(new_r)
        new_state = {"velocity": lay.debucketize(u_out, cast=False),
                     "residual": lay.debucketize(r_out, cast=False)}
        return (lay.debucketize(g_out), new_state,
                self.metrics(self.wire_bytes(lay, compressor), events))

    # -- partitioned (ZeRO-1) exchange --------------------------------------
    def partitioned_layout(self, tree) -> PartitionedLayout:
        return PartitionedLayout.build(self.layout(tree), self.comm.size)

    def _pad_buckets(self, buckets, play: PartitionedLayout):
        out = []
        for b, p in zip(buckets, play.padded_sizes):
            n = b.shape[-1]
            out.append(b if n == p else jnp.pad(
                b, [(0, 0)] * (b.ndim - 1) + [(0, p - n)]))
        return out

    def shard_params(self, tree, play: Optional[PartitionedLayout] = None):
        """This worker's 1/W shard of each (replicated) flat f32 bucket —
        a local slice, no collective.  Feeds ``Optimizer.init``/``update``
        with shard buckets; the optimizer state built from them is the
        ZeRO-1 sharded state."""
        play = play or self.partitioned_layout(tree)
        buckets = self._pad_buckets(play.layout.bucketize(tree), play)
        return self.comm.shard_chunk(buckets)

    def exchange_partitioned(self, grads,
                             play: Optional[PartitionedLayout] = None,
                             events=1.0):
        """Fused reduce-scatter mean: every worker receives ONLY its own
        1/W shard of the cross-worker mean gradient — one reduce-scatter
        per bucket.  Returns (shard_buckets, metrics).  Together with the
        all-gather in ``unpartition`` this ships the same ring bytes as the
        dense all-reduce of ``exchange`` (2·N·(W−1)/W per worker)."""
        play = play or self.partitioned_layout(grads)
        gb = self._pad_buckets(play.layout.bucketize(grads), play)
        return self.exchange_partitioned_accumulated(gb, play, events=events)

    def exchange_partitioned_accumulated(self, buckets,
                                         play: PartitionedLayout,
                                         events=1.0):
        """``exchange_partitioned`` starting from PADDED flat f32 buckets
        (the microbatch accumulator built with ``init_accum(lay, play)`` /
        ``accumulate(..., play=play)``): one reduce-scatter per bucket at
        the boundary, nothing per microbatch.  Returns (shard_buckets,
        metrics)."""
        gb = buckets
        if self._narrow_sharded:
            # narrow wire with f32 ring accumulation, HLO-provably: the
            # reduction is decomposed into ONE all-to-all of the narrowed
            # chunks per bucket (identical ring bytes to a reduce-scatter)
            # plus a local f32 accumulate — a bf16 reduce-scatter would be
            # silently convert-promoted back to an f32 wire by XLA.
            narrowed = [b.astype(self.wire_dtype) for b in gb]
            stacked = self.comm.gather_chunks(narrowed)  # (W, C) per bucket
            shards = [jnp.sum(s.astype(jnp.float32), axis=0)
                      / self.comm.size for s in stacked]
        else:
            # f32 wire (or the stacked simulator, whose _wire_cast already
            # rounds to the wire dtype and upcasts so the axis reduction
            # accumulates in f32 — same semantics as the a2a path)
            shards = self.comm.reduce_scatter(self._wire_cast(gb), mean=True)
            if self.wire_dtype != jnp.float32:
                shards = [s.astype(jnp.float32) for s in shards]
        return shards, self.metrics(self.flat_bytes(play.layout), events)

    def unpartition(self, shards, play: PartitionedLayout):
        """All-gather updated shards back into the full tree — one tiled
        all-gather per bucket (of ``wire_dtype`` buffers: the gathered
        params are the wire-dtype image of the f32 master shards), padding
        sliced away, leaf dtypes restored."""
        shards = self._wire_cast(shards)
        if self._narrow_sharded:
            # pin the narrow wire THROUGH the gather: XLA convert-promotes
            # a bf16 all-gather back to an f32 one, so gather the bitcast
            # uint16 image instead — dtype-exact data movement, the same
            # trick as the packed uint8 compressed wire
            full = self.comm.all_gather(self._bitcast_u16(shards),
                                        tiled=True)
            full = [lax.bitcast_convert_type(b, self.wire_dtype)
                    for b in full]
        else:
            full = self.comm.all_gather(shards, tiled=True)
        full = [lax.slice_in_dim(b, 0, n, axis=b.ndim - 1)
                for b, n in zip(full, play.layout.bucket_sizes)]
        return play.layout.debucketize(full)

    def compress(self, grads, residual, compressor):
        """Error-feedback compression WITHOUT a collective (for strategies
        that buffer/accumulate before communicating, e.g. SSP/Downpour).
        Returns (g_hat_tree, new_residual_tree, packed_bytes_one_send)."""
        lay = self.layout(grads)
        if compressor is None or compressor.name == "none":
            return grads, residual, self.flat_bytes(lay)
        gb = lay.bucketize(grads)
        rb = lay.bucketize(residual)
        g_out, r_out = [], []
        for g, r in zip(gb, rb):
            fe = compressor.fused_encode if self.fused else None
            if fe is None:
                t = g + r
                dec = self._self_decode(t, compressor)
                g_out.append(dec)
                r_out.append(t - dec)
                continue
            arrs, widen, new_r = fe(g, r)
            n = g.shape[-1]
            dec = self._vmap_replicas(
                lambda a, widen=widen, n=n: compressor.decompress(
                    widen(a), None, (n,), jnp.float32))(arrs)
            g_out.append(dec)
            r_out.append(new_r)
        return (lay.debucketize(g_out),
                lay.debucketize(r_out, cast=False),
                self.wire_bytes(lay, compressor))
