"""The communication-completeness spectrum (paper §3) as executable
strategies.

Spectrum point → strategy:
  1. synchronous (large mini-batch)        → ``sync``
  1z. sync + partitioned opt state (ZeRO-1) → ``sync_zero1``  (same wire
     bytes as ``sync``, O(N/W) per-worker optimizer state)
  2. complete, bounded delay               → ``ssp``        (stale-synchronous)
  3. complete, unbounded delay             → ``downpour``   (decentralized
     realization of the parameter-server semantics; see DESIGN.md §2 for why
     the central server is deliberately not built)
  4. partial communication                 → ``gossip``     (ring mixing:
     non-neighbor updates are *never* delivered directly)
  +. model averaging (paper §2.2.3)        → ``local_sgd``
  +. hierarchical (beyond-paper)           → ``hierarchical`` (complete
     within the fast tier × partial across the slow tier)

Every strategy is written against the ``Comm`` interface and therefore runs
both in the stacked-replica simulator (LocalComm) and under shard_map on a
real mesh (ShardComm).  Asynchrony is *logical*: per-worker schedules are
explicit, deterministic state — the faithful SPMD realization of the paper's
delivery-order analysis (Figure 3).

All tensor moving goes through the bucketed ``Fabric`` (core/fabric.py,
DESIGN.md §3): collectives run once per size-capped flat bucket instead of
once per parameter leaf, compression applies to the flat buffer, and the
``wire_bytes`` metric is the exact packed wire size (not an analytic
bits-per-element estimate).  ``bucket_bytes`` on every strategy factory
tunes the fusion granularity."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.comm import Comm, HierComm
from repro.core.compression import Compressor, dgc_init, ef_init
from repro.core.fabric import DEFAULT_BUCKET_BYTES, Fabric
from repro.core.precision import PrecisionPolicy
from repro.optim.optimizers import Optimizer


@dataclass(frozen=True)
class Strategy:
    name: str
    spectrum_point: int  # 1..4 per the paper's §3 taxonomy
    complete: bool  # does every update eventually reach every worker?
    init: Callable  # (params, comm) -> comm_state
    update: Callable  # (params, grads, opt_state, comm_state, t, optimizer, comm)
    #                 -> (params, opt_state, comm_state, metrics)
    init_opt: Optional[Callable] = None  # (params, optimizer, comm) ->
    #                 opt_state; strategies that OWN the optimizer-state
    #                 layout (ZeRO-1 shard buckets) override the default
    #                 optimizer.init(params) in train/loop.init_train_state.
    owns_master: bool = False  # the wider master copy of the params lives
    #                 INSIDE this strategy's opt_state (ZeRO-1 shard
    #                 buckets) — the train loop must NOT keep its own.
    exchange_at_boundary: bool = True  # DECLARATIVE metadata (read by
    #                 tests/tooling, not by the train loop — boundary-only
    #                 behavior is structural: the loop calls ``update``
    #                 exactly once per accumulation boundary with the
    #                 OPTIMIZER step ``t``, whatever this says).  True:
    #                 every ``update`` ships the gradient exchange of its
    #                 call exactly once, so under microbatch accumulation
    #                 (DESIGN.md §8) wire bytes per sample shrink by
    #                 accum_steps.  False: a local-step strategy
    #                 (local_sgd / easgd / ssp / downpour / gossip) whose
    #                 own ``sync_every``-style schedule — counted in
    #                 optimizer steps, never microbatches — decides when
    #                 to communicate.
    wire_profile: str = "dense"  # DECLARATIVE: the HLO collective shape
    #                 ONE exchange lowers to, in the vocabulary of
    #                 ``Fabric.collective_contract`` (dense / partitioned /
    #                 compressed / ring / none).  ``repro.analysis`` lints
    #                 the compiled program against this claim.
    gated: bool = False  # DECLARATIVE: the exchange is schedule-gated via
    #                 ``_gate`` — with a traced step counter every
    #                 collective must sit under a ``lax.cond`` branch
    #                 (the cond-gating lint rule), and wire bytes scale
    #                 by ~1/sync_every.
    sync_every: int = 1  # the gating period (optimizer steps) when
    #                 ``gated``; 1 ⇒ communicates at every update call.
    wire_events: int = 1  # collective rounds per exchange event (ring
    #                 gossip: 2 hops when symmetric).
    owns_params: bool = False  # ZeRO-3: the train state's ``params`` entry
    #                 holds this worker's 1/W flat f32 SHARD BUCKETS, not
    #                 the full tree — the loop must ``gather_params`` the
    #                 full (transient) parameters before forward/backward
    #                 and hand the shard buckets to ``update``.
    init_params: Optional[Callable] = None  # (params, comm) -> shard
    #                 buckets; called by init_train_state when
    #                 ``owns_params`` to shard the freshly-initialized
    #                 full tree (and to record the partition layout the
    #                 strategy's other hooks close over).
    gather_params: Optional[Callable] = None  # (shards, comm) -> full
    #                 params tree: the per-step bucket all-gather of
    #                 ZeRO-3 (wire-dtype image; freed after the step —
    #                 inside jit the gathered tree is a temp, never state).
    partitioned_accum: bool = False  # ZeRO-2/3: microbatch gradients are
    #                 reduce-scattered into the PartitionedLayout as they
    #                 are produced (Fabric.accumulate_partitioned), so the
    #                 accumulator is 1/W and the full gradient is NEVER
    #                 materialized.  The boundary then calls
    #                 ``update_partitioned`` with the accumulated shard
    #                 buckets instead of ``update`` with a full tree.
    update_partitioned: Optional[Callable] = None  # (params_or_shards,
    #                 g_shard_buckets, opt_state, comm_state, t, optimizer,
    #                 comm) -> (params_or_shards, opt_state, comm_state,
    #                 metrics): the boundary step of the partitioned-accum
    #                 path — gradients arrive already reduce-scattered.

    # Contract: ``update`` must treat ``comm_state`` as immutable and
    # return a FRESH mapping — callers re-step from saved state (resume,
    # speculative steps), so writing into the argument would corrupt it.


def _fab(comm: Comm, bucket_bytes: int,
         policy: Optional[PrecisionPolicy]) -> Fabric:
    """Fabric with the policy's wire dtype (f32 when no policy)."""
    return Fabric(comm, bucket_bytes,
                  wire_dtype=policy.wire_dt if policy is not None else None)


def _events(flag):
    """Traced or python bool → f32 event count."""
    return flag.astype(jnp.float32) if hasattr(flag, "astype") else float(flag)


def _gate(flag, sync_fn, operand):
    """Run ``sync_fn`` (which issues collectives) only on sync steps.

    A static schedule flag prunes at trace time (the non-sync trace has NO
    collective at all); a traced flag becomes ``lax.cond`` — ``t`` is
    replicated, every shard takes the same branch, and the collective
    executes 1/sync_every of the steps instead of running every step and
    being discarded through ``jnp.where``."""
    if isinstance(flag, (bool, int)):  # static: prune the dead branch
        return sync_fn(operand) if flag else operand
    return lax.cond(flag, sync_fn, lambda o: o, operand)


def _zero_metrics():
    return {"wire_bytes": jnp.zeros((), jnp.float32),
            "comm_events": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# 1. synchronous — large mini-batch all-reduce (bucket-fused)
# ---------------------------------------------------------------------------
def sync(compressor: Optional[Compressor] = None,
         bucket_bytes: int = DEFAULT_BUCKET_BYTES,
         policy: Optional[PrecisionPolicy] = None) -> Strategy:
    def init(params, comm):
        return {"residual": ef_init(params)} if compressor else {}

    def update(params, grads, opt_state, cstate, t, opt: Optimizer, comm: Comm):
        fab = _fab(comm, bucket_bytes, policy)
        g, new_res, m = fab.exchange(grads, cstate.get("residual"), compressor)
        if compressor:
            cstate = {"residual": new_res}
        params, opt_state = opt.update(g, opt_state, params, t)
        return params, opt_state, cstate, m

    return Strategy("sync", 1, True, init, update,
                    wire_profile="compressed" if compressor else "dense")


# ---------------------------------------------------------------------------
# 1z. synchronous + partitioned optimizer state (ZeRO-1)
# ---------------------------------------------------------------------------
def sync_zero1(bucket_bytes: int = DEFAULT_BUCKET_BYTES,
               policy: Optional[PrecisionPolicy] = None) -> Strategy:
    """Spectrum point 1 with sharded-optimizer data parallelism (ZeRO-1,
    Rajbhandari et al.): each flat f32 bucket is reduce-SCATTERED so worker
    w owns only chunk w of the mean gradient, updates its 1/W shard of the
    parameters against 1/W of the optimizer state, and the updated shards
    are all-gathered back into the full replicated parameters.

    Wire bytes per step equal the dense all-reduce (reduce-scatter +
    all-gather = one ring all-reduce), but per-worker optimizer-state
    memory drops from O(N) to O(N/W) — the memory-bound lever of the
    paper's large-mini-batch regime (§2).  Numerically equivalent to
    ``sync`` with full state: the same mean reaches the same elementwise
    update, only the ownership of the state is partitioned.

    Under a master-keeping precision policy (bf16 working params, f32
    master — core/precision.py) the f32 master rides the partitioned
    opt-state shard: ``opt_state = {"opt": <inner state>, "master":
    <1/W f32 shard buckets>}``.  The update then runs f32 master math
    against the reduce-scattered (bf16-wire, f32-accumulated) gradient
    shards and all-gathers the bf16 image of the new master back into the
    replicated params — per-worker master cost O(N/W), wire cost halved."""

    keeps_master = policy is not None and policy.keeps_master

    def init(params, comm):
        return {}

    def init_opt(params, opt: Optimizer, comm: Comm):
        # optimizer state over THIS worker's shard buckets: 1/W of the
        # dense footprint per worker (tested in tests/test_zero1.py)
        fab = _fab(comm, bucket_bytes, policy)
        shards = fab.shard_params(params)  # flat f32 shard buckets
        inner = opt.init(shards)
        if keeps_master:
            return {"opt": inner, "master": shards}
        return inner

    def update(params, grads, opt_state, cstate, t, opt: Optimizer,
               comm: Comm):
        fab = _fab(comm, bucket_bytes, policy)
        play = fab.partitioned_layout(params)
        g_shards, m = fab.exchange_partitioned(grads, play)
        if keeps_master:
            inner, p_shards = opt_state["opt"], opt_state["master"]
        else:
            inner, p_shards = opt_state, fab.shard_params(params, play)
        p_shards, inner = opt.update(g_shards, inner, p_shards, t)
        params = fab.unpartition(p_shards, play)
        new_state = {"opt": inner, "master": p_shards} if keeps_master \
            else inner
        return params, new_state, cstate, m

    return Strategy("sync_zero1", 1, True, init, update, init_opt,
                    owns_master=keeps_master, wire_profile="partitioned")


# ---------------------------------------------------------------------------
# 1z2. ZeRO-2: gradient sharding on top of the partitioned optimizer state
# ---------------------------------------------------------------------------
def sync_zero2(bucket_bytes: int = DEFAULT_BUCKET_BYTES,
               policy: Optional[PrecisionPolicy] = None) -> Strategy:
    """ZeRO-1 plus gradient sharding (Rajbhandari et al. stage 2): under
    microbatch accumulation the gradient of EVERY microbatch is
    reduce-scattered into the ``PartitionedLayout`` as it is produced
    (``Fabric.accumulate_partitioned``), so the accumulator holds 1/W
    shard buckets and the full gradient is never materialized — the
    accumulator memory of DESIGN.md §8 shrinks by W.

    The trade the planner (launch/planner.py) prices: one reduce-scatter
    per bucket per MICROBATCH (accum_steps × N·(W−1)/W ring bytes per
    boundary, vs one RS for ZeRO-1) against the W× accumulator shrink.
    At ``accum_steps=1`` the wire and the numerics degenerate exactly to
    ``sync_zero1``: one RS + one AG per boundary."""

    keeps_master = policy is not None and policy.keeps_master
    z1 = sync_zero1(bucket_bytes=bucket_bytes, policy=policy)

    def update_partitioned(params, g_shards, opt_state, cstate, t,
                           opt: Optimizer, comm: Comm):
        # boundary of the partitioned-accum scan: gradients arrive as
        # already-reduced 1/W shard buckets — only the shard update and
        # the param all-gather remain.
        fab = _fab(comm, bucket_bytes, policy)
        play = fab.partitioned_layout(params)
        if keeps_master:
            inner, p_shards = opt_state["opt"], opt_state["master"]
        else:
            inner, p_shards = opt_state, fab.shard_params(params, play)
        p_shards, inner = opt.update(g_shards, inner, p_shards, t)
        params = fab.unpartition(p_shards, play)
        new_state = {"opt": inner, "master": p_shards} if keeps_master \
            else inner
        m = fab.metrics(fab.flat_bytes(play.layout) / 2.0)  # the AG half
        return params, new_state, cstate, m

    return Strategy("sync_zero2", 1, True, z1.init, z1.update, z1.init_opt,
                    owns_master=keeps_master, wire_profile="partitioned",
                    partitioned_accum=True,
                    update_partitioned=update_partitioned)


# ---------------------------------------------------------------------------
# 1z3. ZeRO-3: parameter sharding — the train state holds 1/W of the model
# ---------------------------------------------------------------------------
def sync_zero3(bucket_bytes: int = DEFAULT_BUCKET_BYTES,
               policy: Optional[PrecisionPolicy] = None) -> Strategy:
    """Full ZeRO (stage 3): parameters, gradients AND optimizer state are
    partitioned.  The train state's ``params`` are this worker's flat f32
    shard buckets (W× smaller than the replicated tree — the
    ``step_state_peak_bytes`` shrink in roofline/analysis.py); the loop
    all-gathers the full parameters per step via ``gather_params`` (one
    tiled wire-dtype all-gather per bucket, freed after forward/backward),
    reduce-scatters the gradients, and the elementwise optimizer updates
    the shards in place.

    Numerics are bitwise-equal to ``sync``: the reduce-scattered mean is
    the same floats as slicing the all-reduced mean, the optimizers are
    elementwise (optim/optimizers.py), and ``unpartition`` reconstructs
    the exact concatenation — tested in tests/test_zero23.py.

    The f32 shard buckets double as the precision master — under a
    master-keeping policy no separate master copy exists (``owns_master``),
    and the per-step gather ships the wire-dtype (bf16-halved) image."""

    keeps_master = policy is not None and policy.keeps_master
    box = {}  # partition layout, recorded by init_params (static pytree
    #           metadata — read at trace time, never traced)

    def _fab_play(comm, tree=None):
        fab = _fab(comm, bucket_bytes, policy)
        play = box.get("play")
        if play is None and tree is not None:
            play = fab.partitioned_layout(tree)
        return fab, play

    def init(params, comm):
        return {}

    def init_params(params, comm):
        fab = _fab(comm, bucket_bytes, policy)
        play = fab.partitioned_layout(params)
        box["play"] = play
        return fab.shard_params(params, play)  # flat f32 shard buckets

    def gather_params(shards, comm):
        fab, play = _fab_play(comm)
        return fab.unpartition(shards, play)

    def init_opt(p_shards, opt: Optimizer, comm: Comm):
        # ``init_train_state`` hands the SHARD BUCKETS produced by
        # init_params — the optimizer state is shard-shaped by
        # construction, no separate sharding step.
        return opt.init(p_shards)

    def update(p_shards, grads, opt_state, cstate, t, opt: Optimizer,
               comm: Comm):
        # grads: the full per-worker tree from backward over the gathered
        # params (same structure/dtypes as the params tree, so its
        # partitioned layout IS the param layout).
        fab, play = _fab_play(comm, grads)
        g_shards, m = fab.exchange_partitioned(grads, play)
        p_shards, opt_state = opt.update(g_shards, opt_state, p_shards, t)
        return p_shards, opt_state, cstate, m

    def update_partitioned(p_shards, g_shards, opt_state, cstate, t,
                           opt: Optimizer, comm: Comm):
        # ZeRO-2 accumulation path on top: gradients arrive as reduced
        # shard buckets; only the elementwise shard update remains (the
        # param gather of the NEXT step is the AG half of the wire).
        fab = _fab(comm, bucket_bytes, policy)
        p_shards, opt_state = opt.update(g_shards, opt_state, p_shards, t)
        play = box.get("play")
        nb = fab.flat_bytes(play.layout) / 2.0 if play is not None else 0.0
        return p_shards, opt_state, cstate, fab.metrics(nb)

    return Strategy("sync_zero3", 1, True, init, update, init_opt,
                    owns_master=keeps_master, wire_profile="partitioned",
                    owns_params=True, init_params=init_params,
                    gather_params=gather_params, partitioned_accum=True,
                    update_partitioned=update_partitioned)


# ---------------------------------------------------------------------------
# +. local SGD / model averaging (paper §2.2.3)
# ---------------------------------------------------------------------------
def local_sgd(sync_every: int = 8,
              compressor: Optional[Compressor] = None,
              bucket_bytes: int = DEFAULT_BUCKET_BYTES,
              policy: Optional[PrecisionPolicy] = None) -> Strategy:
    def init(params, comm):
        return {}

    def update(params, grads, opt_state, cstate, t, opt, comm):
        fab = _fab(comm, bucket_bytes, policy)
        params, opt_state = opt.update(grads, opt_state, params, t)
        do_avg = (t + 1) % sync_every == 0
        params = _gate(do_avg, fab.all_mean, params)
        m = fab.metrics(fab.flat_bytes(params), events=_events(do_avg))
        return params, opt_state, cstate, m

    return Strategy("local_sgd", 2, True, init, update,
                    exchange_at_boundary=False,
                    gated=True, sync_every=sync_every)


# ---------------------------------------------------------------------------
# 1b. sync + Deep Gradient Compression (momentum correction, [54])
# ---------------------------------------------------------------------------
def sync_dgc(compressor: Compressor, momentum: float = 0.9,
             bucket_bytes: int = DEFAULT_BUCKET_BYTES,
             policy: Optional[PrecisionPolicy] = None) -> Strategy:
    """Synchronous exchange of momentum-corrected sparsified gradients:
    velocity (not raw gradient) is accumulated into the residual, so
    sparsified-away updates keep their momentum — the [54] refinement of
    plain error feedback.  Runs on the flat buckets."""

    def init(params, comm):
        return {"dgc": dgc_init(params)}

    def update(params, grads, opt_state, cstate, t, opt, comm):
        fab = _fab(comm, bucket_bytes, policy)
        g, new_dgc, m = fab.exchange_dgc(grads, cstate["dgc"],
                                         compressor, momentum)
        params, opt_state = opt.update(g, opt_state, params, t)
        return params, opt_state, {"dgc": new_dgc}, m

    return Strategy("sync_dgc", 1, True, init, update,
                    wire_profile="compressed")


# ---------------------------------------------------------------------------
# +. elastic averaging SGD (paper §2.2.3 via [50], Zhang/Choromanska/LeCun)
# ---------------------------------------------------------------------------
def easgd(alpha: float = 0.1, sync_every: int = 4,
          bucket_bytes: int = DEFAULT_BUCKET_BYTES,
          policy: Optional[PrecisionPolicy] = None) -> Strategy:
    """Workers are elastically attracted to a (replicated) center variable;
    the center moves toward the worker average.  Model averaging with a
    spring instead of a hard reset — complete communication, point 2-ish."""

    def init(params, comm):
        def center(p):
            if comm.lead_axes:  # stacked simulator: common center, full shape
                # average over the axis THIS comm reduces (≠ lead_axes-1 for
                # the outer tier of a hierarchy)
                ax = getattr(comm, "axis", comm.lead_axes - 1)
                return jnp.mean(p.astype(jnp.float32), axis=ax,
                                keepdims=True) + jnp.zeros_like(p, jnp.float32)
            return p.astype(jnp.float32)
        return {"center": jax.tree.map(center, params)}

    def update(params, grads, opt_state, cstate, t, opt, comm):
        fab = _fab(comm, bucket_bytes, policy)
        params, opt_state = opt.update(grads, opt_state, params, t)
        do = (t + 1) % sync_every == 0

        def attract(args):
            p, c = args
            diff = jax.tree.map(lambda p_, c_: p_.astype(jnp.float32) - c_,
                                p, c)
            new_c = jax.tree.map(lambda c_, d: c_ + alpha * d,
                                 c, fab.all_mean(diff))
            new_p = jax.tree.map(
                lambda p_, d: (p_.astype(jnp.float32)
                               - alpha * d).astype(p_.dtype), p, diff)
            return new_p, new_c

        params, center = _gate(do, attract, (params, cstate["center"]))
        m = fab.metrics(fab.flat_bytes(params), events=_events(do))
        return params, opt_state, {"center": center}, m

    return Strategy("easgd", 2, True, init, update,
                    exchange_at_boundary=False,
                    gated=True, sync_every=sync_every)


# ---------------------------------------------------------------------------
# 2. stale-synchronous — complete communication, bounded delay s
# ---------------------------------------------------------------------------
def ssp(staleness: int = 4, compressor: Optional[Compressor] = None,
        staleness_aware_lr: bool = False,
        bucket_bytes: int = DEFAULT_BUCKET_BYTES,
        policy: Optional[PrecisionPolicy] = None) -> Strategy:
    """``staleness_aware_lr`` (Zhang et al. [40]): stale contributions are
    scaled by 1/s — the staleness-dependent learning-rate modulation."""
    s = max(1, staleness)

    def init(params, comm):
        def ring(p):
            return jnp.zeros((s,) + p.shape, jnp.float32)

        st = {"buf": jax.tree.map(ring, params)}
        if compressor:
            st["residual"] = ef_init(params)
        return st

    def update(params, grads, opt_state, cstate, t, opt, comm):
        fab = _fab(comm, bucket_bytes, policy)
        new_c = dict(cstate)
        if compressor:
            grads, new_c["residual"], nbytes = fab.compress(
                grads, cstate["residual"], compressor)
        else:
            nbytes = fab.flat_bytes(grads)
        slot = t % s
        g_old = jax.tree.map(lambda b: b[slot], cstate["buf"])  # g_{t-s}
        others_old = jax.tree.map(
            lambda a, b: a - b, fab.all_sum(g_old), g_old)
        w = comm.size
        stale_scale = 1.0 / s if staleness_aware_lr else 1.0
        g_eff = jax.tree.map(
            lambda g, o: (g.astype(jnp.float32) + stale_scale * o) / w,
            grads, others_old)
        params, opt_state = opt.update(g_eff, opt_state, params, t)
        new_c["buf"] = jax.tree.map(
            lambda b, g: b.at[slot].set(g.astype(jnp.float32)),
            cstate["buf"], grads)
        return params, opt_state, new_c, fab.metrics(nbytes)

    return Strategy("ssp", 2, True, init, update,
                    exchange_at_boundary=False)


# ---------------------------------------------------------------------------
# 3. downpour — complete communication, unbounded(-class) delay
# ---------------------------------------------------------------------------
def downpour(push_every: int = 4,
             compressor: Optional[Compressor] = None,
             bucket_bytes: int = DEFAULT_BUCKET_BYTES,
             policy: Optional[PrecisionPolicy] = None) -> Strategy:
    """Decentralized Downpour: workers accumulate locally and push on
    staggered schedules; every update is eventually delivered everywhere
    (complete).  Staggering makes deliveries interleave asynchronously —
    the paper's point-3 regime without the parameter-server bottleneck."""

    def init(params, comm):
        st = {"acc": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}
        if compressor:
            st["residual"] = ef_init(params)
        return st

    def update(params, grads, opt_state, cstate, t, opt, comm):
        fab = _fab(comm, bucket_bytes, policy)
        new_c = dict(cstate)
        if compressor:
            grads, new_c["residual"], nbytes = fab.compress(
                grads, cstate["residual"], compressor)
        else:
            nbytes = fab.flat_bytes(grads)
        w = comm.size
        offset = comm.worker_index()  # (W,) under LocalComm, scalar shard
        push = ((t + offset) % push_every == 0)

        def bcast(flag, x):
            return flag.reshape(flag.shape + (1,) * (x.ndim - flag.ndim)) \
                if hasattr(flag, "ndim") and flag.ndim and flag.ndim < x.ndim else flag

        acc_plus = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), cstate["acc"], grads)
        deliver = jax.tree.map(
            lambda a: jnp.where(bcast(push, a), a, 0.0), acc_plus)
        recv = jax.tree.map(lambda s_, d: s_ - d, fab.all_sum(deliver), deliver)
        g_eff = jax.tree.map(
            lambda g, r: (g.astype(jnp.float32) + r) / w, grads, recv)
        params, opt_state = opt.update(g_eff, opt_state, params, t)
        new_c["acc"] = jax.tree.map(
            lambda a: jnp.where(bcast(push, a), 0.0, a), acc_plus)
        # fleet-wide push fraction (a bare jnp.mean of a ShardComm flag is
        # that shard's 0/1 indicator): the staggered schedule is
        # deterministic in t, so every realization computes the same
        # number locally — no collective spent on a metric.
        sched = (t + jnp.arange(comm.size)) % push_every == 0
        ev = jnp.mean(sched.astype(jnp.float32))
        return params, opt_state, new_c, fab.metrics(nbytes, events=ev)

    return Strategy("downpour", 3, True, init, update,
                    exchange_at_boundary=False)


# ---------------------------------------------------------------------------
# 4. gossip — PARTIAL communication (ring mixing)
# ---------------------------------------------------------------------------
def gossip(mix_every: int = 1, symmetric: bool = True,
           compressor: Optional[Compressor] = None,
           bucket_bytes: int = DEFAULT_BUCKET_BYTES,
           policy: Optional[PrecisionPolicy] = None) -> Strategy:
    """Ring gossip on *weights* after the local step.  A worker only ever
    hears from its ring neighbors — updates from others are never directly
    delivered: the paper's point 4, where model consistency is genuinely
    given up (Statement 1 does not apply)."""

    def init(params, comm):
        return {}

    def update(params, grads, opt_state, cstate, t, opt, comm):
        fab = _fab(comm, bucket_bytes, policy)
        params, opt_state = opt.update(grads, opt_state, params, t)
        do_mix = (t + 1) % mix_every == 0

        def mix(p):
            left = fab.ppermute(p, shift=1)
            if symmetric:
                right = fab.ppermute(p, shift=-1)
                mixed = jax.tree.map(
                    lambda p_, l, r: (p_.astype(jnp.float32)
                                      + l.astype(jnp.float32)
                                      + r.astype(jnp.float32)) / 3.0,
                    p, left, right)
            else:
                mixed = jax.tree.map(
                    lambda p_, l: (p_.astype(jnp.float32)
                                   + l.astype(jnp.float32)) / 2.0, p, left)
            return jax.tree.map(lambda m_, p_: m_.astype(p_.dtype), mixed, p)

        params = _gate(do_mix, mix, params)
        ev = _events(do_mix) * (2.0 if symmetric else 1.0)
        m = fab.metrics(fab.flat_bytes(params), events=ev)
        return params, opt_state, cstate, m

    return Strategy("gossip", 4, False, init, update,
                    exchange_at_boundary=False, wire_profile="ring",
                    gated=True, sync_every=mix_every,
                    wire_events=2 if symmetric else 1)


# ---------------------------------------------------------------------------
# beyond-paper: hierarchical — complete inner tier × partial outer tier
# ---------------------------------------------------------------------------
def hierarchical(inner: Strategy, outer: Strategy) -> Strategy:
    """Compose: ``inner`` runs every step on the fast fabric (intra-pod),
    ``outer`` on the slow fabric (cross-pod).  The comm handed to update
    must be a HierComm; each tier builds its own bucketed Fabric over its
    own Comm (DESIGN.md §2)."""

    def init(params, comm: HierComm):
        return {"inner": inner.init(params, comm.inner),
                "outer": outer.init(params, comm.outer)}

    def update(params, grads, opt_state, cstate, t, opt, comm: HierComm):
        params, opt_state, c_in, m1 = inner.update(
            params, grads, opt_state, cstate["inner"], t, opt, comm.inner)
        noop = Optimizer(lambda p: {},
                         lambda g, s, p, tt: (p, s))
        zero_g = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), grads)
        params, _, c_out, m2 = outer.update(
            params, zero_g, {}, cstate["outer"], t, noop, comm.outer)
        m = {k: m1[k] + m2[k] for k in m1}
        return params, opt_state, {"inner": c_in, "outer": c_out}, m

    return Strategy(f"hier({inner.name}x{outer.name})",
                    4 if not outer.complete else inner.spectrum_point,
                    inner.complete and outer.complete, init, update,
                    exchange_at_boundary=(inner.exchange_at_boundary
                                          and outer.exchange_at_boundary))


REGISTRY = {
    "sync": sync,
    "sync_zero1": sync_zero1,
    "sync_zero2": sync_zero2,
    "sync_zero3": sync_zero3,
    "sync_dgc": sync_dgc,
    "local_sgd": local_sgd,
    "easgd": easgd,
    "ssp": ssp,
    "downpour": downpour,
    "gossip": gossip,
}


def get_strategy(name: str, **kw) -> Strategy:
    return REGISTRY[name](**kw)
