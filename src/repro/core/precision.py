"""End-to-end mixed-precision policy (DESIGN.md §4).

A ``PrecisionPolicy`` names the dtype of every float in the system:

    param_dtype    the working model weights (what forward consumes and the
                   fabric gathers/mixes on the wire)
    compute_dtype  matmul/activation compute inside the models (loss,
                   softmax and norm statistics ALWAYS accumulate in f32 —
                   models/layers.py, models/ssm.py, train/losses.py)
    wire_dtype     uncompressed exchange buffers on the Fabric
                   (core/fabric.py buckets; 2 bytes/element under bf16 —
                   composes with, never replaces, the 1bit/int8/topk
                   compressors which own their packed wire format)
    master_dtype   the optimizer's master copy of the weights.  When it is
                   wider than ``param_dtype`` a persistent master tree is
                   kept: in the train state for dense strategies, and as
                   1/W flat shard buckets INSIDE the partitioned optimizer
                   state for the ZeRO-1 paths (the master rides the shard,
                   so its footprint is O(N/W) per worker).

plus dynamic loss scaling: the loss is multiplied by ``scale`` before the
backward pass, gradients are unscaled in f32, and a step whose gradients
contain inf/nan is SKIPPED — params, optimizer state and comm state are
left untouched and the scale is halved; after ``growth_interval``
consecutive finite steps the scale doubles.

The ``f32`` policy is a strict no-op: every cast is identity and the
scaling machinery is disabled, so f32 training stays bitwise-identical to
a policy-less run (tested in tests/test_precision.py).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

ALLOWED_DTYPES = ("float32", "bfloat16", "float16")


def _check_dtype(name: str, value: str):
    if value not in ALLOWED_DTYPES:
        raise ValueError(
            f"{name}={value!r} is not a supported precision dtype; "
            f"choose one of {ALLOWED_DTYPES}")


@dataclass(frozen=True)
class PrecisionPolicy:
    name: str = "f32"
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    wire_dtype: str = "float32"
    master_dtype: str = "float32"
    init_loss_scale: float = 1.0
    dynamic_scale: bool = False
    growth_interval: int = 200

    def __post_init__(self):
        for f in ("param_dtype", "compute_dtype", "wire_dtype",
                  "master_dtype"):
            _check_dtype(f, getattr(self, f))

    # -- dtype accessors ----------------------------------------------------
    @property
    def param_dt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def compute_dt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def wire_dt(self):
        return jnp.dtype(self.wire_dtype)

    @property
    def master_dt(self):
        return jnp.dtype(self.master_dtype)

    # -- behaviour flags ----------------------------------------------------
    @property
    def uses_scaling(self) -> bool:
        return self.dynamic_scale or self.init_loss_scale != 1.0

    @property
    def keeps_master(self) -> bool:
        """A persistent wider master copy of the params is required."""
        return self.master_dt != self.param_dt

    @property
    def narrow_wire(self) -> bool:
        """True when uncompressed exchange buffers ship at 2 bytes/elt —
        the condition under which the promotion-proof lint rule
        (repro.analysis) applies: no f32 wire collective may survive
        compilation on a sharded realization."""
        return self.wire_dt.itemsize == 2

    @property
    def is_noop(self) -> bool:
        """True when the policy changes nothing vs. policy-less f32."""
        f32 = jnp.dtype(jnp.float32)
        return (self.param_dt == f32 and self.compute_dt == f32
                and self.wire_dt == f32 and self.master_dt == f32
                and not self.uses_scaling)

    # -- tree casts (float leaves only; identity when dtypes match) ---------
    def cast_to_param(self, tree):
        return cast_floats(tree, self.param_dt)

    def cast_to_compute(self, tree):
        return cast_floats(tree, self.compute_dt)

    def cast_to_master(self, tree):
        return cast_floats(tree, self.master_dt)

    # -- serialization (checkpoint meta) ------------------------------------
    def spec(self) -> dict:
        return dataclasses.asdict(self)


def policy_from_spec(spec: dict) -> PrecisionPolicy:
    return PrecisionPolicy(**spec)


def cast_floats(tree, dtype):
    """Cast every floating leaf of ``tree`` to ``dtype`` (ints untouched)."""
    dtype = jnp.dtype(dtype)

    def one(x):
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
            return jnp.asarray(x).astype(dtype)
        return x

    return jax.tree.map(one, tree)


POLICIES = {
    # pure f32: the bitwise-identical default
    "f32": PrecisionPolicy("f32"),
    # mixed bf16: bf16 weights/compute/wire, f32 master + dynamic scaling.
    # The initial scale is a power of two so scaling never perturbs bf16
    # mantissas — only guards true overflow.
    "bf16": PrecisionPolicy(
        "bf16", param_dtype="bfloat16", compute_dtype="bfloat16",
        wire_dtype="bfloat16", master_dtype="float32",
        init_loss_scale=float(2 ** 15), dynamic_scale=True),
    # pure bf16: no master, no scaling — minimum memory, lowest fidelity
    "bf16-pure": PrecisionPolicy(
        "bf16-pure", param_dtype="bfloat16", compute_dtype="bfloat16",
        wire_dtype="bfloat16", master_dtype="bfloat16"),
}


def get_policy(policy) -> PrecisionPolicy:
    """None → f32; a name → registry lookup; a policy → itself."""
    if policy is None:
        return POLICIES["f32"]
    if isinstance(policy, PrecisionPolicy):
        return policy
    if policy not in POLICIES:
        raise KeyError(f"unknown precision policy {policy!r}; "
                       f"have {sorted(POLICIES)}")
    return POLICIES[policy]


def apply_policy(cfg, policy):
    """ModelConfig with the policy's param/compute dtypes applied."""
    policy = get_policy(policy)
    return dataclasses.replace(cfg, param_dtype=policy.param_dtype,
                               compute_dtype=policy.compute_dtype)


# ---------------------------------------------------------------------------
# dynamic loss scaling
# ---------------------------------------------------------------------------
def init_scale_state(policy: PrecisionPolicy) -> dict:
    """Loss-scale carry: {"scale", "good_steps"} (replicated scalars)."""
    return {"scale": jnp.asarray(policy.init_loss_scale, jnp.float32),
            "good_steps": jnp.zeros((), jnp.int32)}


def unscale_grads(grads, scale):
    """Gradients → f32, divided by the loss scale."""
    inv = 1.0 / jnp.asarray(scale, jnp.float32)
    return jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)


def tree_finite(tree):
    """Scalar bool: every element of every leaf is finite."""
    leaves = [jnp.all(jnp.isfinite(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.all(jnp.stack(leaves)) if leaves else jnp.asarray(True)


def next_scale_state(policy: PrecisionPolicy, sstate: dict, finite) -> dict:
    """Overflow → halve (never below 1) and reset the streak; a finite
    step extends the streak and every ``growth_interval``-th doubles."""
    scale, good = sstate["scale"], sstate["good_steps"]
    finite = jnp.asarray(finite)
    if not policy.dynamic_scale:  # static scale: still skip, never adapt
        return {"scale": scale,
                "good_steps": jnp.where(finite, good + 1, 0)}
    grow = finite & (good + 1 >= policy.growth_interval)
    new_scale = jnp.where(
        finite,
        jnp.where(grow, scale * 2.0, scale),
        jnp.maximum(scale * 0.5, 1.0))
    new_good = jnp.where(finite & ~grow, good + 1, 0)
    return {"scale": new_scale, "good_steps": new_good}


def select_tree(pred, on_true, on_false):
    """Elementwise where over two same-structure trees (the skip-step)."""
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b),
                        on_true, on_false)
