"""The FAST "tensor-moving interface" (paper §4), adapted to JAX/TPU.

The paper's central design move is to decouple *parallel coordination* from
*node-level execution* behind a general-purpose tensor-moving interface.
Here that interface is ``Comm``: strategies (core/strategies.py) are written
against it and run unchanged in two realizations:

  * ``LocalComm``  — every worker's tensors are stacked on a leading axis W.
    Collectives are axis reductions / rolls.  Used for CPU tests,
    convergence benchmarks, and vmap-based simulation of large worker
    counts.  Deterministic and single-device.

  * ``ShardComm``  — inside ``shard_map`` over a named mesh axis;
    tensors are per-worker shards and collectives lower to real TPU
    ICI/DCN collectives (psum / ppermute / all-gather).  Used by the
    production launcher.

This dual realization is exactly the paper's portability argument: the
strategy code (the science) is independent of the transport.  The actual
tensor moving — bucketing, flat-buffer fusion, wire packing — lives one
level up in ``core/fabric.py`` (DESIGN.md §3), which drives these
primitives once per *bucket* instead of once per parameter leaf.

``lead_axes`` tells the fabric how many leading replica axes the stacked
layout carries (0 for ShardComm shards, 1 for plain LocalComm, 2 for the
pods×workers hierarchy) so flattening never mixes replicas into one
compression block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


class Comm:
    """Abstract tensor-moving interface."""

    size: int
    lead_axes: int = 0  # leading replica axes in the tensor layout

    def all_mean(self, tree):
        raise NotImplementedError

    def all_sum(self, tree):
        raise NotImplementedError

    def ppermute(self, tree, shift: int = 1):
        """Ring shift: worker w receives worker (w - shift) % W's value."""
        raise NotImplementedError

    def all_gather(self, tree, tiled: bool = False):
        """Gather every worker's value.

        ``tiled=False``: stacked on a NEW leading axis of size W (the
        fabric's packed wire path; only meaningful for per-shard
        realizations — the stacked simulator already sees every replica).
        ``tiled=True``: concatenated along the LAST axis — the inverse of
        ``reduce_scatter``, used by the partitioned (ZeRO-1) exchange."""
        raise NotImplementedError

    def reduce_scatter(self, tree, mean: bool = False):
        """Cross-worker sum (or mean), scattered: worker w keeps only its
        own chunk w of the last axis, which must divide by W.  The ZeRO-1
        primitive: reduce_scatter + shard update + all_gather(tiled=True)
        moves the same ring bytes as one all-reduce."""
        raise NotImplementedError

    def shard_chunk(self, tree):
        """Worker w's own 1/W chunk of the last axis of a REPLICATED tree
        (a local slice — no communication)."""
        raise NotImplementedError

    def worker_index(self, like=None):
        """Per-worker index in [0, W), broadcastable against local tensors."""
        raise NotImplementedError


class LocalComm(Comm):
    """Stacked-replica realization: leaves carry a worker dim at ``axis``.

    ``lead_axes`` (defaults to ``axis + 1``) is the total count of leading
    replica axes in the layout — e.g. the hierarchical (P, W, ...) layout
    has lead_axes=2 for BOTH tier comms, while each tier reduces over its
    own ``axis``."""

    def __init__(self, size: int, axis: int = 0, lead_axes: int | None = None):
        self.size = size
        self.axis = axis
        self.lead_axes = axis + 1 if lead_axes is None else lead_axes

    def all_mean(self, tree):
        ax = self.axis
        return jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.mean(x, axis=ax, keepdims=True),
                                       x.shape), tree)

    def all_sum(self, tree):
        ax = self.axis
        return jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.sum(x, axis=ax, keepdims=True),
                                       x.shape), tree)

    def ppermute(self, tree, shift: int = 1):
        return jax.tree.map(lambda x: jnp.roll(x, shift, axis=self.axis), tree)

    def all_gather(self, tree, tiled: bool = False):
        if not tiled:
            raise NotImplementedError(
                "stacked LocalComm already sees every replica; only the "
                "tiled (last-axis concat) gather is defined")
        ax, w = self.axis, self.size

        def one(x):
            y = jnp.moveaxis(x, ax, -2)  # (..., W, C): shards in rank order
            flat = y.reshape(y.shape[:-2] + (w * x.shape[-1],))
            return jnp.broadcast_to(jnp.expand_dims(flat, ax),
                                    x.shape[:-1] + (w * x.shape[-1],))

        return jax.tree.map(one, tree)

    def reduce_scatter(self, tree, mean: bool = False):
        ax, w = self.axis, self.size

        def one(x):
            red = jnp.mean(x, axis=ax) if mean else jnp.sum(x, axis=ax)
            c = x.shape[-1] // w
            chunks = red.reshape(red.shape[:-1] + (w, c))
            return jnp.moveaxis(chunks, -2, ax)  # worker w gets chunk w

        return jax.tree.map(one, tree)

    def shard_chunk(self, tree):
        """Worker w's own 1/W chunk of the last axis of a REPLICATED tree
        (no communication: the local slice of a value every worker holds)."""
        ax, w = self.axis, self.size

        def one(x):
            c = x.shape[-1] // w
            chunks = x.reshape(x.shape[:-1] + (w, c))
            idx = jax.lax.broadcasted_iota(
                jnp.int32, chunks.shape[:-2] + (1, c), ax)
            return jnp.take_along_axis(chunks, idx, axis=-2).reshape(
                x.shape[:-1] + (c,))

        return jax.tree.map(one, tree)

    def worker_index(self, like=None):
        return jnp.arange(self.size).reshape(
            (1,) * self.axis + (self.size,))

    # helpers for stacked layout -------------------------------------------
    def replicate(self, tree):
        """Broadcast a single-replica pytree to the stacked layout."""
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.size,) + jnp.shape(x)).copy(), tree)

    def replica(self, tree, w: int):
        return jax.tree.map(lambda x: x[w], tree)


class ShardComm(Comm):
    """shard_map realization over one (or more) named mesh axes."""

    lead_axes = 0

    def __init__(self, axis_name, size: int):
        self.axis_name = axis_name
        self.size = size

    def all_mean(self, tree):
        return jax.tree.map(lambda x: jax.lax.pmean(x, self.axis_name), tree)

    def all_sum(self, tree):
        return jax.tree.map(lambda x: jax.lax.psum(x, self.axis_name), tree)

    def ppermute(self, tree, shift: int = 1):
        n = self.size
        perm = [((i - shift) % n, i) for i in range(n)]
        return jax.tree.map(
            lambda x: jax.lax.ppermute(x, self.axis_name, perm), tree)

    def all_gather(self, tree, tiled: bool = False):
        return jax.tree.map(
            lambda x: jax.lax.all_gather(
                x, self.axis_name,
                axis=x.ndim - 1 if tiled else 0, tiled=tiled), tree)

    def reduce_scatter(self, tree, mean: bool = False):
        def one(x):
            y = jax.lax.psum_scatter(x, self.axis_name,
                                     scatter_dimension=x.ndim - 1, tiled=True)
            return y / self.size if mean else y

        return jax.tree.map(one, tree)

    def gather_chunks(self, tree):
        """The data movement of a reduce-scatter WITHOUT the reduction:
        worker w receives every peer's chunk w of the last axis (which
        must divide by W), stacked on a NEW leading axis (W, ..., C).
        One all-to-all per leaf — identical ring bytes to
        ``reduce_scatter`` — leaving the ACCUMULATION dtype to the
        caller.  This is how the fabric realizes a narrow (bf16) wire
        with f32 accumulation: the wire op carries only the narrow
        chunks (core/fabric.py::exchange_partitioned)."""
        def one(x):
            c = x.shape[-1] // self.size
            y = x.reshape(x.shape[:-1] + (self.size, c))
            y = jnp.moveaxis(y, -2, 0)  # (W, ..., C): piece w -> worker w
            return jax.lax.all_to_all(y, self.axis_name, split_axis=0,
                                      concat_axis=0)

        return jax.tree.map(one, tree)

    def shard_chunk(self, tree):
        """This shard's 1/W chunk of the last axis of a replicated tree."""
        i = jax.lax.axis_index(self.axis_name)

        def one(x):
            c = x.shape[-1] // self.size
            return jax.lax.dynamic_slice_in_dim(x, i * c, c, axis=x.ndim - 1)

        return jax.tree.map(one, tree)

    def worker_index(self, like=None):
        return jax.lax.axis_index(self.axis_name)


class HierComm:
    """Two-tier comm: ``inner`` (fast fabric, e.g. intra-pod ICI) and
    ``outer`` (slow fabric, e.g. pod-to-pod DCN).  The beyond-paper
    hierarchical strategy composes a complete strategy on ``inner`` with a
    partial one on ``outer`` (DESIGN.md §2)."""

    def __init__(self, inner: Comm, outer: Comm):
        self.inner = inner
        self.outer = outer
        self.size = inner.size * outer.size


class LocalHierComm(HierComm):
    """Stacked layout (P, W, ...): axis 0 = pods (outer), axis 1 = workers.

    Both tier comms declare lead_axes=2 — a compression block must never
    mix values across pods OR workers, whichever tier is communicating."""

    def __init__(self, pods: int, workers: int):
        super().__init__(LocalComm(workers, axis=1, lead_axes=2),
                         LocalComm(pods, axis=0, lead_axes=2))
