"""The FAST "tensor-moving interface" (paper §4), adapted to JAX/TPU.

The paper's central design move is to decouple *parallel coordination* from
*node-level execution* behind a general-purpose tensor-moving interface.
Here that interface is ``Comm``: strategies (core/strategies.py) are written
against it and run unchanged in two realizations:

  * ``LocalComm``  — every worker's tensors are stacked on a leading axis W.
    Collectives are axis-0 reductions / rolls.  Used for CPU tests,
    convergence benchmarks, and vmap-based simulation of large worker
    counts.  Deterministic and single-device.

  * ``ShardComm``  — inside ``jax.shard_map`` over a named mesh axis;
    tensors are per-worker shards and collectives lower to real TPU
    ICI/DCN collectives (psum / ppermute).  Used by the production
    launcher.

This dual realization is exactly the paper's portability argument: the
strategy code (the science) is independent of the transport (the fabric).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


class Comm:
    """Abstract tensor-moving interface."""

    size: int

    def all_mean(self, tree):
        raise NotImplementedError

    def all_sum(self, tree):
        raise NotImplementedError

    def ppermute(self, tree, shift: int = 1):
        """Ring shift: worker w receives worker (w - shift) % W's value."""
        raise NotImplementedError

    def worker_index(self, like=None):
        """Per-worker index in [0, W), broadcastable against local tensors."""
        raise NotImplementedError


class LocalComm(Comm):
    """Stacked-replica realization: leaves have leading worker dim W."""

    def __init__(self, size: int):
        self.size = size

    def all_mean(self, tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape),
            tree)

    def all_sum(self, tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.sum(x, axis=0, keepdims=True), x.shape),
            tree)

    def ppermute(self, tree, shift: int = 1):
        return jax.tree.map(lambda x: jnp.roll(x, shift, axis=0), tree)

    def worker_index(self, like=None):
        return jnp.arange(self.size)

    # helpers for stacked layout -------------------------------------------
    def replicate(self, tree):
        """Broadcast a single-replica pytree to the stacked layout."""
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.size,) + jnp.shape(x)).copy(), tree)

    def replica(self, tree, w: int):
        return jax.tree.map(lambda x: x[w], tree)


class ShardComm(Comm):
    """shard_map realization over one (or more) named mesh axes."""

    def __init__(self, axis_name, size: int):
        self.axis_name = axis_name
        self.size = size

    def all_mean(self, tree):
        return jax.tree.map(lambda x: jax.lax.pmean(x, self.axis_name), tree)

    def all_sum(self, tree):
        return jax.tree.map(lambda x: jax.lax.psum(x, self.axis_name), tree)

    def ppermute(self, tree, shift: int = 1):
        n = self.size
        perm = [((i - shift) % n, i) for i in range(n)]
        return jax.tree.map(
            lambda x: jax.lax.ppermute(x, self.axis_name, perm), tree)

    def worker_index(self, like=None):
        return jax.lax.axis_index(self.axis_name)


class HierComm:
    """Two-tier comm: ``inner`` (fast fabric, e.g. intra-pod ICI) and
    ``outer`` (slow fabric, e.g. pod-to-pod DCN).  The beyond-paper
    hierarchical strategy composes a complete strategy on ``inner`` with a
    partial one on ``outer`` (DESIGN.md §2)."""

    def __init__(self, inner: Comm, outer: Comm):
        self.inner = inner
        self.outer = outer
        self.size = inner.size * outer.size


class LocalHierComm(HierComm):
    """Stacked layout (P, W, ...): axis 0 = pods (outer), axis 1 = workers."""

    def __init__(self, pods: int, workers: int):
        inner = LocalComm(workers)
        outer = LocalComm(pods)
        super().__init__(inner, outer)
        # re-bind axes: inner ops act on axis 1, outer on axis 0
        inner.all_mean = lambda tree: jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.mean(x, axis=1, keepdims=True), x.shape), tree)
        inner.all_sum = lambda tree: jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.sum(x, axis=1, keepdims=True), x.shape), tree)
        inner.ppermute = lambda tree, shift=1: jax.tree.map(
            lambda x: jnp.roll(x, shift, axis=1), tree)
        outer.ppermute = lambda tree, shift=1: jax.tree.map(
            lambda x: jnp.roll(x, shift, axis=0), tree)
