"""Statement 1 machinery (paper §3, Figure 3).

    "Assuming mini-batch SGD without momentum in a distributed setting, if
     all the gradient updates (communications) are delivered to all the
     workers, regardless of the delay, all the model replicas will be
     consistent [once the queues are emptied]."

This module is the executable form of Figure 3: workers produce updates,
a *delivery schedule* decides when (or whether) each update reaches each
peer, pending updates sit in queues, and ``drain`` empties them.  The
property tests (tests/test_consistency_property.py) drive it with
hypothesis-generated schedules to validate both the statement and its
boundary conditions:

  * complete delivery, any order/delay  → replicas consistent   (Statement 1)
  * dropped updates (partial comm.)     → replicas diverge      (¬Statement 1)
  * momentum                            → consistency breaks    (the paper's
    "without momentum" qualifier is load-bearing: momentum makes the update
    a non-commutative function of arrival order)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class Update:
    src: int
    seq: int  # per-source sequence number
    grad: np.ndarray


class Replica:
    """One model replica applying (possibly stale) updates via plain SGD or
    momentum SGD — momentum exists to demonstrate the counterexample."""

    def __init__(self, w0: np.ndarray, lr: float, momentum: float = 0.0):
        self.w = w0.astype(np.float64).copy()
        self.lr = lr
        self.beta = momentum
        self.m = np.zeros_like(self.w)
        self.applied: set = set()

    def apply(self, upd: Update):
        key = (upd.src, upd.seq)
        assert key not in self.applied, f"duplicate delivery {key}"
        self.applied.add(key)
        if self.beta:
            self.m = self.beta * self.m + upd.grad
            self.w -= self.lr * self.m
        else:
            self.w -= self.lr * upd.grad


class ConsistencySim:
    """W replicas + per-(src,dst) delivery queues.

    ``schedule[(src, dst)]`` maps a produced update index to the round at
    which it is delivered (np.inf ⇒ never — partial communication).
    Updates produced locally are applied immediately at the source.
    """

    def __init__(self, n_workers: int, dim: int, lr: float = 0.1,
                 momentum: float = 0.0, seed: int = 0):
        rng = np.random.default_rng(seed)
        w0 = rng.normal(size=(dim,))
        self.replicas = [Replica(w0, lr, momentum) for _ in range(n_workers)]
        self.n = n_workers
        self.queues: dict = {}  # (src, dst) -> list[(deliver_round, Update)]
        self.round = 0
        self.rng = rng
        self.dropped = 0

    def produce(self, src: int, grad: np.ndarray, seq: int,
                delays: Optional[dict] = None):
        """Worker ``src`` computes ``grad``: applies locally, enqueues for
        every peer with per-destination delay (None/inf ⇒ drop)."""
        upd = Update(src, seq, np.asarray(grad, np.float64))
        self.replicas[src].apply(upd)
        for dst in range(self.n):
            if dst == src:
                continue
            delay = (delays or {}).get(dst, 0)
            if delay is None or delay == np.inf:
                self.dropped += 1
                continue
            self.queues.setdefault((src, dst), []).append(
                (self.round + delay, upd))

    def deliver_due(self):
        for (src, dst), q in self.queues.items():
            due = [u for (r, u) in q if r <= self.round]
            self.queues[(src, dst)] = [(r, u) for (r, u) in q if r > self.round]
            for u in due:
                self.replicas[dst].apply(u)

    def step(self):
        self.round += 1
        self.deliver_due()

    def drain(self):
        """The Figure-3 'event that triggers application of all pending
        updates' (e.g. a global synchronization)."""
        for (src, dst), q in self.queues.items():
            for (_, u) in q:
                self.replicas[dst].apply(u)
            self.queues[(src, dst)] = []

    def weights(self) -> np.ndarray:
        return np.stack([r.w for r in self.replicas])

    def max_divergence(self) -> float:
        w = self.weights()
        return float(np.max(np.abs(w - w[0:1])))

    def consistent(self, atol: float = 1e-9) -> bool:
        return self.max_divergence() <= atol
