from repro.core.comm import Comm, HierComm, LocalComm, LocalHierComm, ShardComm  # noqa: F401
from repro.core.compression import get_compressor  # noqa: F401
from repro.core.strategies import Strategy, get_strategy  # noqa: F401
