"""Staleness accounting and the implicit-momentum connection.

The paper (§3) leans on Mitliagkas et al., "Asynchrony begets Momentum":
with W asynchronous workers, the expected update direction behaves like
momentum SGD with  β ≈ 1 − 1/W  (geometric staleness distribution).  The
paper flags "no clear understanding of what happens in case of incomplete
communication" — we provide the measurement tooling:

  * ``implicit_momentum(W)`` — the Mitliagkas prediction.
  * ``effective_momentum_fit`` — fit β̂ from an observed weight trajectory
    by regressing update_t against update_{t-1} (used by
    benchmarks/bench_staleness.py to compare sync/ssp/downpour/gossip
    against the prediction, and by tests).
  * ``staleness_histogram`` — delivery-delay distribution of a strategy's
    schedule, the quantity a centralized parameter server would measure
    "for free" and a decentralized system must reconstruct (paper §3).
  * ``StragglerDetector`` — per-worker boundary-time EWMAs vs the fleet
    median, with hysteresis (DESIGN.md §13): persistent stragglers are
    demoted from sync to local-step participation and re-promoted on
    recovery.  Host-side numpy only; the launch layer flips a traced
    mask, so demotion never retraces the step.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def implicit_momentum(n_workers: int) -> float:
    """Mitliagkas et al. prediction: β = 1 − 1/W."""
    return 1.0 - 1.0 / max(1, n_workers)


def effective_momentum_fit(weight_traj: np.ndarray) -> float:
    """Least-squares fit of u_t ≈ β u_{t-1} over a weight trajectory
    (T, dim) — returns β̂."""
    w = np.asarray(weight_traj, np.float64)
    u = np.diff(w, axis=0)  # (T-1, dim)
    if len(u) < 3:
        return 0.0
    num = float(np.sum(u[1:] * u[:-1]))
    den = float(np.sum(u[:-1] * u[:-1])) + 1e-30
    return num / den


@dataclass(frozen=True)
class StragglerPolicy:
    """Hysteresis thresholds for straggler demotion/re-promotion.

    A worker whose boundary-time EWMA exceeds ``demote_ratio`` × the
    fleet median for ``patience`` consecutive boundaries is demoted to
    the local-step tier; a demoted worker back under ``promote_ratio`` ×
    median for ``recovery`` consecutive boundaries is re-promoted.  The
    gap between the two ratios prevents flapping at the threshold."""

    alpha: float = 0.4
    demote_ratio: float = 1.75
    promote_ratio: float = 1.25
    patience: int = 2
    recovery: int = 3


class StragglerDetector:
    """Per-worker boundary-time EWMAs against the fleet median.

    ``observe`` once per optimizer boundary with the measured (or
    simulated — ``core/chaos.py::FleetClock``) per-worker times; then
    ``to_demote()``/``to_promote()`` list the workers whose hysteresis
    counters crossed the policy thresholds, and the caller commits the
    transitions with ``demote``/``promote`` (membership changes with
    ``add``/``drop``).  Pure host-side numpy — no traced state."""

    def __init__(self, workers, policy: StragglerPolicy | None = None):
        self.policy = policy or StragglerPolicy()
        self.ewma = {w: None for w in workers}
        self.slow = {w: 0 for w in workers}
        self.fast = {w: 0 for w in workers}
        self.demoted = set()

    def add(self, worker) -> None:
        self.ewma.setdefault(worker, None)
        self.slow.setdefault(worker, 0)
        self.fast.setdefault(worker, 0)

    def drop(self, worker) -> None:
        for d in (self.ewma, self.slow, self.fast):
            d.pop(worker, None)
        self.demoted.discard(worker)

    def observe(self, times: dict) -> float:
        """Fold one boundary's per-worker times in; returns the median EWMA."""
        p = self.policy
        for w, t in times.items():
            self.add(w)
            prev = self.ewma[w]
            self.ewma[w] = t if prev is None else p.alpha * t + (1 - p.alpha) * prev
        known = [v for v in self.ewma.values() if v is not None]
        med = float(np.median(known)) if known else 0.0
        for w in times:
            e = self.ewma[w]
            if w not in self.demoted:
                self.slow[w] = self.slow[w] + 1 if e > p.demote_ratio * med else 0
            else:
                self.fast[w] = self.fast[w] + 1 if e < p.promote_ratio * med else 0
        return med

    def to_demote(self) -> list:
        return sorted(w for w, c in self.slow.items()
                      if w not in self.demoted and c >= self.policy.patience)

    def to_promote(self) -> list:
        return sorted(w for w, c in self.fast.items()
                      if w in self.demoted and c >= self.policy.recovery)

    def demote(self, worker) -> None:
        self.demoted.add(worker)
        self.slow[worker] = 0
        self.fast[worker] = 0

    def promote(self, worker) -> None:
        self.demoted.discard(worker)
        self.slow[worker] = 0
        self.fast[worker] = 0


def staleness_histogram(schedule, n_workers: int, horizon: int):
    """schedule: callable (src, dst, t) -> delivery delay (int or None).
    Returns (delays list, drop_fraction)."""
    delays, drops, total = [], 0, 0
    for t in range(horizon):
        for src in range(n_workers):
            for dst in range(n_workers):
                if src == dst:
                    continue
                total += 1
                d = schedule(src, dst, t)
                if d is None:
                    drops += 1
                else:
                    delays.append(d)
    return np.asarray(delays), drops / max(1, total)
