"""Staleness accounting and the implicit-momentum connection.

The paper (§3) leans on Mitliagkas et al., "Asynchrony begets Momentum":
with W asynchronous workers, the expected update direction behaves like
momentum SGD with  β ≈ 1 − 1/W  (geometric staleness distribution).  The
paper flags "no clear understanding of what happens in case of incomplete
communication" — we provide the measurement tooling:

  * ``implicit_momentum(W)`` — the Mitliagkas prediction.
  * ``effective_momentum_fit`` — fit β̂ from an observed weight trajectory
    by regressing update_t against update_{t-1} (used by
    benchmarks/bench_staleness.py to compare sync/ssp/downpour/gossip
    against the prediction, and by tests).
  * ``staleness_histogram`` — delivery-delay distribution of a strategy's
    schedule, the quantity a centralized parameter server would measure
    "for free" and a decentralized system must reconstruct (paper §3).
"""

from __future__ import annotations

import numpy as np


def implicit_momentum(n_workers: int) -> float:
    """Mitliagkas et al. prediction: β = 1 − 1/W."""
    return 1.0 - 1.0 / max(1, n_workers)


def effective_momentum_fit(weight_traj: np.ndarray) -> float:
    """Least-squares fit of u_t ≈ β u_{t-1} over a weight trajectory
    (T, dim) — returns β̂."""
    w = np.asarray(weight_traj, np.float64)
    u = np.diff(w, axis=0)  # (T-1, dim)
    if len(u) < 3:
        return 0.0
    num = float(np.sum(u[1:] * u[:-1]))
    den = float(np.sum(u[:-1] * u[:-1])) + 1e-30
    return num / den


def staleness_histogram(schedule, n_workers: int, horizon: int):
    """schedule: callable (src, dst, t) -> delivery delay (int or None).
    Returns (delays list, drop_fraction)."""
    delays, drops, total = [], 0, 0
    for t in range(horizon):
        for src in range(n_workers):
            for dst in range(n_workers):
                if src == dst:
                    continue
                total += 1
                d = schedule(src, dst, t)
                if d is None:
                    drops += 1
                else:
                    delays.append(d)
    return np.asarray(delays), drops / max(1, total)
