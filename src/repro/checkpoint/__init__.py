from repro.checkpoint.checkpointer import (latest_step,  # noqa: F401
                                           latest_valid_step, read_meta,
                                           read_precision, reshard_bucket,
                                           restore_checkpoint,
                                           save_checkpoint, stray_tmp_files,
                                           verify_checkpoint)
