from repro.checkpoint.checkpointer import (latest_step, read_meta,  # noqa: F401
                                           read_precision, reshard_bucket,
                                           restore_checkpoint,
                                           save_checkpoint)
