"""npz-based pytree checkpointer (no orbax dependency).

Shard-aware in the practical sense: arrays are gathered to host (fully
addressable on save) and restored with ``jax.device_put`` against the
caller-provided sharding template, so a restore can re-shard onto a
different mesh — the "redistribute training" requirement of the paper's
enterprise story (§1).

Guarantees for the production path:

  * **Integrity** — every save records a per-leaf crc32 in meta.json;
    ``restore_checkpoint``/``verify_checkpoint`` check it and name the
    corrupt leaf, and ``latest_valid_step`` resumes past corrupt or
    partial steps (the ``--resume auto`` primitive).  Stray ``*.tmp``
    files from killed mid-save writers are ignored but reported.

  * **Atomic writes** — the ``.npz`` and ``meta.json`` are written to a
    temp name and ``os.replace``d into place, so a crash mid-save can
    never leave a truncated "latest" checkpoint behind.
  * **Partitioned (ZeRO-1) opt state** — ``save_checkpoint(partition=
    play.spec())`` records the shard-bucket partition (worker count +
    true bucket sizes) in meta.json; ``restore_checkpoint(repartition=
    True)`` re-shards any saved shard-bucket leaf whose shape disagrees
    with the template — reassemble chunks in rank order, drop the old
    padding, re-pad for the new worker count — so a run saved at W
    workers restores onto W' (the paper's "redistribute training").

Precision (core/precision.py, DESIGN.md §4):

  * **Master precision on disk** — low-precision float leaves (bf16/f16
    working params) are WIDENED to f32 before they hit the ``.npz``;
    the checkpoint always stores the master-fidelity values (and ``.npz``
    has no portable encoding for ml_dtypes anyway).  The widening is
    lossless, and f32 leaves are written byte-identically to before.
  * **Casted restore** — a restored leaf whose dtype disagrees with the
    template is cast to the template's dtype, so a run saved under the
    f32 policy restores directly into bf16 working params (and vice
    versa), across worker counts when combined with ``repartition``.
  * **Policy record** — ``save_checkpoint(precision=policy.spec())``
    stores the full PrecisionPolicy per step in meta.json;
    ``read_precision(dir, step)`` returns it for the resuming run.
"""

from __future__ import annotations

import json
import os
import re
import warnings
import zlib

import jax
import numpy as np

# ONE proven re-shard implementation serves both the checkpoint restore
# below and the live elastic resize (launch/elastic.py) — see
# core/resharding.py; re-exported here for the original public API.
from repro.core.resharding import reshard_bucket  # noqa: F401


def _prod(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}.{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}.{i}")
    else:
        yield prefix, tree


def _unflatten_into(template, flat: dict, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat,
                                   f"{prefix}.{k}" if prefix else str(k))
                for k in template}
    if isinstance(template, (list, tuple)):
        t = type(template)
        return t(_unflatten_into(v, flat, f"{prefix}.{i}")
                 for i, v in enumerate(template))
    return flat[prefix]


def _widen_for_disk(arr: np.ndarray) -> np.ndarray:
    """Low-precision floats → f32 (master precision on disk; lossless)."""
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float16"):
        return arr.astype(np.float32)
    return arr


def save_checkpoint(ckpt_dir: str, step: int, tree,
                    partition: dict | None = None,
                    precision: dict | None = None) -> str:
    """Atomically write ``tree`` as ``ckpt_<step>.npz`` + meta.json.

    ``partition``: optional ZeRO-1 partition spec (``PartitionedLayout
    .spec()``: {"n_parts", "bucket_sizes"}) describing the shard-bucket
    leaves of the saved opt state; recorded in meta.json so a later
    restore can re-shard onto a different worker count.

    ``precision``: optional PrecisionPolicy spec (``policy.spec()``)
    recorded per step in meta.json.  Low-precision float leaves are
    widened to f32 on disk regardless (see module docstring)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = {}
    checksums = {}
    for path, leaf in _flatten(tree):
        arr = _widen_for_disk(np.asarray(jax.device_get(leaf)))
        arrays[path] = arr
        checksums[path] = zlib.crc32(np.ascontiguousarray(arr).tobytes())
    fname = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    tmp = fname + ".tmp"
    with open(tmp, "wb") as f:  # file handle: savez won't append a suffix
        np.savez_compressed(f, **arrays)
    os.replace(tmp, fname)
    # meta is MERGED, and partition specs are keyed per step, so a later
    # partition-less save into the same dir never orphans an earlier
    # partitioned checkpoint
    meta = read_meta(ckpt_dir)
    meta["latest"] = step
    # per-leaf crc32 over the on-disk (widened) bytes: restore verifies
    # and names the corrupt leaf instead of silently loading garbage
    meta.setdefault("checksums", {})[str(step)] = checksums
    if partition is not None:
        meta.setdefault("partitions", {})[str(step)] = partition
    if precision is not None:
        meta.setdefault("precision", {})[str(step)] = precision
    mpath = os.path.join(ckpt_dir, "meta.json")
    with open(mpath + ".tmp", "w") as f:
        json.dump(meta, f)
    os.replace(mpath + ".tmp", mpath)
    return fname


def read_meta(ckpt_dir: str) -> dict:
    mpath = os.path.join(ckpt_dir, "meta.json")
    if not os.path.exists(mpath):
        return {}
    with open(mpath) as f:
        return json.load(f)


def read_precision(ckpt_dir: str, step: int) -> dict | None:
    """The PrecisionPolicy spec recorded for ``step`` (None if absent)."""
    return read_meta(ckpt_dir).get("precision", {}).get(str(step))


def stray_tmp_files(ckpt_dir: str) -> list:
    """Leftover ``*.tmp`` files from a writer killed mid-save.

    The atomic protocol (write tmp → ``os.replace``) guarantees these are
    never the "latest" checkpoint — they are garbage to ignore, but worth
    REPORTING: a recurring stray means writers are dying mid-save."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(f for f in os.listdir(ckpt_dir) if f.endswith(".tmp"))


def _warn_stray_tmp(ckpt_dir: str):
    stray = stray_tmp_files(ckpt_dir)
    if stray:
        warnings.warn(
            f"{ckpt_dir}: ignoring {len(stray)} stray tmp file(s) left by a "
            f"killed mid-save writer: {', '.join(stray)}", stacklevel=3)


def latest_step(ckpt_dir: str):
    steps = []
    if not os.path.isdir(ckpt_dir):
        return None
    _warn_stray_tmp(ckpt_dir)
    for f in os.listdir(ckpt_dir):
        m = re.match(r"ckpt_(\d+)\.npz$", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def verify_checkpoint(ckpt_dir: str, step: int):
    """Integrity-check one step; returns None if clean, else a reason str.

    Checks every ``.npz`` member decompresses AND matches the per-leaf
    crc32 recorded in meta at save time (older checkpoints without a
    checksum record only get the decompression check)."""
    fname = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    if not os.path.exists(fname):
        return f"ckpt_{step:08d}.npz missing"
    cks = read_meta(ckpt_dir).get("checksums", {}).get(str(step))
    try:
        with np.load(fname) as data:
            for k in data.files:
                try:
                    arr = data[k]
                except Exception as e:
                    return f"leaf {k!r} unreadable ({e})"
                if cks is not None and k in cks:
                    got = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                    if got != cks[k]:
                        return (f"leaf {k!r} corrupt (crc32 {got:#010x} != "
                                f"recorded {cks[k]:#010x})")
            if cks is not None:
                missing = sorted(set(cks) - set(data.files))
                if missing:
                    return f"leaves missing from archive: {missing}"
    except Exception as e:
        return f"archive unreadable ({e})"
    return None


def latest_valid_step(ckpt_dir: str):
    """Newest step that passes :func:`verify_checkpoint` (None if none).

    Corrupt/partial steps are skipped with a warning naming the reason —
    the ``--resume auto`` primitive: a run killed mid-save or a bit-rotted
    latest step falls back to the newest intact one."""
    if not os.path.isdir(ckpt_dir):
        return None
    _warn_stray_tmp(ckpt_dir)
    steps = sorted((int(m.group(1)) for m in
                    (re.match(r"ckpt_(\d+)\.npz$", f)
                     for f in os.listdir(ckpt_dir)) if m), reverse=True)
    for step in steps:
        reason = verify_checkpoint(ckpt_dir, step)
        if reason is None:
            return step
        warnings.warn(f"{ckpt_dir}: skipping step {step}: {reason}",
                      stacklevel=2)
    return None


def restore_checkpoint(ckpt_dir: str, step: int, template, shardings=None,
                       repartition: bool = False):
    """Restore into the structure of ``template``; if ``shardings`` (same
    structure) is given, leaves are placed with those shardings.

    ``repartition=True``: shard-bucket leaves saved under a recorded ZeRO-1
    partition (see ``save_checkpoint``) whose shapes disagree with the
    template are re-sharded for the template's worker count.  Bucket
    identity is the leaf's trailing path index (shard states are lists of
    per-bucket arrays, so "opt_state.m.3" is bucket 3) — the template must
    therefore be built with the SAME bucket layout (``bucket_bytes``) as
    the save; a mismatched bucket count is rejected rather than silently
    zero-filling state."""
    fname = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    _warn_stray_tmp(ckpt_dir)
    data = np.load(fname)
    cks = read_meta(ckpt_dir).get("checksums", {}).get(str(step))
    flat = {}
    for k in data.files:
        try:
            flat[k] = data[k]
        except Exception as e:
            raise ValueError(
                f"{fname}: leaf {k!r} is corrupt — unreadable ({e})") from e
        if cks is not None and k in cks:
            got = zlib.crc32(np.ascontiguousarray(flat[k]).tobytes())
            if got != cks[k]:
                raise ValueError(
                    f"{fname}: leaf {k!r} is corrupt — crc32 {got:#010x} "
                    f"does not match the recorded {cks[k]:#010x}")
    if repartition:
        part = read_meta(ckpt_dir).get("partitions", {}).get(str(step))
        if part is None:
            raise ValueError("repartition=True but the checkpoint records "
                             "no partition spec (save with partition=...)")
        sizes = part["bucket_sizes"]
        resharded_lists = set()
        for path, want in _flatten(template):
            saved = flat.get(path)
            head, _, idx = path.rpartition(".")
            if saved is None or not idx.isdigit():
                continue
            wshape = tuple(getattr(want, "shape", ()))
            if tuple(saved.shape) != wshape:
                if int(idx) >= len(sizes):
                    raise ValueError(
                        f"{path}: bucket {idx} outside the recorded "
                        f"partition ({len(sizes)} buckets) — template "
                        "built with a different bucket layout")
                if _prod(wshape) < sizes[int(idx)]:
                    raise ValueError(
                        f"{path}: template holds {_prod(wshape)} elements "
                        f"but bucket {idx} carries {sizes[int(idx)]} — "
                        "template built with a different bucket layout")
                flat[path] = reshard_bucket(saved, sizes[int(idx)], wshape)
                resharded_lists.add(head)
        # every saved bucket of a re-sharded list must be consumed: a
        # template with FEWER buckets (different bucket_bytes) would
        # otherwise silently drop the tail buckets' state
        for head in resharded_lists:
            saved_idx = {int(k.rpartition(".")[2]) for k in data.files
                         if k.rpartition(".")[0] == head
                         and k.rpartition(".")[2].isdigit()}
            templ_idx = {int(p.rpartition(".")[2])
                         for p, _ in _flatten(template)
                         if p.rpartition(".")[0] == head
                         and p.rpartition(".")[2].isdigit()}
            if saved_idx != templ_idx:
                raise ValueError(
                    f"{head}: checkpoint has buckets {sorted(saved_idx)} "
                    f"but template expects {sorted(templ_idx)} — bucket "
                    "layout (bucket_bytes) must match the save")
    tree = _unflatten_into(template, flat)

    def cast_to_template(x, want):
        # casted restore: disk carries master (f32) fidelity — a template
        # asking for a narrower working dtype (bf16 params) gets the cast
        wd = getattr(want, "dtype", None)
        if wd is None:
            return x
        wd = np.dtype(jax.numpy.dtype(wd))
        x = np.asarray(x)
        return x.astype(wd) if x.dtype != wd else x

    tree = jax.tree.map(cast_to_template, tree, template)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
