"""npz-based pytree checkpointer (no orbax dependency).

Shard-aware in the practical sense: arrays are gathered to host (fully
addressable on save) and restored with ``jax.device_put`` against the
caller-provided sharding template, so a restore can re-shard onto a
different mesh — the "redistribute training" requirement of the paper's
enterprise story (§1).
"""

from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}.{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}.{i}")
    else:
        yield prefix, tree


def _unflatten_into(template, flat: dict, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat,
                                   f"{prefix}.{k}" if prefix else str(k))
                for k in template}
    if isinstance(template, (list, tuple)):
        t = type(template)
        return t(_unflatten_into(v, flat, f"{prefix}.{i}")
                 for i, v in enumerate(template))
    return flat[prefix]


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = {}
    for path, leaf in _flatten(tree):
        arrays[path] = np.asarray(jax.device_get(leaf))
    fname = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    np.savez_compressed(fname, **arrays)
    with open(os.path.join(ckpt_dir, "meta.json"), "w") as f:
        json.dump({"latest": step}, f)
    return fname


def latest_step(ckpt_dir: str):
    steps = []
    if not os.path.isdir(ckpt_dir):
        return None
    for f in os.listdir(ckpt_dir):
        m = re.match(r"ckpt_(\d+)\.npz$", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template, shardings=None):
    """Restore into the structure of ``template``; if ``shardings`` (same
    structure) is given, leaves are placed with those shardings."""
    fname = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(fname)
    flat = {k: data[k] for k in data.files}
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
