"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs(per device) / peak_FLOP/s
    memory term     = HBM-resident bytes(per device) / HBM_bw
    collective term = Σ_type ring_factor·bytes(per device) / link_bw

Methodology (all per-device: the compiled module is the per-partition SPMD
program):

  * HLO_FLOPs come from ``compiled.cost_analysis()`` of depth-truncated
    UNROLLED lowerings (1 and 2 super-blocks), linearly extrapolated to the
    full depth — XLA counts while-loop bodies once, so scanning stacks
    under-report by the trip count; unrolled truncations are trip-count
    exact and matmul/collective costs are linear in depth.
  * Collective bytes are NOT in cost_analysis: we parse the optimized HLO
    text of the same unrolled truncations, sum output-shape bytes of every
    collective op (scaled by ring traffic factors: all-reduce ≈ 2×,
    gather/scatter/permute ≈ 1×), and extrapolate identically.
  * The memory term uses the full-config compile's ``memory_analysis()``
    resident bytes (args + outputs + temps − aliased) — one full sweep of
    resident state per step, the realistic TPU proxy.  The raw
    ``bytes accessed`` figure from XLA:CPU is kept in the record as an
    *unfused upper bound* (CPU cost analysis sums per-op traffic with no
    fusion, inflating it ~10-30× vs a fused TPU program).
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# ring traffic factor: bytes moved per device / buffer bytes
_RING_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(lhs: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\]\S*)\s+([a-z][a-z0-9\-]*)\(")


def parse_collectives(hlo_text: str, loop_trip_counts=None) -> dict:
    """Per-collective-type output bytes from optimized (post-SPMD) HLO.

    ``loop_trip_counts``: optional {computation_name_fragment: trips} to
    scale collectives inside while bodies (XLA emits the body once)."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    scale = 1
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or s.startswith("ENTRY"):
            # entering a new computation definition: reset/update scale
            scale = 1
            if loop_trip_counts:
                for frag, trips in loop_trip_counts.items():
                    if frag in s.split("(")[0]:
                        scale = trips
                        break
        m = _INSTR_RE.search(s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base in out:
            out[base] += _shape_bytes(shape_str) * scale
            counts[base] += scale
    return {"bytes": out, "counts": counts}


def iter_collective_instrs(hlo_text: str):
    """Per-instruction collective records from optimized HLO text.

    Yields ``{"op": base_op, "bytes": output_bytes, "dtypes": [..]}`` for
    every collective instruction (``-start`` counted, ``-done`` skipped) —
    the instruction-level view ``repro.analysis`` rules need to separate
    scalar control traffic (loss pmean, finite-flag pmin) from bucket
    wire traffic, which ``parse_collectives`` aggregates away."""
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line.strip())
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_OPS:
            yield {"op": base,
                   "bytes": _shape_bytes(shape_str),
                   "tuple": shape_str.startswith("("),
                   "dtypes": [dt for dt, _ in _SHAPE_RE.findall(shape_str)
                              if dt in _DTYPE_BYTES]}


def dtype_wire_bytes(n_elements: int, wire_dtype: str = "float32") -> float:
    """Flat-buffer bytes to ship ``n_elements`` once at ``wire_dtype``
    (PrecisionPolicy.wire_dtype) — the dtype-aware input to
    ``exchange_wire_bytes``; a bf16 wire halves it."""
    return float(n_elements) * _DTYPE_BYTES[
        {"float32": "f32", "bfloat16": "bf16", "float16": "f16"}[wire_dtype]]


def exchange_wire_bytes(flat_bytes: float, w: int,
                        partitioned: bool = False) -> float:
    """Ring bytes per worker to exchange one flat buffer of
    ``flat_bytes`` (already dtype-scaled: see ``dtype_wire_bytes``)
    across ``w`` workers.

    ``partitioned`` documents call-site intent only: a dense all-reduce
    (2·(W−1)/W·N) and the ZeRO-1 reduce-scatter + all-gather
    ((W−1)/W·N each) move the SAME bytes — partitioning the optimizer
    state costs no extra wire (core/fabric.py::exchange_partitioned)."""
    return 2.0 * (w - 1) / w * float(flat_bytes)


def opt_state_bytes(n_params: int, state_floats: int, w: int = 1,
                    partitioned: bool = False,
                    master_floats: int = 0) -> float:
    """Per-worker optimizer-state footprint in bytes.

    Dense data parallelism replicates the full f32 state on every worker;
    ZeRO-1 (``sync_zero1`` / ``partition_grads``) partitions it so each
    worker holds 1/W — the redundancy the paper's memory-bound
    large-mini-batch regime (§2) pays for nothing.  ``state_floats`` is
    ``Optimizer.state_floats`` (0 sgd, 1 momentum, 2 adam);
    ``master_floats=1`` adds the f32 master copy a master-keeping
    precision policy stores alongside the state (in the 1/W shard on the
    ZeRO-1 path — core/precision.py, DESIGN.md §4)."""
    total = 4.0 * (state_floats + master_floats) * n_params
    return total / w if partitioned else total


def resize_moved_bytes(bucket_sizes, w_old: int, w_new: int,
                       state_floats: int = 1, itemsize: int = 4) -> float:
    """Exact bytes that change OWNER RANK in an in-memory W → W′ ZeRO
    re-partition (launch/elastic.py::resize_state, DESIGN.md §13).

    Shard chunks are rank-ordered: element ``i`` of a bucket with ``n``
    live elements is owned by rank ``i // ceil(n/W)``.  It moves in the
    resize iff its old and new owner ranks differ, so the cost is a
    breakpoint walk over the two chunk grids — O(W + W′) per bucket, not
    O(n).  ``state_floats`` counts the f32 state copies re-sharded (e.g.
    2 for adam m+v, +1 if ZeRO-3 parameter shards ride along).

    Contrast with :func:`checkpoint_roundtrip_bytes`: the checkpoint-
    restore baseline always touches EVERY element twice (write + read),
    while the in-memory path only moves the owner-changed span — for
    W=4 → 2 that is at most half the elements, and a W → W no-op moves
    zero."""
    moved = 0
    for n in bucket_sizes:
        c_old = -(-n // w_old)
        c_new = -(-n // w_new)
        i = 0
        while i < n:
            ro, rn = i // c_old, i // c_new
            nxt = min((ro + 1) * c_old, (rn + 1) * c_new, n)
            if ro != rn:
                moved += nxt - i
            i = nxt
    return float(moved * itemsize * state_floats)


def checkpoint_roundtrip_bytes(bucket_sizes, state_floats: int = 1,
                               itemsize: int = 4) -> float:
    """Disk traffic of the resize-via-checkpoint baseline: every state
    element is serialized once and parsed once (2×) regardless of how
    few elements actually change owner — the overhead the online resize
    (``resize_moved_bytes``) avoids, before even counting compression
    CPU and the filesystem round-trip."""
    n = sum(bucket_sizes)
    return float(2 * n * itemsize * state_floats)


def param_bytes(n_params: int, param_dtype: str = "float32", w: int = 1,
                zero_stage: int = 0) -> float:
    """Working-parameter bytes per worker at the policy's ``param_dtype``
    — bf16 working params halve this (while the f32 master rides the 1/W
    opt-state shard).  ZeRO stages 0-2 replicate the full parameters on
    every worker; stage 3 (``sync_zero3``) shards them too, so each
    worker holds 1/W of the flat f32 bucket image and all-gathers blocks
    transiently around the forward/backward (one bucket resident at a
    time — bounded by the bucket size, not counted here)."""
    total = dtype_wire_bytes(n_params, param_dtype)
    return total / w if zero_stage >= 3 else total


def wire_bytes_per_sample(flat_bytes: float, w: int,
                          samples_per_microbatch: int,
                          accum_steps: int = 1) -> float:
    """Ring bytes per worker per SAMPLE under microbatch accumulation
    (train/loop.py, DESIGN.md §8): one exchange per boundary is amortized
    over ``accum_steps x samples_per_microbatch`` samples, so the
    per-sample wire cost shrinks by exactly ``accum_steps`` — the
    gradient-accumulation lever of Nichols et al. (2021), on every
    strategy including the ZeRO-1 partitioned path (whose RS+AG move the
    same ring bytes as the dense all-reduce)."""
    return exchange_wire_bytes(flat_bytes, w) \
        / float(samples_per_microbatch * accum_steps)


def accum_state_bytes(n_params: int, accum_steps: int = 1, w: int = 1,
                      zero_stage: int = 0) -> float:
    """Resident bytes of the microbatch gradient accumulator: the flat f32
    bucket image of the gradients (4·N per worker) lives across the scan
    while ``accum_steps > 1``; the unaccumulated step keeps no
    accumulator.  ZeRO stage >= 2 (``sync_zero2``/``sync_zero3``)
    reduce-scatters every microbatch's gradients straight into a 1/W
    shard accumulator, shrinking this term by W.  (Bucket padding on the
    partitioned path adds < W elements per bucket — ignored here.)"""
    if accum_steps <= 1:
        return 0.0
    total = 4.0 * float(n_params)
    return total / w if zero_stage >= 2 else total


def step_state_peak_bytes(param_nbytes: float, opt_nbytes: float,
                          n_params: int, accum_steps: int = 1,
                          donated: bool = True, w: int = 1,
                          zero_stage: int = 0) -> float:
    """Peak per-worker TRAIN-STATE bytes across one step.

    With buffer donation (``donate_argnums=(0,)`` on every step jit —
    train/loop.py, launch/specs.py) the consumed state aliases the
    produced one (the dry-run's ``memory_analysis().alias_size_in_bytes``)
    so old and new params/opt-state are never both resident; without
    donation every state leaf is double-buffered.  Accumulation adds the
    f32 accumulator buckets on top.

    ``param_nbytes`` / ``opt_nbytes`` are the DENSE per-worker figures
    (``param_bytes(..., w=1)`` / ``opt_state_bytes(..., w=1)``); the ZeRO
    stage applies the sharding factors here: stage >= 1 partitions the
    optimizer state by W, stage >= 2 additionally shards the gradient
    accumulator, stage >= 3 shards the parameters themselves — the W×
    parameter-state shrink of ZeRO-3 (Rajbhandari et al.), paid back with
    one per-bucket all-gather around each forward/backward."""
    p = float(param_nbytes)
    o = float(opt_nbytes)
    if zero_stage >= 3:
        p /= w
    if zero_stage >= 1:
        o /= w
    state = p + o
    return (state if donated else 2.0 * state) \
        + accum_state_bytes(n_params, accum_steps, w, zero_stage)


def tp_wire_bytes(activation_nbytes: float, tp_degree: int,
                  n_layers: int) -> float:
    """Ring bytes per device per training step for the explicit TP
    activation combines: two row-parallel all-reduces per layer (attention
    out-projection + MLP down-projection), forward and backward — the
    ``collective_contract(..., "tp")`` budget priced at the all-reduce
    ring factor 2·(T−1)/T.  ``activation_nbytes`` is one microbatch's
    (B, L, D) activation at the compute dtype."""
    if tp_degree <= 1:
        return 0.0
    combines = 4.0 * n_layers  # (wo + w_down) x (fwd + bwd)
    return combines * 2.0 * (tp_degree - 1) / tp_degree \
        * float(activation_nbytes)


def collective_count(hlo_text: str, loop_trip_counts=None) -> int:
    """Total cross-worker collective ops in an optimized HLO module.

    The fusion check for the bucketed fabric (core/fabric.py): an exchange
    lowered through ``Fabric`` must contain at most ``layout.n_buckets``
    of these where the per-leaf path emitted one (or more) per parameter
    leaf."""
    return sum(parse_collectives(hlo_text, loop_trip_counts)["counts"].values())


def extrapolate_cost(run1: dict, run2: dict, repeat: int):
    """Linear-in-depth extrapolation from unrolled 1-/2-super-block runs.

    total(R) = cost(1) + (R − 1) · (cost(2) − cost(1)).
    Returns (cost_dict, collective_dict)."""
    c1, c2 = run1["cost"], run2["cost"]
    keys = ("flops", "bytes accessed", "transcendentals")
    cost = {}
    for k in keys:
        a, b = float(c1.get(k, 0.0)), float(c2.get(k, 0.0))
        cost[k] = a + (repeat - 1) * max(0.0, b - a)
    p1 = parse_collectives(run1["hlo"])
    p2 = parse_collectives(run2["hlo"])
    coll = {"bytes": {}, "counts": {}}
    for k in COLLECTIVE_OPS:
        a, b = p1["bytes"][k], p2["bytes"][k]
        coll["bytes"][k] = a + (repeat - 1) * max(0, b - a)
        a, b = p1["counts"][k], p2["counts"][k]
        coll["counts"][k] = a + (repeat - 1) * max(0, b - a)
    return cost, coll


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float
    hbm_bytes: float
    collective: dict
    model_flops_per_device: float
    hbm_bytes_unfused_upper: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops_per_device / self.flops if self.flops else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_unfused_upper": self.hbm_bytes_unfused_upper,
            "collective_bytes": self.collective["bytes"],
            "collective_counts": self.collective["counts"],
            "model_flops_per_device": self.model_flops_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops_per_device(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (fwd)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        factor = 2.0
    return factor * n_active * tokens / n_devices


def analyse(arch, shape, mesh_label, n_devices, cost, coll, cfg,
            mem=None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    if mem is not None:
        hbm = float(mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    else:
        hbm = float(cost.get("bytes accessed", 0.0))
    r = Roofline(
        arch=arch, shape=shape.name, mesh=mesh_label,
        flops=flops, hbm_bytes=hbm, collective=coll,
        model_flops_per_device=model_flops_per_device(cfg, shape, n_devices),
        hbm_bytes_unfused_upper=float(cost.get("bytes accessed", 0.0)))
    r.compute_s = flops / PEAK_FLOPS_BF16
    r.memory_s = hbm / HBM_BW
    wire = sum(_RING_FACTOR[k] * v for k, v in coll["bytes"].items())
    r.collective_s = wire / ICI_BW
    return r
