"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT vision encoder + mistral-nemo decoder backbone.
The vision frontend (ViT + projector) is a STUB per the brief —
input_specs() provides precomputed patch embeddings.
[hf:mistralai/Pixtral-12B-2409]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    modality="vision",
))
