"""xlstm-125m [ssm]: 12 blocks d_model=768 4H vocab=50304, alternating
sLSTM + mLSTM blocks (d_ff=0: blocks carry their own up-projections).
[arXiv:2405.04517]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    tie_embeddings=True,
    xlstm_pattern=("mlstm", "slstm"),
    ssm_expand=2,
))
