"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,               # routed-expert hidden dim
    moe_d_ff=1408,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=False,
    num_experts=60,
    top_k=4,
    num_shared_experts=4,
    moe_every=1,
))
