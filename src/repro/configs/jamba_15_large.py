"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
expert d_ff=24576 vocab=65536, Mamba+attention 1:7 interleave, MoE 16
experts top-2 every other layer. [arXiv:2403.19887]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    moe_d_ff=24576,
    vocab_size=65_536,
    tie_embeddings=False,
    attn_every=8,            # 1 attention : 7 mamba per super-block
    num_experts=16,
    top_k=2,
    moe_every=2,             # MoE ffn every other layer
    ssm_state_dim=16,
    ssm_expand=2,
))
