"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) expert
d_ff=512 vocab=49155, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    moe_d_ff=512,
    vocab_size=49_155,
    tie_embeddings=True,
    num_experts=32,
    top_k=8,
    num_shared_experts=0,
    moe_every=1,
))
