"""Model configuration system.

Every assigned architecture is expressed as a ModelConfig built from LayerSpec
super-blocks: the layer stack is ``superblock * repeat`` (+ optional remainder),
which maps 1:1 onto ``jax.lax.scan`` over stacked parameters in
``models/transformer.py``.  Heterogeneous stacks (Jamba's 1:7 attn:mamba
interleave, xLSTM's sLSTM/mLSTM alternation) are fixed structures *within* the
super-block, so the scan stays homogeneous.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

FULL_ATTENTION = -1  # sentinel: no sliding window


@dataclass(frozen=True)
class LayerSpec:
    """Static structure of one layer inside a super-block."""

    mixer: str = "attn"  # attn | mamba | mlstm | slstm | none
    ffn: str = "mlp"  # mlp | moe | none
    window: int = FULL_ATTENTION  # sliding window (tokens); -1 = full attention
    rope_theta: float = 10_000.0


@dataclass(frozen=True)
class ModelConfig:
    # identity ----------------------------------------------------------------
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""  # citation for the config

    # trunk -------------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: Optional[int] = None  # default: d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32_000
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # attention ---------------------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    attn_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # window for "local" layers
    global_every: Optional[int] = None  # 1 global layer per N (gemma3: 6)
    global_rope_theta: Optional[float] = None  # rope theta for global layers

    # MoE ---------------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: Optional[int] = None  # expert hidden dim (defaults to d_ff)
    moe_every: int = 1  # MoE ffn every N layers (others use dense mlp)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    expert_pad_to: int = 16  # pad expert count to a multiple (EP divisibility)

    # SSM / hybrid ------------------------------------------------------------
    attn_every: Optional[int] = None  # hybrid: 1 attn layer per N (jamba: 8)
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256  # time-chunk for the selective scan
    xlstm_pattern: Optional[tuple] = None  # e.g. ("mlstm", "slstm")

    # encoder-decoder ---------------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 3072  # frozen source length used for decode shapes

    # multimodal stub ---------------------------------------------------------
    modality: Optional[str] = None  # None | "audio" | "vision"

    # numerics ----------------------------------------------------------------
    # validated at construction against the precision subsystem's allowed
    # set (core/precision.py) so a bad dtype fails HERE, not deep inside
    # model init where the offending config is long out of the traceback
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # execution ---------------------------------------------------------------
    # lax.scan over the layer stack (compact HLO, fast compile) vs unrolled
    # (exact cost_analysis: XLA counts while-loop bodies once — the dry-run
    # unrolls so roofline FLOPs/bytes/collectives are trip-count-true).
    scan_layers: bool = True
    remat: bool = True
    # "tp": heads/d_ff sharded over "model" (Megatron TP) — paper-faithful
    #       baseline for the dry-run.
    # "cp": sequence sharded over "model" (context parallel): MLP is fully
    #       local, attention all-gathers the (small, GQA) KV — §Perf it. 4.
    sharding_mode: str = "tp"
    # §Perf iteration 2 (EXPERIMENTS.md): saving MoE a2a results across the
    # remat boundary cuts wire traffic ~21% but costs ~2.7 GB/layer/device —
    # exceeds 16 GB HBM on the large MoE trains, so opt-in only.
    save_moe_a2a: bool = False
    # Explicit tensor parallelism (models/tensor_parallel.py, DESIGN.md §12):
    # tp_degree > 1 switches the two ROW-PARALLEL contractions (attention
    # out-projection over heads, MLP down-projection over d_ff) to the
    # blocked-canonical form — a stacked sum of tp_degree partial einsums.
    # Unsharded, this is the bitwise REFERENCE for a TP run of the same
    # degree: each TP rank computes exactly one of those partials and the
    # combine is the same stacked sum (for degree 2 a single f32 add, which
    # is order-independent by IEEE commutativity).  tp_degree=1 keeps the
    # historical single-einsum path untouched.
    tp_degree: int = 1

    # ------------------------------------------------------------------------
    def __post_init__(self):
        from repro.core.precision import ALLOWED_DTYPES
        for f in ("param_dtype", "compute_dtype"):
            v = getattr(self, f)
            if v not in ALLOWED_DTYPES:
                raise ValueError(
                    f"{self.name}: {f}={v!r} is not a supported precision "
                    f"dtype; choose one of {ALLOWED_DTYPES} "
                    "(see core/precision.py)")
        t = self.tp_degree
        if t < 1:
            raise ValueError(f"{self.name}: tp_degree must be >= 1, got {t}")
        if t > 1:
            # only the dims the row/column split partitions need to divide
            for f, v in (("num_heads", self.num_heads),
                         ("num_kv_heads", self.num_kv_heads),
                         ("d_ff", self.d_ff)):
                if v and v % t:
                    raise ValueError(
                        f"{self.name}: tp_degree={t} does not divide "
                        f"{f}={v}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def num_experts_padded(self) -> int:
        """Experts padded up so the expert dim divides the EP axis (dummy
        experts hold zero weights and are never routed to)."""
        p = self.expert_pad_to
        return -(-self.num_experts // p) * p if self.num_experts else 0

    # ---- layer-stack structure ------------------------------------------------
    def superblock(self) -> tuple:
        """(specs, repeat): the decoder stack is ``specs`` repeated ``repeat``×."""
        n = self.num_layers
        if self.family == "ssm" and self.xlstm_pattern:
            pat = tuple(LayerSpec(mixer=m, ffn="none") for m in self.xlstm_pattern)
            assert n % len(pat) == 0, (self.name, n, pat)
            return pat, n // len(pat)
        if self.family == "hybrid" and self.attn_every:
            k = self.attn_every
            assert n % k == 0
            specs = []
            for i in range(k):
                mixer = "attn" if i == 0 else "mamba"
                ffn = "moe" if (self.num_experts and (i % self.moe_every == self.moe_every - 1)) else "mlp"
                specs.append(LayerSpec(mixer=mixer, ffn=ffn, rope_theta=self.rope_theta))
            return tuple(specs), n // k
        # uniform stacks (dense / moe / vlm / audio-decoder): superblock of 1.
        ffn = "moe" if self.num_experts else "mlp"
        specs = (LayerSpec(mixer="attn", ffn=ffn, rope_theta=self.rope_theta),)
        return specs, n

    def layer_windows(self):
        """Per-layer (window, rope_theta) for uniform attention stacks.

        Returns arrays of shape (repeat, len(superblock)) used as scanned
        values — this is how gemma3's 5:1 local:global pattern rides a
        homogeneous scan.
        """
        import numpy as np

        specs, repeat = self.superblock()
        s = len(specs)
        windows = np.full((repeat, s), FULL_ATTENTION, dtype=np.int32)
        thetas = np.full((repeat, s), self.rope_theta, dtype=np.float32)
        if self.sliding_window is not None:
            n = self.num_layers
            assert s == 1, "sliding-window patterns only supported on uniform stacks"
            for li in range(n):
                if self.global_every and (li + 1) % self.global_every == 0:
                    windows[li, 0] = FULL_ATTENTION
                    thetas[li, 0] = self.global_rope_theta or self.rope_theta
                else:
                    windows[li, 0] = self.sliding_window
                    thetas[li, 0] = self.rope_theta
        return windows, thetas

    def with_sliding_window(self, window: int = 4096) -> "ModelConfig":
        """Sliding-window *variant* for long-context decode on full-attention
        archs (see DESIGN.md §5 — explicitly flagged as a variant)."""
        return replace(self, sliding_window=window, global_every=None,
                       name=self.name + "-swa")

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 super-blocks, d_model ≤ 512, ≤4 experts."""
        specs, _ = self.superblock()
        nl = len(specs) * min(2, max(1, self.num_layers // len(specs)))
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        d_model = min(self.d_model, 256)
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=nl,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=min(self.resolved_head_dim, 64),
            d_ff=min(self.d_ff, 512) or 0,
            moe_d_ff=min(self.expert_d_ff, 256) if self.num_experts else None,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            expert_pad_to=1,
            top_k=min(self.top_k, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 64),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            global_every=2 if self.global_every else None,
        )

    # ---- parameter count -------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        qkv = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        attn = qkv + o + (self.num_heads * hd + 2 * self.num_kv_heads * hd if self.qkv_bias else 0)
        mlp = 3 * d * self.d_ff
        moe = 0
        if self.num_experts:
            moe = self.num_experts * 3 * d * self.expert_d_ff + d * self.num_experts
            moe += self.num_shared_experts * 3 * d * self.expert_d_ff
        d_in = self.ssm_expand * d
        mamba = (d * d_in * 2 + d_in * self.ssm_conv_dim + d_in * (self.ssm_state_dim * 2 + 1)
                 + d_in * self.ssm_state_dim + d_in + d_in * d)
        mlstm_d = (d * d_in * 2 + 3 * d_in + d_in * d)  # qkv from x, gates, out
        slstm_d = 4 * d * d + 4 * d * d + d * self.d_ff if self.d_ff else 8 * d * d

        specs, repeat = self.superblock()
        total = 0
        for spec in specs:
            if spec.mixer == "attn":
                total += attn
            elif spec.mixer == "mamba":
                total += mamba
            elif spec.mixer == "mlstm":
                total += mlstm_d
            elif spec.mixer == "slstm":
                total += slstm_d
            if spec.ffn == "mlp":
                total += mlp
            elif spec.ffn == "moe":
                total += moe
            total += 2 * d  # norms
        total *= repeat
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        total += d  # final norm
        if self.is_encoder_decoder:
            enc = self.num_encoder_layers * (attn + mlp + 2 * d)
            xattn = self.num_layers * (qkv + o + d)  # cross-attention per decoder layer
            total += enc + xattn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.expert_d_ff
        specs, repeat = self.superblock()
        n_moe_layers = sum(1 for s in specs if s.ffn == "moe") * repeat
        inactive = n_moe_layers * (self.num_experts - self.top_k) * per_expert
        return int(full - inactive)


# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name.endswith("-swa"):
        return get_config(name[:-4]).with_sliding_window()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        gemma3_1b, deepseek_67b, seamless_m4t_medium, xlstm_125m,
        qwen25_14b, qwen2_moe_a27b, granite_moe_1b, pixtral_12b,
        jamba_15_large, qwen2_15b,
    )
