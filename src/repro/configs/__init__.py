from repro.configs.base import (  # noqa: F401
    FULL_ATTENTION,
    LayerSpec,
    ModelConfig,
    get_config,
    list_configs,
    register,
)
