"""seamless-m4t-medium [audio]: enc-dec, 12L d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=256206. Audio frontend (mel + conv feature extractor) is a
STUB per the brief — input_specs() provides precomputed frame embeddings.
[arXiv:2308.11596]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=12,            # decoder layers
    num_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    tie_embeddings=True,
    modality="audio",
    encoder_seq_len=3072,     # frozen source-frame length for decode shapes
))
