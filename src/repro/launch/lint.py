import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"  # noqa: E501 — MUST precede any jax import

"""Static-analysis linter CLI (DESIGN.md §11): compile the production
exchange/train-step rigs for every (config × strategy × precision ×
accum) cell and lint the jaxprs/HLO against the repo's performance
contracts (repro.analysis).  (The two lines above give the single-CPU
container 8 placeholder devices so the shard_map exchange rigs can
build a 4-wide 'pod' mesh; set ONLY here and in dryrun, never globally.)

Usage:
    PYTHONPATH=src python -m repro.launch.lint --arch gemma3-1b
    PYTHONPATH=src python -m repro.launch.lint --all [--out LINT.json]
    PYTHONPATH=src python -m repro.launch.lint --validate

``--all`` writes the committed ``LINT.json`` artifact; CI re-validates
it (and a ``LINT_SMOKE=1`` rerun) exactly like the bench tiers.  Exit
codes: 0 clean, 1 rule violations, 2 unknown config name.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

from repro.analysis import report as R  # noqa: E402
from repro.analysis import sweep as SW  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
OUT = os.path.join(ROOT, "LINT.json")


def _progress(cell):
    counts = {"pass": 0, "fail": 0, "skip": 0}
    for r in cell.rules:
        counts[r.status] += 1
    tag = (f"{cell.config}/{cell.strategy}/{cell.precision}"
           f"/accum{cell.accum}")
    print(f"  {tag}: pass={counts['pass']} skip={counts['skip']}"
          + (f" FAIL={counts['fail']}" if counts["fail"] else ""),
          flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.lint",
        description="jaxpr/HLO invariant linter over the production matrix")
    ap.add_argument("--arch", help="lint a single config (all strategies "
                    "x precisions x accums)")
    ap.add_argument("--all", action="store_true",
                    help="sweep every lint config and write the artifact")
    ap.add_argument("--out", default=None,
                    help=f"artifact path (default {OUT} with --all)")
    ap.add_argument("--smoke", action="store_true",
                    help="small config slice (also via LINT_SMOKE=1)")
    ap.add_argument("--validate", action="store_true",
                    help="validate the committed artifact and exit")
    args = ap.parse_args(argv)

    out_path = args.out or OUT
    if args.validate:
        rep = R.validate_file(out_path)
        s = rep["summary"]
        print(f"{out_path}: OK — {s['cells']} cells, {s['pass']} pass, "
              f"{s['skip']} skip, smoke={rep['meta']['smoke']}")
        return 0

    smoke = args.smoke or os.environ.get("LINT_SMOKE") == "1"
    configs = None
    if args.arch is not None:
        if args.arch not in SW.LINT_CONFIGS:
            print(f"unknown config {args.arch!r}; valid names: "
                  + ", ".join(SW.LINT_CONFIGS), file=sys.stderr)
            raise SystemExit(2)
        configs = (args.arch,)
    elif not args.all:
        ap.error("one of --arch, --all or --validate is required")

    t0 = time.time()
    rep = SW.run(configs=configs, smoke=smoke, progress=_progress)
    s = rep["summary"]
    print(f"linted {s['cells']} cells in {time.time() - t0:.1f}s: "
          f"{s['pass']} pass, {s['skip']} skip, {s['fail']} fail")
    if args.all or args.out:
        with open(out_path, "w") as f:
            json.dump(rep, f, indent=1, sort_keys=True)
        print(f"wrote {out_path}")
    bad = R.violations(rep)
    for line in bad:
        print(f"VIOLATION {line}", file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
