"""Elastic fault-tolerant fleet training (DESIGN.md §13).

The launch layer's answer to preemptible fleets: worker failure,
preemption, slowness, and (re)join are first-class *boundary events*
instead of run-killers.  Four pieces:

  * :class:`FleetView` — epoch-numbered membership.  Workers keep stable
    global ids; ranks are their index in the sorted member tuple, so
    rank reassignment after any transition is deterministic and needs no
    coordinator state.  Every transition bumps ``epoch``; membership only
    changes AT optimizer boundaries (between exchanges), never inside one.
  * :func:`resize_state` — the in-memory, online W → W′ re-partition.
    ZeRO shard-bucket state goes through ``core/resharding.py`` — the
    SAME ``reshard_bucket`` the checkpoint restore uses, so the live
    resize is bitwise-equal to a ``save → restore(repartition=True)``
    round-trip with no disk round-trip.  Dense replica-stacked state is
    row-gathered (survivors keep their row, joiners copy the sync
    consensus row).
  * :func:`make_elastic_replica_step` — a dense-sync boundary step that
    takes the fleet's participation mask as a TRACED input: straggler
    demotion/promotion flips mask entries, never retraces.  Demoted
    workers keep taking LOCAL optimizer steps (the paper's loose-coupling
    tier) and are pulled back to the sync consensus by a ``lax.cond``-
    gated resync the static-analysis tier verifies
    (``elastic-demotion-gated`` rule).
  * :class:`ElasticFleet` — the boundary-driven controller wiring it all
    to the chaos harness (``core/chaos.py``) and the straggler detector
    (``core/staleness.py``): graceful preempt/rejoin resizes, bounded
    retry + exponential backoff on exchange failure, and graceful
    degradation — workers still failing after the retries are dropped
    from the next epoch and the surviving fleet re-runs the boundary
    from the last consistent state (state commits only on success).

Scope: the stacked-replica simulator (plain ``LocalComm``, lead axis 0).
Delivery-buffer strategies (ssp/downpour ring buffers keyed by schedule
slot, not worker) are not elastically resizable and fail loudly in
``resize_dense_tree``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chaos import ChaosSchedule, ExchangeFailure, FleetClock
from repro.core.comm import LocalComm, ShardComm
from repro.core.fabric import DEFAULT_BUCKET_BYTES, Fabric
from repro.core.resharding import repartition_tree
from repro.core.staleness import StragglerDetector, StragglerPolicy
from repro.core.strategies import _gate


# ---------------------------------------------------------------------------
# membership
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FleetView:
    """One epoch of fleet membership.

    ``members`` are stable global worker ids (sorted); a worker's rank is
    its index in the tuple — deterministic across every controller that
    sees the same view, with no extra coordination.  ``demoted`` members
    still hold a rank and a replica row but sit in the local-step tier
    (mask 0).  Transitions return a NEW view with ``epoch + 1``."""

    epoch: int
    members: tuple
    demoted: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "members", tuple(sorted(set(self.members))))
        object.__setattr__(
            self, "demoted",
            tuple(sorted(set(self.demoted) & set(self.members))))

    @property
    def size(self) -> int:
        return len(self.members)

    def rank_of(self, worker) -> int:
        return self.members.index(worker)

    def mask(self) -> np.ndarray:
        """(W,) f32 participation mask: 1 = sync tier, 0 = demoted."""
        return np.array([0.0 if w in self.demoted else 1.0
                         for w in self.members], np.float32)

    def without(self, *workers) -> "FleetView":
        return FleetView(self.epoch + 1,
                         tuple(w for w in self.members if w not in workers),
                         self.demoted)

    def with_joined(self, *workers) -> "FleetView":
        return FleetView(self.epoch + 1, self.members + tuple(workers),
                         self.demoted)

    def with_demoted(self, demoted) -> "FleetView":
        return FleetView(self.epoch + 1, self.members, tuple(demoted))


# ---------------------------------------------------------------------------
# in-memory resize
# ---------------------------------------------------------------------------
def _row_index(old_view: FleetView, new_view: FleetView) -> np.ndarray:
    """Old-row index for each new member: survivors keep their own row,
    joiners copy the consensus row (the first surviving sync-tier member
    — sync training keeps those rows identical, so the choice is exact,
    not approximate)."""
    common = [w for w in new_view.members if w in old_view.members]
    if not common:
        raise ValueError("resize with no surviving member — nothing to "
                         "carry the fleet state across the transition")
    sync_common = [w for w in common if w not in old_view.demoted]
    consensus = old_view.rank_of((sync_common or common)[0])
    return np.array([old_view.rank_of(w) if w in old_view.members
                     else consensus for w in new_view.members])


def resize_dense_tree(tree, old_view: FleetView, new_view: FleetView):
    """Row-gather every stacked (W, …) leaf from the old view's rows to
    the new view's.  Leaves without a leading worker axis are rejected —
    that is what makes ssp/downpour delivery buffers fail loudly instead
    of being silently corrupted."""
    idx = jnp.asarray(_row_index(old_view, new_view))
    w = old_view.size

    def one(x):
        if getattr(x, "ndim", 0) == 0 or x.shape[0] != w:
            raise ValueError(
                f"leaf with shape {getattr(x, 'shape', ())} has no leading "
                f"worker axis of size {w} — not elastically resizable "
                "(stacked replica-first layout required)")
        return jnp.asarray(x)[idx]

    return jax.tree.map(one, tree)


def resize_state(state, old_view: FleetView, new_view: FleetView, *,
                 strategy=None, bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Re-partition a train state in memory for a fleet transition.

    ZeRO shard-bucket state (``sync_zero1/2`` opt shards, ``sync_zero3``
    parameter shards) is re-sharded with ``core/resharding`` — bitwise
    what a checkpoint save → ``restore(repartition=True)`` round-trip
    produces, without touching disk.  Dense replica-stacked state is
    row-gathered per :func:`resize_dense_tree`.  ``bucket_bytes`` must
    match the strategy's own bucket layout (it is re-derived from the
    full parameter tree, exactly like the save path derives its
    ``partition=`` spec).

    For ZeRO-3 the strategy's recorded :class:`PartitionedLayout` is
    re-primed for the new worker count via an allocation-free
    ``eval_shape`` of ``init_params`` — ``gather_params`` keeps working
    after the resize."""
    if old_view.members == new_view.members:
        return dict(state)
    comm_old = LocalComm(old_view.size)
    comm_new = LocalComm(new_view.size)
    owns_params = bool(strategy is not None
                       and getattr(strategy, "owns_params", False))
    sharded_opt = bool(strategy is not None
                       and getattr(strategy, "init_opt", None) is not None)

    new_state = {"step": state["step"]}
    sizes = None
    if sharded_opt or owns_params:
        full_old = (strategy.gather_params(state["params"], comm_old)
                    if owns_params else state["params"])
        play = Fabric(comm_old, bucket_bytes).partitioned_layout(full_old)
        sizes = play.layout.bucket_sizes

    if owns_params:
        new_state["params"] = repartition_tree(state["params"], sizes,
                                               new_view.size)
        # re-prime the strategy's recorded layout for the new width so
        # gather_params works post-resize; eval_shape allocates nothing
        full_new = resize_dense_tree(full_old, old_view, new_view)
        jax.eval_shape(lambda p: strategy.init_params(p, comm_new), full_new)
    else:
        new_state["params"] = resize_dense_tree(state["params"], old_view,
                                                new_view)

    new_state["opt_state"] = (
        repartition_tree(state["opt_state"], sizes, new_view.size)
        if sharded_opt
        else resize_dense_tree(state["opt_state"], old_view, new_view))
    new_state["comm_state"] = resize_dense_tree(state["comm_state"],
                                                old_view, new_view)
    if "master" in state:
        new_state["master"] = resize_dense_tree(state["master"], old_view,
                                                new_view)
    if "loss_scale" in state:
        new_state["loss_scale"] = state["loss_scale"]
    return new_state


# ---------------------------------------------------------------------------
# masked boundary step (straggler tiers)
# ---------------------------------------------------------------------------
def _member_scalar(comm, mask):
    """This worker's mask entry: the replicated (W,) vector itself on the
    stacked simulator (it aligns with the lead axis), the rank's scalar
    under shard_map."""
    if isinstance(comm, ShardComm):
        return jnp.take(mask, comm.worker_index())
    return mask


def _bcast(m, x, comm):
    if isinstance(comm, ShardComm):
        return m
    return m.reshape(m.shape + (1,) * (x.ndim - 1))


def masked_exchange(fab: Fabric, grads, mask):
    """Sync-tier mean with local-tier passthrough.

    Sync members (mask 1) receive sum(mask·g)/n_sync — with an all-ones
    mask this is bitwise the dense all-mean at power-of-two W.  Demoted
    members (mask 0) keep their LOCAL gradient: they still take optimizer
    steps, just without waiting on (or slowing down) the collective."""
    comm = fab.comm
    m = _member_scalar(comm, mask)
    nsync = jnp.maximum(jnp.sum(mask), 1.0)
    weighted = jax.tree.map(
        lambda g: g.astype(jnp.float32) * _bcast(m, g, comm), grads)
    summed = fab.all_sum(weighted)

    def blend(s, g):
        gb = _bcast(m, g, comm)
        return gb * (s / nsync) + (1.0 - gb) * g.astype(jnp.float32)

    g_eff = jax.tree.map(blend, summed, grads)
    return g_eff, fab.metrics(fab.flat_bytes(grads))


def demoted_resync(fab: Fabric, params, mask, t, resync_every: int):
    """Cond-gated recovery pull for the local tier.

    Every ``resync_every`` boundaries the demoted rows are reset to the
    sync-tier consensus, so a re-promoted worker rejoins from fleet state
    rather than its drifted local weights.  The consensus collective sits
    UNDER ``lax.cond`` — on non-resync boundaries no bytes move, which is
    exactly what the ``elastic-demotion-gated`` lint rule proves on this
    function's jaxpr (demotion must REDUCE a straggler's wire cost, not
    smuggle it back in every boundary)."""
    comm = fab.comm

    def pull(p):
        m = _member_scalar(comm, mask)
        nsync = jnp.maximum(jnp.sum(mask), 1.0)
        weighted = jax.tree.map(
            lambda x: x.astype(jnp.float32) * _bcast(m, x, comm), p)
        consensus = jax.tree.map(lambda s: s / nsync, fab.all_sum(weighted))
        return jax.tree.map(
            lambda x, c: (_bcast(m, x, comm) * x.astype(jnp.float32)
                          + (1.0 - _bcast(m, x, comm)) * c).astype(x.dtype),
            p, consensus)

    do = (t + 1) % resync_every == 0
    return _gate(do, pull, params), do


def _masked_divergence(params, mask):
    """Max |x − sync_mean| over sync rows — 0 when the sync tier agrees."""
    n = jnp.maximum(jnp.sum(mask), 1.0)

    def one(x):
        x = x.astype(jnp.float32)
        mb = mask.reshape((mask.shape[0],) + (1,) * (x.ndim - 1))
        mean = jnp.sum(x * mb, axis=0, keepdims=True) / n
        return jnp.max(jnp.abs((x - mean) * mb))

    leaves = [one(x) for x in jax.tree.leaves(params)]
    return jnp.max(jnp.stack(leaves)) if leaves else jnp.zeros(())


def make_elastic_replica_step(loss_fn, optimizer, comm: LocalComm, *,
                              resync_every: int = 8,
                              bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                              jit: bool = True, donate: bool = True):
    """Dense-sync boundary step with a traced participation mask.

    ``step(state, batches, mask) -> (state, metrics)``: ``mask`` is a
    (W,) f32 input, so demotion/promotion changes VALUES only — the per-
    width compilation is reused across every tier change (retrace only on
    an actual resize, where W changes)."""
    fab = Fabric(comm, bucket_bytes)
    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    def step(state, batches, mask):
        loss, grads = grad_fn(state["params"], batches)
        g_eff, m = masked_exchange(fab, grads, mask)
        params, opt_state = optimizer.update(g_eff, state["opt_state"],
                                             state["params"], state["step"])
        params, did_resync = demoted_resync(fab, params, mask, state["step"],
                                            resync_every)
        new_state = {"params": params, "opt_state": opt_state,
                     "comm_state": state["comm_state"],
                     "step": state["step"] + 1}
        metrics = dict(m)
        metrics["loss"] = jnp.mean(loss)
        metrics["resync"] = did_resync
        metrics["sync_divergence"] = _masked_divergence(params, mask)
        return new_state, metrics

    return jax.jit(step, donate_argnums=(0,)) if (jit and donate) else (
        jax.jit(step) if jit else step)


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------
class ElasticFleet:
    """Boundary-driven elastic controller over the stacked simulator.

    Owns the :class:`FleetView`, the train state, and one compiled step
    per fleet width.  ``run_boundary(batch_fn)`` executes one optimizer
    boundary end-to-end: graceful membership events → straggler
    demotion/promotion → the exchange attempt loop (bounded retry with
    exponential backoff; persistent failures degrade to the survivors) →
    the committed step.  ``batch_fn(view, t)`` must return stacked
    (W, …) batches for the CURRENT view, so a mid-boundary resize
    regenerates correct-width data.

    State is committed only when the step succeeds: a boundary that loses
    workers re-runs on the surviving fleet from the last consistent
    state, so recovery completes within that same boundary."""

    def __init__(self, params, loss_fn, optimizer, *, workers: int = 4,
                 straggler_policy: StragglerPolicy | None = None,
                 resync_every: int = 8,
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 chaos: ChaosSchedule | None = None,
                 clock: FleetClock | None = None,
                 retries: int = 2, backoff_s: float = 0.01):
        self.view = FleetView(0, tuple(range(workers)))
        self.loss_fn, self.optimizer = loss_fn, optimizer
        self.resync_every = resync_every
        self.bucket_bytes = bucket_bytes
        self.chaos = chaos
        self.clock = clock or (FleetClock(workers) if straggler_policy
                               else None)
        self.retries, self.backoff_s = retries, backoff_s
        self.detector = (StragglerDetector(range(workers), straggler_policy)
                         if straggler_policy else None)
        comm = LocalComm(workers)
        stacked = comm.replicate(params)
        self.state = {"params": stacked, "opt_state": optimizer.init(stacked),
                      "comm_state": {}, "step": jnp.zeros((), jnp.int32)}
        self._steps = {}
        self.history = []

    def _step_for(self, width: int):
        if width not in self._steps:
            self._steps[width] = make_elastic_replica_step(
                self.loss_fn, self.optimizer, LocalComm(width),
                resync_every=self.resync_every,
                bucket_bytes=self.bucket_bytes)
        return self._steps[width]

    def resize(self, new_view: FleetView) -> None:
        """Commit a membership transition at the current boundary."""
        old = self.view
        if new_view.members != old.members:
            self.state = resize_state(self.state, old, new_view,
                                      bucket_bytes=self.bucket_bytes)
        if self.detector is not None:
            for w in set(old.members) - set(new_view.members):
                self.detector.drop(w)
            for w in set(new_view.members) - set(old.members):
                self.detector.add(w)
        self.view = new_view

    def _straggler_pass(self, events, log) -> None:
        if self.clock is None:
            return
        self.clock.apply(events)
        times = self.clock.boundary_times(self.view.members)
        log["boundary_times"] = times
        if self.detector is None:
            return
        self.detector.observe(times)
        demote, promote = self.detector.to_demote(), self.detector.to_promote()
        for w in demote:
            self.detector.demote(w)
        for w in promote:
            self.detector.promote(w)
        if demote or promote:
            log["demoted"], log["promoted"] = demote, promote
            self.resize(self.view.with_demoted(self.detector.demoted))

    def _attempt_exchange(self, t: int, attempt: int, kills, flakes) -> None:
        failed = set(kills) | (set(flakes) if attempt == 0 else set())
        if failed:
            raise ExchangeFailure(
                f"boundary {t}: collective failed at attempt {attempt} "
                f"for workers {sorted(failed)}",
                workers=failed, transient=not kills)

    def run_boundary(self, batch_fn) -> dict:
        t = int(self.state["step"])
        events = self.chaos.at(t) if self.chaos else []
        log = {"t": t, "epoch": self.view.epoch, "size": self.view.size,
               "events": [e.spec() for e in events], "attempts": 0,
               "backoffs": []}
        # announced transitions first: rejoin/preempt resize gracefully
        joins = [e.worker for e in events
                 if e.kind == "rejoin" and e.worker not in self.view.members]
        if joins:
            self.resize(self.view.with_joined(*joins))
        pre = [e.worker for e in events
               if e.kind == "preempt" and e.worker in self.view.members]
        if pre:
            self.resize(self.view.without(*pre))
        self._straggler_pass(events, log)
        # the exchange attempt loop: flakes clear on retry, kills exhaust
        # the retries and degrade the fleet to the survivors
        kills = {e.worker for e in events
                 if e.kind == "kill" and e.worker in self.view.members}
        flakes = {e.worker for e in events
                  if e.kind == "flake" and e.worker in self.view.members}
        attempt, backoff = 0, self.backoff_s
        while True:
            try:
                self._attempt_exchange(t, attempt, kills, flakes)
                break
            except ExchangeFailure as e:
                log["attempts"] += 1
                if attempt >= self.retries:
                    if not e.transient:
                        # graceful degradation: drop the dead workers from
                        # the next epoch and re-run on the survivors
                        log["dropped"] = sorted(kills)
                        self.resize(self.view.without(*kills))
                        kills, flakes = set(), set()
                        attempt, backoff = 0, self.backoff_s
                        continue
                    raise
                log["backoffs"].append(backoff)
                time.sleep(backoff)
                backoff *= 2
                attempt += 1
        # the committed step, on whatever fleet survived
        batches = batch_fn(self.view, t)
        mask = jnp.asarray(self.view.mask())
        self.state, metrics = self._step_for(self.view.size)(
            self.state, batches, mask)
        log["epoch_after"] = self.view.epoch
        log["size_after"] = self.view.size
        log["loss"] = float(metrics["loss"])
        self.history.append(log)
        return log

    def run(self, n_boundaries: int, batch_fn) -> list:
        return [self.run_boundary(batch_fn) for _ in range(n_boundaries)]
