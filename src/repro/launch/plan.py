"""Auto-parallelism planner CLI (DESIGN.md §12).

Usage:
    PYTHONPATH=src python -m repro.launch.plan --arch deepseek-67b
    PYTHONPATH=src python -m repro.launch.plan --all [--out PLAN.json]
    PYTHONPATH=src python -m repro.launch.plan --validate

``--all`` writes the committed ``PLAN.json`` artifact; CI validates it,
reruns a ``PLAN_SMOKE=1`` slice and re-validates, exactly like the bench
and lint tiers.  ``--validate`` also cross-checks every chosen plan
against the committed ``LINT.json`` analysis-tier results when present.
Exit codes: 0 clean, 1 validation failure, 2 unknown config name.
"""

import argparse
import json
import os
import sys
import time

from repro.launch import planner as PL

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
OUT = os.path.join(ROOT, "PLAN.json")
TIMING = os.path.join(ROOT, "BENCH_timing.json")


def _show(plan: dict):
    ch, base = plan["chosen"], plan["baseline_dp"]
    print(f"{plan['config']}: dp={ch['dp']} tp={ch['tp']} "
          f"zero={ch['zero_stage']} accum={ch['accum_steps']} "
          f"{ch['precision']} -> {ch['step_s']:.3f}s/step "
          f"(state {ch['state_gb']:.2f} GB, "
          f"pure-DP {base['step_s']:.3f}s, "
          f"{plan['speedup_vs_dp']:.2f}x, "
          f"{plan['candidates_searched']} candidates)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.plan",
        description="roofline-driven auto-parallelism planner")
    ap.add_argument("--arch", help="plan a single config")
    ap.add_argument("--all", action="store_true",
                    help="plan every eval config and write the artifact")
    ap.add_argument("--out", default=None,
                    help=f"artifact path (default {OUT} with --all)")
    ap.add_argument("--smoke", action="store_true",
                    help="small config slice (also via PLAN_SMOKE=1)")
    ap.add_argument("--validate", action="store_true",
                    help="validate the committed artifact and exit")
    args = ap.parse_args(argv)

    out_path = args.out or OUT
    if args.validate:
        try:
            rep = PL.validate_file(out_path)
        except ValueError as e:
            print(f"VALIDATION FAILED: {e}", file=sys.stderr)
            return 1
        s = rep["summary"]
        print(f"{out_path}: OK — {s['configs']} plans, "
              f"{s['beat_pure_dp']} beat pure DP, "
              f"smoke={rep['meta']['smoke']}")
        return 0

    smoke = args.smoke or os.environ.get("PLAN_SMOKE") == "1"
    names = None
    if args.arch is not None:
        valid = PL.plan_configs()
        if args.arch not in valid:
            print(f"unknown config {args.arch!r}; valid names: "
                  + ", ".join(valid), file=sys.stderr)
            raise SystemExit(2)
        names = (args.arch,)

    t0 = time.time()
    rep = PL.build_report(names=names, smoke=smoke, timing_path=TIMING)
    for plan in rep["plans"]:
        _show(plan)
    s = rep["summary"]
    print(f"planned {s['configs']} config(s) in {time.time() - t0:.2f}s: "
          f"{s['beat_pure_dp']} beat pure DP")
    if args.all or (args.out and names is None):
        with open(out_path, "w") as f:
            json.dump(rep, f, indent=1, sort_keys=True)
        print(f"wrote {out_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
