"""Sharding rules: activation constraints + parameter partition specs.

Conventions (DESIGN.md §6):
  batch    → ("pod", "data")   (pure data parallel across pods — the tier the
                                paper's partial-communication strategies target)
  heads/ffn/experts/vocab → "model"   (tensor parallel)
  large param dims        → "data"    (FSDP / ZeRO-3 style)
  long-context sequence   → "data"    (524k decode, batch=1)

The ``shard`` helper is a no-op outside a mesh context so model code runs
unchanged in single-device smoke tests.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import jax_compat as compat

# logical axis names used throughout the model code
BATCH = ("pod", "data")
SEQ = "data"
MODEL = "model"
EXPERT = "model"


def seq_ax(cfg):
    """Axis carrying the sequence dim of activations ("cp" mode)."""
    return MODEL if getattr(cfg, "sharding_mode", "tp") == "cp" else None


def heads_ax(cfg):
    """Axis carrying heads/d_ff of activations ("tp" mode)."""
    return None if getattr(cfg, "sharding_mode", "tp") == "cp" else MODEL


def _filter_spec(spec, axis_names):
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a in axis_names)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def shard(x, *spec):
    """Constrain activation sharding; drops axes absent from the mesh, not
    dividing the dim, or currently Manual (inside a shard_map over that
    axis); no-op when no mesh context is active."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    sizes = dict(mesh.shape)
    manual = compat.manual_axis_names(mesh)
    sizes = {k: v for k, v in sizes.items() if k not in manual}
    entries = []
    for d, entry in enumerate(spec):
        if entry is None or d >= x.ndim:
            entries.append(None)
            continue
        axes = [a for a in (entry if isinstance(entry, tuple) else (entry,))
                if a in sizes]
        while axes:
            prod = 1
            for a in axes:
                prod *= sizes[a]
            if x.shape[d] % prod == 0:
                break
            axes.pop()
        entries.append(tuple(axes) if len(axes) > 1 else
                       (axes[0] if axes else None))
    if all(e is None for e in entries):  # nothing to constrain (e.g. the
        return x  # whole mesh is Manual inside a shard_map body)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


# ---------------------------------------------------------------------------
# Parameter partition rules: (path regex, PartitionSpec) — first match wins.
# Param path strings look like "stack/layers/0/attn/wq" etc.
# FSDP: shard the big non-TP dim over "data"; TP dims over "model".
# ---------------------------------------------------------------------------
PARAM_RULES = [
    # embeddings / lm head: vocab over model, d_model over data (FSDP)
    (r".*embed.*", P("model", "data")),
    (r".*lm_head.*", P("data", "model")),
    # attention projections (leading scan dim handled separately)
    (r".*(attn|self_attn|cross_attn)/wq$", P("data", "model", None)),
    (r".*(attn|self_attn|cross_attn)/wk$", P("data", "model", None)),
    (r".*(attn|self_attn|cross_attn)/wv$", P("data", "model", None)),
    (r".*(attn|self_attn|cross_attn)/wo$", P("model", None, "data")),
    (r".*(attn|self_attn|cross_attn)/(bq|bk|bv)$", P("model", None)),
    # dense mlp
    (r".*mlp/w_(gate|up)$", P("data", "model")),
    (r".*mlp/w_down$", P("model", "data")),
    # MoE: experts over model axis (expert parallel), then FSDP over data
    (r".*moe/router.*", P("data", None)),
    (r".*moe/w_(gate|up)$", P("model", "data", None)),
    (r".*moe/w_down$", P("model", None, "data")),
    (r".*shared/w_(gate|up)$", P("data", "model")),
    (r".*shared/w_down$", P("model", "data")),
    # mamba
    (r".*mamba/in_proj$", P("data", "model")),
    (r".*mamba/out_proj$", P("model", "data")),
    (r".*mamba/(conv_w|conv_b|x_proj|dt_proj|A_log|D|dt_bias)$", None),  # small
    # xlstm
    (r".*mlstm/w(q|k|v)$", P("data", "model", None)),
    (r".*mlstm/out_proj$", P("model", None, "data")),
    (r".*slstm/W$", P("data", "model")),
    (r".*slstm/R$", P("model", None, None)),
    # norms and everything small: replicated
    (r".*", None),
]

# "cp" (context-parallel) mode: the "model" axis carries SEQUENCE, so
# weights take no TP — everything big is ZeRO-3 sharded over BOTH axes
# (gathered at use; grads reduce-scattered by the partitioner).
FSDP2 = ("data", "model")
PARAM_RULES_CP = [
    (r".*embed.*", P("model", "data")),
    (r".*lm_head.*", P("data", "model")),
    # attention weights: ZeRO over "data" only — a 2-axis shard makes the
    # partitioner gather the (seq-sharded) residual stream instead of the
    # much smaller weights (§Perf hillclimb 2 it. 2)
    (r".*(attn|self_attn|cross_attn)/wq$", P("data", None, None)),
    (r".*(attn|self_attn|cross_attn)/wk$", P("data", None, None)),
    (r".*(attn|self_attn|cross_attn)/wv$", P("data", None, None)),
    (r".*(attn|self_attn|cross_attn)/wo$", P(None, None, "data")),
    (r".*mlp/w_(gate|up)$", P(FSDP2, None)),
    (r".*mlp/w_down$", P(None, FSDP2)),
    (r".*mamba/in_proj$", P(FSDP2, None)),
    (r".*mamba/out_proj$", P(None, FSDP2)),
    (r".*", None),
]


def spec_for_path(path: str, ndim: int, stacked: bool,
                  mode: str = "tp") -> P:
    rules = PARAM_RULES_CP if mode == "cp" else PARAM_RULES
    for pat, spec in rules:
        if re.match(pat, path):
            if spec is None:
                spec = P()
            entries = list(spec)
            if stacked:
                entries = [None] + entries  # leading scan dim unsharded
            # pad/trim to ndim
            entries = entries[:ndim] + [None] * (ndim - len(entries))
            return P(*entries)
    return P()


def _flatten_with_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten_with_paths(tree[k], f"{prefix}/{k}" if prefix else k)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten_with_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def param_specs(params, stacked_marker="stack", mode: str = "tp"):
    """PartitionSpec pytree matching ``params`` (same structure)."""

    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(v, f"{prefix}/{k}" if prefix else k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = type(tree)
            return t(build(v, f"{prefix}/{i}") for i, v in enumerate(tree))
        stacked = f"{stacked_marker}/" in prefix or prefix.startswith(stacked_marker)
        return spec_for_path(prefix, tree.ndim if hasattr(tree, "ndim") else 0,
                             stacked, mode)

    return build(params)


def zero1_state_shardings(opt_state_template, mesh, axis: str = "pod"):
    """NamedShardings for ZeRO-1 optimizer state (train/loop.py::
    zero1_opt_template): every leaf is a padded flat f32 bucket whose
    length is a multiple of the ``axis`` size by construction, partitioned
    over that data-parallel axis so each device holds its 1/W shard."""
    names = set(mesh.axis_names)

    def to_sh(leaf):
        spec = P(axis) if axis in names and getattr(leaf, "ndim", 1) else P()
        return NamedSharding(mesh, spec)

    return jax.tree.map(to_sh, opt_state_template)


def elastic_state_shardings(state_template, mesh, axis: str = "pod"):
    """NamedShardings for a RESIZED ZeRO shard-state tree.

    ``launch/elastic.py::resize_state`` re-pads every shard bucket for
    the new worker count (``PartitionedLayout.with_parts``), so the
    resized template keeps the zero1 invariant — flat f32 buckets whose
    length is a multiple of the new ``axis`` size — and the same
    partition rule applies verbatim.  Exists as its own entry point so
    the production placement of a post-resize fleet is one call, not a
    re-derivation of the zero1 rule at the call site."""
    return zero1_state_shardings(state_template, mesh, axis)


def param_shardings(params, mesh):
    names = set(mesh.axis_names)

    def to_sharding(spec):
        return NamedSharding(mesh, _filter_spec(spec, names))

    return jax.tree.map(to_sharding, param_specs(params),
                        is_leaf=lambda x: isinstance(x, P))
