"""End-to-end trainer CLI.

Two modes mirroring DESIGN.md §3:
  * replica-simulator mode (default on CPU): W model replicas under any
    spectrum strategy + optional compression — the paper's experimental rig.
  * sharded mode (--sharded): one global model under pjit on whatever
    devices exist (data-parallel sync; the production path).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
      --strategy gossip --workers 4 --steps 200 --compressor onebit
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, list_configs
from repro.core.comm import LocalComm
from repro.core.compression import get_compressor
from repro.core.precision import POLICIES, apply_policy, get_policy
from repro.core.strategies import REGISTRY, get_strategy
from repro.data.pipeline import DataConfig, bayes_entropy, prefetch_batches
from repro.models import transformer as T
from repro.optim import adam, sgd, warmup_cosine
from repro.train.loop import (init_train_state, make_loss_fn,
                              make_replica_train_step)


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of the arch")
    ap.add_argument("--strategy", default="sync", choices=sorted(REGISTRY))
    ap.add_argument("--zero-stage", type=int, default=0,
                    choices=[0, 1, 2, 3],
                    help="ZeRO partitioning stage (shorthand for "
                         "--strategy sync_zero{N}): 1 shards optimizer "
                         "state, 2 also reduce-scatters per-microbatch "
                         "gradients into a 1/W accumulator, 3 also shards "
                         "the parameters (gathered per step)")
    ap.add_argument("--compressor", default="none",
                    choices=["none", "onebit", "int8", "topk"])
    ap.add_argument("--precision", default="f32", choices=sorted(POLICIES),
                    help="precision policy (core/precision.py): f32 | "
                         "bf16 (bf16 compute/wire, f32 master, dynamic "
                         "loss scaling) | bf16-pure")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100,
                    help="OPTIMIZER steps (accumulation boundaries)")
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="microbatches accumulated per optimizer step "
                         "(DESIGN.md §8): the exchange fires once per "
                         "boundary, so wire bytes per sample shrink by "
                         "this factor; effective global batch = workers x "
                         "batch-per-worker x accum-steps")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="batches kept in flight by the double-buffered "
                         "device prefetch (1 = synchronous)")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adam", choices=["adam", "sgd"])
    ap.add_argument("--fused-adam", action="store_true",
                    help="route the Adam update through the fused Pallas "
                         "kernel (one VMEM pass per flat bucket — pairs "
                         "with the ZeRO-1 shard-bucket update boundary)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", default=None, choices=["auto"],
                    help="auto: resume from the latest VALID checkpoint in "
                         "--ckpt-dir (corrupt/partial steps are verified "
                         "against the per-leaf checksums and skipped); "
                         "exit 2 with a one-line message when the dir has "
                         "no valid step")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None, help="JSON metrics file")
    return ap


def strategy_from_args(args, policy=None):
    comp = None
    if args.compressor != "none":
        comp = get_compressor(args.compressor) if args.compressor != "topk" \
            else get_compressor("topk", ratio=0.01)
    kw = {}
    if args.strategy in ("sync", "ssp", "downpour"):
        kw["compressor"] = comp
    if args.strategy == "sync_dgc":
        if comp is None:
            print("sync_dgc needs --compressor (onebit | int8 | topk)",
                  file=sys.stderr)
            raise SystemExit(2)
        kw["compressor"] = comp
    if policy is not None:
        kw["policy"] = policy
    return get_strategy(args.strategy, **kw)


def resume_auto(ckpt_dir, state, strategy, comm, policy, strategy_name):
    """Restore the newest valid checkpoint into ``state`` (in place).

    Builds the restore template as a mirror of the save tree below (replica-0
    params [+ master], shard-bucket opt state / ZeRO-3 param shards for the
    sync_zero* strategies) and re-shards across worker counts when the save
    recorded a partition spec.  Returns the restored step; exits 2 when the
    dir holds no valid step or the checkpoint doesn't fit this run."""
    import jax.numpy as jnp

    from repro.checkpoint import (latest_valid_step, read_meta,
                                  restore_checkpoint)
    step0 = latest_valid_step(ckpt_dir)
    if step0 is None:
        print(f"--resume auto: no valid checkpoint step in {ckpt_dir!r}",
              file=sys.stderr)
        raise SystemExit(2)
    owns = getattr(strategy, "owns_params", False)
    full = strategy.gather_params(state["params"], comm) if owns \
        else state["params"]
    template = {"params": comm.replica(full, 0), "step": state["step"]}
    if policy is not None and "master" in state:
        template["master"] = comm.replica(state["master"], 0)
    if strategy_name.startswith("sync_zero"):
        template["opt_state"] = state["opt_state"]
        if owns:
            template["param_shards"] = state["params"]
    has_part = str(step0) in read_meta(ckpt_dir).get("partitions", {})
    try:
        restored = restore_checkpoint(ckpt_dir, step0, template,
                                      repartition=has_part)
    except (KeyError, ValueError) as e:
        print(f"--resume auto: checkpoint step {step0} does not match this "
              f"run's strategy/layout ({e})", file=sys.stderr)
        raise SystemExit(2)
    if owns:
        state["params"] = jax.tree.map(jnp.asarray, restored["param_shards"])
    else:
        state["params"] = comm.replicate(restored["params"])
    if "master" in template:
        state["master"] = comm.replicate(restored["master"])
    if "opt_state" in template:
        state["opt_state"] = jax.tree.map(jnp.asarray, restored["opt_state"])
    state["step"] = jnp.asarray(restored["step"], jnp.int32)
    return int(restored["step"])


def main(argv=None):
    args = build_argparser().parse_args(argv)
    try:
        cfg = get_config(args.arch)
    except KeyError:
        print(f"unknown arch {args.arch!r}; valid names: "
              + ", ".join(sorted(list_configs())), file=sys.stderr)
        raise SystemExit(2)
    if args.reduced:
        cfg = cfg.reduced()
    if args.zero_stage:
        if args.strategy not in ("sync", f"sync_zero{args.zero_stage}"):
            print(f"--zero-stage {args.zero_stage} conflicts with "
                  f"--strategy {args.strategy}", file=sys.stderr)
            raise SystemExit(2)
        args.strategy = f"sync_zero{args.zero_stage}"
    if cfg.is_encoder_decoder or cfg.modality is not None:
        raise SystemExit("trainer CLI supports decoder-only text archs; "
                         "see examples/ for enc-dec and multimodal")
    policy = get_policy(args.precision)
    if policy.is_noop:
        policy = None  # f32: the bitwise pre-precision path
    else:
        cfg = apply_policy(cfg, policy)

    comm = LocalComm(args.workers)
    strategy = strategy_from_args(args, policy)
    sched = warmup_cosine(args.lr, warmup=max(1, args.steps // 20),
                          total_steps=args.steps)
    opt = (adam(sched, fused=args.fused_adam) if args.optimizer == "adam"
           else sgd(sched))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      batch_per_worker=args.batch_per_worker, seed=args.seed)

    key = jax.random.PRNGKey(args.seed)
    params = comm.replicate(T.init_model(key, cfg))
    state = init_train_state(params, opt, strategy, comm, policy=policy)

    loss_fn_single = make_loss_fn(cfg, remat=False)

    def loss_fn(p, toks):
        return loss_fn_single(p, {"tokens": toks, "labels": toks})

    step_fn = make_replica_train_step(loss_fn, opt, strategy, comm,
                                      policy=policy,
                                      accum_steps=args.accum_steps)

    n_params = sum(x.size for x in jax.tree.leaves(params)) // args.workers
    # global-batch accounting: one optimizer step consumes accum_steps
    # microbatches of workers x batch_per_worker samples each, but ships
    # the wire bytes of ONE exchange
    samples_per_step = args.workers * args.batch_per_worker * args.accum_steps
    print(f"arch={cfg.name} params={n_params:,} strategy={strategy.name} "
          f"precision={args.precision} workers={args.workers} "
          f"accum_steps={args.accum_steps} "
          f"global_batch={samples_per_step} "
          f"prefetch_depth={args.prefetch_depth} "
          f"entropy_floor={bayes_entropy(dcfg):.3f}")

    start_step = 0
    if args.resume:
        if not args.ckpt_dir:
            print("--resume auto requires --ckpt-dir", file=sys.stderr)
            raise SystemExit(2)
        start_step = resume_auto(args.ckpt_dir, state, strategy, comm,
                                 policy, args.strategy)
        print(f"resumed from step {start_step} ({args.ckpt_dir})")

    history = []
    t0 = time.time()
    for t, batches in prefetch_batches(dcfg, args.workers, args.steps,
                                       accum_steps=args.accum_steps,
                                       depth=args.prefetch_depth):
        if t < start_step:
            # identical data stream to an uninterrupted run: boundaries
            # before the restored step are consumed, not trained on
            continue
        state, m = step_fn(state, batches)
        if t % args.log_every == 0 or t == args.steps - 1:
            rec = {"step": t, "loss": float(m["loss"]),
                   "divergence": float(m["replica_divergence"]),
                   "wire_bytes": float(m["wire_bytes"]),
                   "wire_bytes_per_sample":
                       float(m["wire_bytes"]) / samples_per_step,
                   "elapsed_s": round(time.time() - t0, 2)}
            if "loss_scale" in m:
                rec["loss_scale"] = float(m["loss_scale"])
            history.append(rec)
            print(f"step {t:5d} loss {rec['loss']:.4f} "
                  f"div {rec['divergence']:.2e} wireB {rec['wire_bytes']:.0f}"
                  f" wireB/sample {rec['wire_bytes_per_sample']:.1f}")

    if args.ckpt_dir:
        # ZeRO-3 keeps only shard buckets in the state: gather the full
        # tree so the checkpoint stays worker-count-portable
        full_params = strategy.gather_params(state["params"], comm) \
            if getattr(strategy, "owns_params", False) else state["params"]
        tree = {"params": comm.replica(full_params, 0),
                "step": state["step"]}
        kw = {}
        if policy is not None:
            kw["precision"] = policy.spec()
            if "master" in state:  # dense f32 master rides the checkpoint
                tree["master"] = comm.replica(state["master"], 0)
        if args.strategy.startswith("sync_zero"):
            # shard-bucket opt state (incl. any f32 master / ZeRO-3 param
            # shards) + the partition spec, so a restore can re-shard to
            # another W
            from repro.core.fabric import Fabric
            tree["opt_state"] = state["opt_state"]
            if getattr(strategy, "owns_params", False):
                tree["param_shards"] = state["params"]
            kw["partition"] = Fabric(comm).partitioned_layout(
                full_params).spec()
        save_checkpoint(args.ckpt_dir, args.steps, tree, **kw)
        print(f"checkpoint saved to {args.ckpt_dir}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
