"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  TPU v5e constants live here too — they feed the
roofline analysis.
"""

from __future__ import annotations

from repro.core import jax_compat as compat


# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (4,) over ("data",))."""
    return compat.make_mesh(shape, axes)
