"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  TPU v5e constants live here too — they feed the
roofline analysis.
"""

from __future__ import annotations

from repro.core import jax_compat as compat


# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False, tp_degree: int = 16):
    """The 256-chip pod mesh (512 with ``multi_pod``): the trailing
    "model" axis carries ``tp_degree`` chips and the "data" axis the
    rest — ``data x model`` is always 256, so the launch planner can
    trade DP degree against TP degree without changing the device
    count."""
    if tp_degree < 1 or 256 % tp_degree:
        raise ValueError(f"tp_degree must divide 256, got {tp_degree}")
    dp = 256 // tp_degree
    shape = (2, dp, tp_degree) if multi_pod else (dp, tp_degree)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (4,) over ("data",))."""
    return compat.make_mesh(shape, axes)
