"""Roofline-driven auto-parallelism planner (DESIGN.md §12).

For every eval config the planner searches the launch space

    (DP degree × TP degree × ZeRO stage × accum_steps × precision)

with ``dp × tp = DEVICES`` fixed, costs each candidate with the
three-term roofline model of ``repro.roofline.analysis`` (compute /
HBM / collective) plus an explicit HBM-overflow swap penalty, and emits
the cheapest feasible launch spec per config into the committed
``PLAN.json`` artifact (CLI: ``python -m repro.launch.plan``).

The cost model is ANALYTIC — the same closed forms the measured dry-run
tier extrapolates (``model_flops_per_device``, ``step_state_peak_bytes``,
``exchange_wire_bytes``, ``tp_wire_bytes``) — so planning all 11 configs
is instant and deterministic; CI re-derives every chosen plan and
compares step costs exactly.

Within one candidate the TP assignment is a PaSE-style dynamic program
over the layer-graph segments ``[embed] + [block]×L + [head]``: each
segment independently picks its parallel degree (1 or the candidate's
``tp``) to minimise segment compute + combine wire + the reshard cost of
switching degree between adjacent segments.  The repo's TP scheme keeps
the residual stream replicated at block boundaries (the row-parallel
all-reduce IS the resharding), so transitions are free and the
recurrence degenerates per-segment — but the recurrence is what the
planner optimises, and a future sequence-sharded scheme only has to
price the transition.

The measured breakeven table of ``BENCH_timing.json`` closes the loop on
gradient compression: a compressed wire only pays below the measured
breakeven link bandwidth, so the planner records an advisory instead of
unconditionally adding the codec to the plan.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.configs.base import get_config, list_configs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.analysis import (
    dtype_wire_bytes,
    exchange_wire_bytes,
    model_flops_per_device,
    opt_state_bytes,
    step_state_peak_bytes,
    tp_wire_bytes,
)

DEVICES = 256
HBM_BYTES = 16 << 30  # ~16 GB HBM per device (launch/mesh.py production pod)
# each byte over HBM is swapped out AND back in per step through the HBM
# interface, at a fraction of its bandwidth (host-link staging)
SWAP_FACTOR = 8.0

TP_DEGREES = (1, 2, 4, 8, 16)
ZERO_STAGES = (0, 1, 2, 3)
ACCUM_STEPS = (1, 4)
PRECISIONS = ("f32", "bf16")

# the 10 registered archs + the sliding-window long-context variant —
# the same eval set the lint sweep proves (repro.analysis.sweep)
def plan_configs() -> Tuple[str, ...]:
    return tuple(sorted(list_configs())) + ("qwen2.5-14b-swa",)


SMOKE_CONFIGS = ("gemma3-1b", "qwen2-1.5b")

# configs whose chosen plan must beat pure data parallelism with margin
# (the memory-bound regime of the paper §2: pure DP replicates state it
# cannot hold)
LARGE_CONFIGS = ("deepseek-67b", "qwen2-moe-a2.7b", "jamba-1.5-large-398b")

ZERO_STRATEGY = {0: "sync", 1: "sync_zero1", 2: "sync_zero2",
                 3: "sync_zero3"}

_PARAM_BYTES = {"f32": 4.0, "bf16": 2.0}
_MASTER_FLOATS = {"f32": 0, "bf16": 1}  # bf16 keeps an f32 master shard
_ADAM_FLOATS = 2


# ---------------------------------------------------------------------------
# model decomposition — what fraction of the config TP actually divides
# ---------------------------------------------------------------------------
def _head_dim(cfg) -> int:
    return cfg.head_dim or cfg.d_model // cfg.num_heads


def _attn_params_per_layer(cfg) -> float:
    hd = _head_dim(cfg)
    return float(cfg.d_model * hd * (2 * cfg.num_heads
                                     + 2 * cfg.num_kv_heads))


def _embed_params(cfg) -> float:
    copies = 1 if cfg.tie_embeddings else 2
    return float(copies * cfg.vocab_size * cfg.d_model)


def tp_valid_degrees(cfg) -> Tuple[int, ...]:
    """TP degrees the split axes of models/tensor_parallel.py admit:
    ``t`` must divide the head blocks (wq/wo), the KV blocks (wk/wv) and
    the feed-forward width (w_gate/w_up/w_down).  SSM stacks have no
    row-parallel contraction to split."""
    if cfg.family == "ssm":
        return (1,)
    out = [1]
    for t in TP_DEGREES[1:]:
        if DEVICES % t:
            continue
        if cfg.num_heads % t or cfg.num_kv_heads % t:
            continue
        if cfg.d_ff and cfg.d_ff % t:
            continue
        out.append(t)
    return tuple(out)


def tp_split_fractions(cfg) -> Tuple[float, float]:
    """(active-compute fraction, total-parameter fraction) that the TP
    split axes divide.  Attention projections always split; the dense
    MLP splits; MoE expert banks are REPLICATED across TP ranks
    (models/tensor_parallel.py ships them whole), so for MoE families
    only the attention share shrinks."""
    n_layers = float(cfg.num_layers)
    attn_layers = n_layers
    if cfg.attn_every:  # hybrid: 1 attention layer per attn_every
        attn_layers = n_layers / float(cfg.attn_every)
    attn = _attn_params_per_layer(cfg) * attn_layers
    dense_ffn = 0.0
    if cfg.num_experts == 0:
        dense_ffn = 3.0 * cfg.d_model * cfg.d_ff * n_layers
    elif cfg.moe_every > 1:  # mixed stacks: dense mlp on non-MoE layers
        dense_layers = n_layers - n_layers / float(cfg.moe_every)
        dense_ffn = 3.0 * cfg.d_model * cfg.d_ff * dense_layers
    split = attn + dense_ffn
    active = float(cfg.active_param_count())
    total = float(cfg.param_count())
    return (min(1.0, split / active) if active else 0.0,
            min(1.0, split / total) if total else 0.0)


# ---------------------------------------------------------------------------
# PaSE-style segment recurrence
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Segment:
    name: str
    flops: float          # per optimizer step, whole cluster
    split_frac: float     # fraction of flops a TP degree divides
    combines: int         # row-parallel all-reduces if run TP (fwd+bwd)


def build_segments(cfg, shape) -> List[Segment]:
    """[embed] + [block]×L + [head] with per-segment model FLOPs.
    The lm head is the (B·S·D·V) logits matmul (6× for fwd+bwd); the
    embedding lookup is a gather (≈0 FLOPs); the residual blocks share
    the remaining 6·N_active·tokens evenly."""
    tokens = float(shape.global_batch * shape.seq_len)
    total = 6.0 * float(cfg.active_param_count()) * tokens
    head = 6.0 * float(cfg.vocab_size * cfg.d_model) * tokens
    head = min(head, total * 0.5)
    block = max(0.0, total - head) / float(cfg.num_layers)
    active_frac, _ = tp_split_fractions(cfg)
    segs = [Segment("embed", 0.0, 0.0, 0)]
    segs += [Segment("block", block, active_frac, 4)] * cfg.num_layers
    segs.append(Segment("head", head, 0.0, 0))
    return segs


def assign_segments(segs: List[Segment], tp: int, dp: int,
                    act_nbytes: float, peak: float,
                    reshard_nbytes: float = 0.0) -> Tuple[float, Dict]:
    """Minimise Σ segment cost over per-segment degree ∈ {1, tp} with a
    transition cost when adjacent segments change degree.

    cost(seg, t) = flops/dp·(split/t + 1−split)/peak
                 + combines·2(t−1)/t·act_bytes/ICI_BW   [t>1]
    trans(a, b)  = reshard_bytes/ICI_BW                  [a≠b]

    The non-split share is REPLICATED across the model group — every TP
    rank computes it for its DP shard, so it divides by dp only; that
    redundancy is the genuine cost of raising tp on a config whose head
    or expert compute TP cannot divide.

    The repo's TP keeps activations replicated at segment boundaries
    (the combine all-reduce is the reshard), so ``reshard_nbytes`` is 0
    and the recurrence is separable — it is kept general on purpose."""

    def seg_cost(s: Segment, t: int) -> float:
        comp = s.flops * (s.split_frac / t + 1.0 - s.split_frac) \
            / (dp * peak)
        wire = 0.0
        if t > 1 and s.combines:
            wire = s.combines * 2.0 * (t - 1) / t * act_nbytes / ICI_BW
        return comp + wire

    choices = (1,) if tp <= 1 else (1, tp)
    trans = reshard_nbytes / ICI_BW
    # DP over segments, state = degree of the previous segment
    best = {t: (seg_cost(segs[0], t) if t == 1 or segs[0].split_frac
                else float("inf")) for t in choices}
    path = {t: [t] for t in choices}
    for s in segs[1:]:
        nbest, npath = {}, {}
        for t in choices:
            c = seg_cost(s, t)
            prev = min(choices,
                       key=lambda q: best[q] + (trans if q != t else 0.0))
            nbest[t] = best[prev] + (trans if prev != t else 0.0) + c
            npath[t] = path[prev] + [t]
        best, path = nbest, npath
    t_end = min(choices, key=lambda t: best[t])
    degrees = path[t_end]
    summary = {"embed": degrees[0], "head": degrees[-1],
               "block": max(degrees[1:-1]) if len(degrees) > 2 else 1,
               "tp_blocks": sum(1 for d in degrees[1:-1] if d > 1)}
    return best[t_end], summary


# ---------------------------------------------------------------------------
# candidate costing
# ---------------------------------------------------------------------------
def candidate_cost(cfg, shape, tp: int, zero: int, accum: int,
                   precision: str) -> Optional[dict]:
    """Roofline-modeled cost of one launch candidate; None if the
    candidate cannot be launched (indivisible batch or TP axes)."""
    if tp not in tp_valid_degrees(cfg):
        return None
    dp = DEVICES // tp
    if shape.global_batch % (dp * accum):
        return None

    n = float(cfg.param_count())
    _, total_frac = tp_split_fractions(cfg)
    # per-device parameter share after the TP split (replicated leaves
    # — embeddings, norms, MoE banks — stay whole on every rank)
    n_dev = n * (1.0 - total_frac) + n * total_frac / tp

    pbytes = _PARAM_BYTES[precision]
    peak = PEAK_FLOPS_BF16 * (1.0 if precision == "bf16" else 0.5)
    p_dense = n_dev * pbytes
    o_dense = opt_state_bytes(int(n_dev), _ADAM_FLOATS,
                              master_floats=_MASTER_FLOATS[precision])
    state = step_state_peak_bytes(p_dense, o_dense, int(n_dev),
                                  accum_steps=accum, donated=True,
                                  w=dp, zero_stage=zero)

    # activations: per-device microbatch residual stream, resident across
    # the remat'd backward
    b_dev = shape.global_batch // (dp * accum)
    act = float(b_dev * shape.seq_len * cfg.d_model) * pbytes
    act_resident = act * cfg.num_layers

    mem = state + act_resident
    over = max(0.0, mem - float(HBM_BYTES))
    swap_s = over * SWAP_FACTOR / HBM_BW

    # compute + TP combines via the segment recurrence
    compute_s, segments = assign_segments(
        build_segments(cfg, shape), tp, dp, act, peak)

    # data-parallel gradient exchange (per boundary), at the wire dtype
    flat = dtype_wire_bytes(int(n_dev),
                            "bfloat16" if precision == "bf16" else "float32")
    if dp == 1:
        dp_wire = 0.0
    elif zero <= 1:
        dp_wire = exchange_wire_bytes(flat, dp)
    elif zero == 2:
        # RS per MICROBATCH into the shard accumulator + one AG
        dp_wire = (accum + 1.0) * (dp - 1.0) / dp * flat
    else:
        # ZeRO-3: per-microbatch RS + the per-step parameter all-gather
        dp_wire = (accum + 1.0) * (dp - 1.0) / dp * flat
    tp_wire = tp_wire_bytes(act, tp, cfg.num_layers) * accum
    collective_s = (dp_wire + tp_wire) / ICI_BW

    memory_s = mem / HBM_BW
    step_s = max(compute_s, memory_s, collective_s) + swap_s
    return {
        "dp": dp, "tp": tp, "zero_stage": zero, "accum_steps": accum,
        "precision": precision, "strategy": ZERO_STRATEGY[zero],
        "step_s": step_s, "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "swap_penalty_s": swap_s,
        "state_bytes": state, "state_gb": state / (1 << 30),
        "hbm_ok": mem <= float(HBM_BYTES),
        "dp_wire_bytes": dp_wire, "tp_wire_bytes": tp_wire,
        "segments": segments,
        "microbatch_per_device": b_dev,
    }


def baseline_cost(cfg, shape, precision: str = "bf16") -> dict:
    """Pure data parallelism: dp=DEVICES, no TP, no ZeRO, no accum —
    the replicate-everything launch the paper's §2 regime starts from."""
    return candidate_cost(cfg, shape, tp=1, zero=0, accum=1,
                          precision=precision)


def plan_config(name: str, shape=None) -> dict:
    """Search the full candidate space for one config; returns the plan
    record (chosen spec + pure-DP baseline + search provenance)."""
    from repro.launch.specs import SHAPES

    cfg = get_config(name)
    shape = shape or SHAPES["train_4k"]
    candidates = []
    for precision in PRECISIONS:
        for zero in ZERO_STAGES:
            for accum in ACCUM_STEPS:
                for tp in tp_valid_degrees(cfg):
                    c = candidate_cost(cfg, shape, tp, zero, accum,
                                       precision)
                    if c is not None:
                        candidates.append(c)
    if not candidates:
        raise ValueError(f"{name}: no launchable candidate")
    chosen = min(candidates, key=lambda c: (c["step_s"], c["tp"],
                                            c["zero_stage"],
                                            c["accum_steps"]))
    base = baseline_cost(cfg, shape)
    return {
        "config": name,
        "shape": shape.name,
        "n_params": int(cfg.param_count()),
        "n_active_params": int(cfg.active_param_count()),
        "model_flops_per_device": model_flops_per_device(
            cfg, shape, DEVICES),
        "chosen": chosen,
        "baseline_dp": base,
        "speedup_vs_dp": base["step_s"] / chosen["step_s"],
        "candidates_searched": len(candidates),
    }


# ---------------------------------------------------------------------------
# breakeven advisory from the measured bench tier
# ---------------------------------------------------------------------------
def compression_advisory(timing_path: str = "BENCH_timing.json") -> dict:
    """The measured encode-overhead breakeven (benchmarks/bench_timing.py):
    a compressed gradient wire pays only below ``breakeven_gbps`` link
    bandwidth.  The planner compares against the modeled interconnect and
    records the verdict instead of blindly adding a codec."""
    link_gbps = ICI_BW / 1e9
    try:
        with open(timing_path) as f:
            rows = json.load(f).get("breakeven", [])
    except (OSError, json.JSONDecodeError):
        rows = []
    best = max((r.get("breakeven_gbps", 0.0) for r in rows), default=0.0)
    return {
        "source": os.path.basename(timing_path) if rows else None,
        "best_breakeven_gbps": best,
        "link_gbps": link_gbps,
        "compression_pays": bool(rows) and link_gbps < best,
    }


# ---------------------------------------------------------------------------
# report + validation
# ---------------------------------------------------------------------------
def build_report(names=None, smoke: bool = False,
                 timing_path: str = "BENCH_timing.json") -> dict:
    if names is None:
        names = SMOKE_CONFIGS if smoke else plan_configs()
    plans = [plan_config(n) for n in names]
    beat = sum(1 for p in plans if p["speedup_vs_dp"] > 1.0)
    return {
        "meta": {
            "schema": 1,
            "devices": DEVICES,
            "hbm_gb": HBM_BYTES / (1 << 30),
            "peak_flops_bf16": PEAK_FLOPS_BF16,
            "hbm_gbps": HBM_BW / 1e9,
            "ici_gbps": ICI_BW / 1e9,
            "swap_factor": SWAP_FACTOR,
            "smoke": bool(smoke),
            "search_space": {
                "tp_degrees": list(TP_DEGREES),
                "zero_stages": list(ZERO_STAGES),
                "accum_steps": list(ACCUM_STEPS),
                "precisions": list(PRECISIONS),
            },
            "compression_advisory": compression_advisory(timing_path),
        },
        "plans": plans,
        "summary": {"configs": len(plans), "beat_pure_dp": beat},
    }


def _schema_helpers():
    try:
        from benchmarks import common
    except ImportError:
        import pathlib
        import sys
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[3]))
        from benchmarks import common
    return common


_CHOSEN_KEYS = ("dp", "tp", "zero_stage", "accum_steps", "precision",
                "strategy", "step_s", "compute_s", "memory_s",
                "collective_s", "swap_penalty_s", "state_bytes",
                "segments")

# modeled margin the memory-bound large configs must clear over pure DP
LARGE_MARGIN = 1.2


def validate(report: dict, path: str = "PLAN.json",
             lint_report: Optional[dict] = None) -> dict:
    """Schema + acceptance for the committed planner artifact.

    Acceptance: every plan launchable and re-derivable (CI recomputes the
    chosen candidate's modeled cost and compares exactly), every chosen
    plan beats-or-ties pure DP, the named LARGE_CONFIGS beat it by
    ``LARGE_MARGIN``, and — when the lint report is supplied — every
    chosen (config, strategy, precision, accum) cell passed the
    analysis-tier rules."""
    C = _schema_helpers()
    C.require_sections(report, ("meta", "plans", "summary"), path)
    meta = report["meta"]
    C.check(meta.get("schema") == 1,
            f"{path}: unsupported schema {meta.get('schema')}")
    C.require_keys(meta, ("devices", "hbm_gb", "peak_flops_bf16",
                          "ici_gbps", "smoke", "search_space",
                          "compression_advisory"), f"{path}: meta")
    C.check(meta["devices"] == DEVICES,
            f"{path}: devices {meta['devices']} != {DEVICES}")
    plans = report["plans"]
    C.check(plans, f"{path}: empty plan list")
    names = [p.get("config") for p in plans]
    C.check(len(set(names)) == len(names), f"{path}: duplicate configs")
    if not meta.get("smoke"):
        missing = set(plan_configs()) - set(names)
        C.check(not missing, f"{path}: configs missing plans: "
                             f"{sorted(missing)}")
    lint_cells = {}
    if lint_report is not None:
        for cell in lint_report.get("cells", []):
            key = (cell["config"], cell["strategy"], cell["precision"],
                   cell["accum"])
            lint_cells[key] = all(r["status"] != "fail"
                                  for r in cell["rules"])
    from repro.launch.specs import SHAPES

    for p in plans:
        label = f"{path}: plan {p.get('config')}"
        C.require_keys(p, ("config", "shape", "n_params", "chosen",
                           "baseline_dp", "speedup_vs_dp",
                           "candidates_searched"), label)
        ch = p["chosen"]
        C.require_keys(ch, _CHOSEN_KEYS, f"{label} chosen")
        C.require_positive(ch, ("step_s", "compute_s"), f"{label} chosen")
        C.check(ch["dp"] * ch["tp"] == meta["devices"],
                f"{label}: dp×tp = {ch['dp']}×{ch['tp']} != devices")
        C.check(ch["zero_stage"] in ZERO_STAGES,
                f"{label}: bad zero_stage {ch['zero_stage']}")
        C.check(ch["precision"] in PRECISIONS,
                f"{label}: bad precision {ch['precision']!r}")
        C.check(ZERO_STRATEGY[ch["zero_stage"]] == ch["strategy"],
                f"{label}: strategy {ch['strategy']!r} does not match "
                f"zero_stage {ch['zero_stage']}")
        # feasibility: fits HBM, or the swap penalty is a small fraction
        # of the modeled step (nothing cheaper exists if chosen)
        C.check(ch.get("hbm_ok") or
                ch["swap_penalty_s"] <= 0.25 * ch["step_s"],
                f"{label}: chosen plan thrashes HBM "
                f"({ch['state_gb']:.1f} GB state, penalty "
                f"{ch['swap_penalty_s']:.2f}s)")
        C.check(p["speedup_vs_dp"] >= 1.0 - 1e-9,
                f"{label}: chosen plan slower than pure DP "
                f"({p['speedup_vs_dp']:.3f}x)")
        # re-derive: the committed numbers must be exactly what the
        # analytic model produces for the chosen point
        cfg = get_config(p["config"])
        re = candidate_cost(cfg, SHAPES[p["shape"]], ch["tp"],
                            ch["zero_stage"], ch["accum_steps"],
                            ch["precision"])
        C.check(re is not None, f"{label}: chosen candidate not launchable")
        C.check(abs(re["step_s"] - ch["step_s"])
                <= 1e-9 * max(1.0, abs(re["step_s"])),
                f"{label}: committed step_s {ch['step_s']} != re-derived "
                f"{re['step_s']}")
        if lint_cells:
            key = (p["config"], ch["strategy"], ch["precision"],
                   ch["accum_steps"])
            C.check(lint_cells.get(key, False),
                    f"{label}: chosen cell {key} has no passing "
                    f"analysis-tier lint result")
    by_name = {p["config"]: p for p in plans}
    if not meta.get("smoke"):
        for name in LARGE_CONFIGS:
            p = by_name.get(name)
            C.check(p is not None, f"{path}: no plan for large config "
                                   f"{name}")
            C.check(p["speedup_vs_dp"] >= LARGE_MARGIN,
                    f"{path}: {name} margin {p['speedup_vs_dp']:.2f}x "
                    f"< required {LARGE_MARGIN}x over pure DP")
    summ = report["summary"]
    C.check(summ.get("configs") == len(plans),
            f"{path}: summary config count mismatch")
    return report


def validate_file(path: str, lint_path: Optional[str] = None) -> dict:
    C = _schema_helpers()
    report = C.load_report(path, "python -m repro.launch.plan --all")
    lint = None
    if lint_path is None:
        lint_path = os.path.join(os.path.dirname(os.path.abspath(path)),
                                 "LINT.json")
    if os.path.exists(lint_path):
        with open(lint_path) as f:
            lint = json.load(f)
    return validate(report, path, lint_report=lint)
