import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"  # noqa: E501 — MUST precede any jax import

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production mesh, print memory/cost analysis, and emit roofline
terms.  (The two lines above give the single-CPU container 512 placeholder
devices so jax.make_mesh can build the production mesh; set ONLY here,
never globally — smoke tests and benches must see 1 device.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.core import jax_compat as compat  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (SHAPES, build_step, resolve_config,  # noqa: E402
                                truncate)
from repro.roofline.analysis import analyse, extrapolate_cost  # noqa: E402

ALL_ARCHS = [
    "gemma3-1b", "deepseek-67b", "seamless-m4t-medium", "xlstm-125m",
    "qwen2.5-14b", "qwen2-moe-a2.7b", "granite-moe-1b-a400m", "pixtral-12b",
    "jamba-1.5-large-398b", "qwen2-1.5b",
]


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
            mode: str = "tp", precision: str = None,
            accum_steps: int = 1, zero_stage: int = 0, tp_degree: int = 1):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_label = "2x16x16" if multi_pod else "16x16"
    n_dev = 512 if multi_pod else 256
    shape = SHAPES[shape_name]

    cfg = resolve_config(arch, shape_name)
    if cfg is not None and (mode != "tp" or tp_degree > 1):
        import dataclasses
        cfg = dataclasses.replace(cfg, sharding_mode=mode,
                                  tp_degree=tp_degree)
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_label,
                "status": "skip",
                "reason": "full-attention enc-dec x 500k decode (DESIGN.md §5)"}

    # --- full config, scan-over-layers: proves lowering/sharding + memory ---
    t0 = time.time()
    step_fn, sds, shardings, donate = build_step(cfg, shape_name, mesh,
                                                 precision=precision,
                                                 accum_steps=accum_steps,
                                                 zero_stage=zero_stage)
    with compat.set_mesh(mesh):
        jitted = jax.jit(step_fn, in_shardings=shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    del lowered, compiled

    # --- depth-truncated UNROLLED lowerings: trip-count-exact roofline -----
    specs_len, repeat_full = len(cfg.superblock()[0]), cfg.superblock()[1]
    costs = {}
    for r in (1, 2):
        tcfg = truncate(cfg, r)
        tstep, tsds, tsh, tdon = build_step(tcfg, shape_name, mesh,
                                            precision=precision,
                                            accum_steps=accum_steps,
                                            zero_stage=zero_stage)
        with compat.set_mesh(mesh):
            tcomp = jax.jit(tstep, in_shardings=tsh,
                            donate_argnums=tdon).lower(*tsds).compile()
        costs[r] = {"cost": compat.cost_analysis(tcomp),
                    "hlo": tcomp.as_text()}
        del tcomp
    cost, coll = extrapolate_cost(costs[1], costs[2], repeat_full)
    if accum_steps > 1 and shape.kind == "train":
        # XLA's cost_analysis counts the microbatch lax.scan body ONCE
        # (same trip-count blindness the depth extrapolation corrects), so
        # the compute/memory-traffic terms of a boundary step scale by
        # accum_steps.  Collective bytes stay as parsed: the boundary
        # fires one exchange regardless of accum_steps — that asymmetry
        # IS the accumulation win the roofline should show.
        cost = {k: v * accum_steps for k, v in cost.items()}
    roof = analyse(arch, shape, mesh_label, n_dev, cost, coll, cfg, mem)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_label,
        "status": "ok", "variant": cfg.name,
        "accum_steps": accum_steps if shape.kind == "train" else 1,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "roofline": roof.row(),
    }
    if verbose:
        print(f"== {arch} x {shape_name} [{mesh_label}] ({cfg.name})")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"   memory_analysis: {mem}")
        print(f"   per-device: args {mem.argument_size_in_bytes/2**30:.2f} GiB, "
              f"temps {mem.temp_size_in_bytes/2**30:.2f} GiB")
        ca_keys = {k: cost[k] for k in ("flops", "bytes accessed")
                   if k in cost}
        print(f"   cost_analysis: {ca_keys}")
        rr = roof.row()
        print(f"   roofline: compute {rr['compute_s']*1e3:.2f} ms | memory "
              f"{rr['memory_s']*1e3:.2f} ms | collective "
              f"{rr['collective_s']*1e3:.2f} ms  → dominant: {rr['dominant']}"
              f" | useful-FLOP ratio {rr['useful_flops_ratio']:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--mode", default="tp", choices=["tp", "cp"])
    ap.add_argument("--precision", default=None,
                    choices=["f32", "bf16", "bf16-pure"],
                    help="precision policy for the train step (None keeps "
                         "the historical bf16-dtype lowering with no "
                         "policy machinery)")
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="microbatch accumulation per optimizer step "
                         "(DESIGN.md \u00a78): train shapes gain a leading "
                         "scan axis and fire one exchange per boundary")
    ap.add_argument("--zero-stage", type=int, default=0,
                    choices=[0, 1, 2, 3],
                    help="ZeRO stage for train shapes: 1 shards optimizer "
                         "state over \"pod\", 2 also shards the microbatch "
                         "grad accumulator, 3 also shards the parameters")
    ap.add_argument("--tp-degree", type=int, default=1,
                    help="tensor-parallel degree baked into the config "
                         "(cfg.tp_degree): >1 takes the blocked-reference "
                         "lowering of models/layers.py")
    args = ap.parse_args()

    if args.arch is not None and args.arch not in ALL_ARCHS:
        print(f"unknown config {args.arch!r}; valid names: "
              + ", ".join(ALL_ARCHS), file=sys.stderr)
        raise SystemExit(2)

    pairs = []
    if args.all:
        pairs = [(a, s) for a in ALL_ARCHS for s in SHAPES]
    else:
        archs = [args.arch] if args.arch else ALL_ARCHS
        shapes = [args.shape] if args.shape else list(SHAPES)
        pairs = [(a, s) for a in archs for s in shapes]

    results = []
    for arch, shape in pairs:
        try:
            results.append(run_one(arch, shape, args.multi_pod,
                                   mode=args.mode,
                                   precision=args.precision,
                                   accum_steps=args.accum_steps,
                                   zero_stage=args.zero_stage,
                                   tp_degree=args.tp_degree))
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape,
                            "mesh": "2x16x16" if args.multi_pod else "16x16",
                            "status": "error", "error": repr(e)})
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"] == "skip")
    err = sum(1 for r in results if r["status"] == "error")
    print(f"\n==== dry-run summary: {ok} ok / {skip} skip / {err} error ====")
    for r in results:
        if r["status"] == "error":
            print(f"  ERROR {r['arch']} x {r['shape']}: {r['error'][:200]}")
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
