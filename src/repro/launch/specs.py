"""Input specs and step builders for every (architecture × input shape).

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins (no
device allocation) for each model input; the dry-run lowers against them.
Decode shapes lower ``serve_step`` (ONE token against a seq_len cache);
``long_500k`` additionally requires sub-quadratic attention — full-attention
archs get the explicitly-flagged sliding-window variant (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.precision import apply_policy, get_policy
from repro.models import transformer as T
from repro.optim.optimizers import adam, state_template
from repro.train.loop import make_sharded_train_step


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# the one genuine skip (DESIGN.md §5): full-attention enc-dec × 500k decode
SKIPS = {("seamless-m4t-medium", "long_500k")}


def resolve_config(name_or_cfg, shape_name: str,
                   dtype: str = "bfloat16") -> Optional[ModelConfig]:
    """Pick the per-pair model config; None ⇒ recorded skip.

    ``long_500k`` on full-attention archs returns the sliding-window
    variant; SSM/hybrid and natively-windowed archs run their published
    config."""
    from repro.configs import get_config

    cfg = get_config(name_or_cfg) if isinstance(name_or_cfg, str) else name_or_cfg
    if (cfg.name, shape_name) in SKIPS:
        return None
    if shape_name == "long_500k" and cfg.family not in ("ssm", "hybrid") \
            and cfg.sliding_window is None:
        cfg = cfg.with_sliding_window(4096)
    return dataclasses.replace(cfg, param_dtype=dtype, compute_dtype=dtype)


def truncate(cfg: ModelConfig, repeat: int) -> ModelConfig:
    """Depth-truncated UNROLLED variant (``repeat`` super-blocks) for exact
    cost_analysis: XLA counts while-loop bodies once, so the dry-run derives
    per-layer cost from unrolled 1- and 2-super-block lowerings and
    extrapolates linearly in depth (exact for matmul/collective costs)."""
    specs, _ = cfg.superblock()
    return dataclasses.replace(
        cfg,
        num_layers=len(specs) * repeat,
        num_encoder_layers=min(cfg.num_encoder_layers, repeat),
        scan_layers=False,
    )


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------
def _fit_spec(spec: P, shape, mesh) -> P:
    """Drop sharding axes that don't divide the dim (or are absent)."""
    sizes = dict(mesh.shape)  # Mesh.shape is an axis-name → size mapping
    out = []
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            out.append(None)
            continue
        axes = list(entry) if isinstance(entry, tuple) else [entry]
        axes = [a for a in axes if a in sizes]
        while axes:
            prod = 1
            for a in axes:
                prod *= sizes[a]
            if shape[d] % prod == 0:
                break
            axes.pop()  # drop the innermost axis and retry
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def fit_sharding(sds, spec: P, mesh) -> NamedSharding:
    return NamedSharding(mesh, _fit_spec(spec, sds.shape, mesh))


BD = ("pod", "data")  # batch axes


def _cache_spec(path: str, ndim: int, shape_name: str) -> P:
    """Sharding for stacked decode-cache leaves (leading dim = scan repeat)."""
    long = shape_name == "long_500k"
    seq_axes = ("pod", "data", "model") if long else "model"
    if path.endswith("/k") or path.endswith("/v"):  # (R,B,S,KV,Dh)
        return P(None, None if long else "data", seq_axes, None, None)
    if path.endswith("/conv"):  # (R,B,k-1,d_in)
        return P(None, "data", None, "model")
    if path.endswith("/ssm"):  # (R,B,d_in,N)
        return P(None, "data", "model", None)
    if path.endswith("/C"):  # (R,B,H,dh,dh)
        return P(None, "data", "model", None, None)
    if path.endswith("/n") or path.endswith("/m") or path.endswith("/c") \
            or path.endswith("/h"):  # (R,B,H,dh) / (R,B,H) / slstm (R,B,D)
        return P(*([None, "data"] + [None] * (ndim - 2)))
    return P(*([None] * ndim))


def _tree_shardings(sds_tree, spec_fn, mesh):
    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = type(tree)
            return t(walk(v, f"{prefix}/{i}") for i, v in enumerate(tree))
        return fit_sharding(tree, spec_fn(prefix, tree.ndim), mesh)

    return walk(sds_tree)


# ---------------------------------------------------------------------------
# model / optimizer SDS (no allocation)
# ---------------------------------------------------------------------------
def model_sds(cfg: ModelConfig):
    return jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg))


def elastic_partition_spec(cfg: ModelConfig, workers: int,
                           bucket_bytes: int) -> dict:
    """The ZeRO partition spec ({"n_parts", "bucket_sizes"}) of a config's
    parameter tree at ``workers``, derived allocation-free via eval_shape.

    This is THE identity an elastic transition preserves: ``bucket_sizes``
    are invariant across a W → W′ resize (only ``n_parts`` and padding
    change — ``PartitionedLayout.with_parts``), so the launch layer can
    pre-compute resize cost (``roofline.resize_moved_bytes``) and the
    post-resize placement (``sharding.elastic_state_shardings``) for any
    candidate fleet size without touching device memory."""
    from repro.core.comm import LocalComm
    from repro.core.fabric import Fabric

    sds = model_sds(cfg)
    # partitioned_layout wants the replica-stacked view (lead axis = W)
    stacked = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((workers,) + x.shape, x.dtype), sds)
    fab = Fabric(LocalComm(workers), bucket_bytes)
    return fab.partitioned_layout(stacked).spec()


def param_shardings_sds(params_sds, mesh, mode: str = "tp"):
    from repro.launch.sharding import param_specs

    specs = param_specs(params_sds, mode=mode)
    return jax.tree.map(
        lambda sds, spec: fit_sharding(sds, spec, mesh),
        params_sds, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------
def _emb_dtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh,
                      accum_steps: int = 1):
    """``accum_steps > 1`` prepends a microbatch axis: every batch leaf is
    ``(accum_steps, global_batch, ...)`` — axis 0 is scanned by the
    microbatched train step (never sharded), the batch axis keeps its
    ``BD`` sharding.  ``global_batch`` stays the PER-MICROBATCH size, so
    the effective optimizer batch is ``accum_steps × global_batch``."""
    b, l = shape.global_batch, shape.seq_len

    def _spec(shape_tail, spec: P):
        if accum_steps == 1:
            return shape_tail, spec
        return (accum_steps,) + shape_tail, P(*((None,) + tuple(spec)))

    sds, sh = {}, {}

    def add(name, shape_tail, dtype, spec):
        full, sp = _spec(shape_tail, spec)
        sds[name] = jax.ShapeDtypeStruct(full, dtype)
        sh[name] = fit_sharding(sds[name], sp, mesh)

    if cfg.modality in ("vision",):  # decoder consumes patch+text embeddings
        add("embeds", (b, l, cfg.d_model), _emb_dtype(cfg), P(BD, None, None))
    else:
        add("tokens", (b, l), jnp.int32, P(BD, None))
    add("labels", (b, l), jnp.int32, P(BD, None))
    if cfg.is_encoder_decoder:  # audio frontend stub: frame embeddings
        add("source_embeds", (b, cfg.encoder_seq_len, cfg.d_model),
            _emb_dtype(cfg), P(BD, None, None))
    return sds, sh


# ---------------------------------------------------------------------------
# step builders — each returns (step_fn, arg_sds (tuple), arg_shardings, donate)
# ---------------------------------------------------------------------------
def build_train_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     pod_compressor=None, partition_grads: bool = False,
                     precision=None, accum_steps: int = 1,
                     zero_stage: int = 0):
    """``precision``: None keeps the pre-precision build exactly; a policy
    name (``--precision {f32,bf16,bf16-pure}``) or PrecisionPolicy applies
    its param/compute dtypes to the config and threads wire dtype, master
    placement and loss-scale state through the step.

    ``accum_steps``: microbatched boundary step (DESIGN.md §8) — the batch
    specs gain a leading scan axis and the lowered step fires one exchange
    per boundary.  The state stays donated (``donate_argnums=(0,)``), so
    params/opt-state/accumulator buffers alias across steps.

    ``zero_stage`` (``--zero-stage``): 1 ≡ ``partition_grads`` (sharded
    optimizer state over "pod"), 2 additionally reduce-scatters each
    microbatch's gradients into a 1/W shard accumulator, 3 shards the
    parameters too — ``state["params"]`` becomes the flat f32 shard
    buckets of ``zero3_param_template`` (sharded ``P("pod")``, doubling as
    the precision master) and the full param tree is only a step
    temporary."""
    policy = None
    if precision is not None:
        policy = get_policy(precision)
        cfg = apply_policy(cfg, policy)
        if policy.is_noop:
            policy = None
    if partition_grads:
        zero_stage = max(zero_stage, 1)
    partition_grads = zero_stage >= 1
    opt = adam(3e-4)

    params_sds = model_sds(cfg)
    step_fn = make_sharded_train_step(
        cfg, opt, remat=True,
        pod_compressor=pod_compressor,
        partition_grads=partition_grads,
        policy=policy,
        accum_steps=accum_steps,
        zero_stage=zero_stage,
        param_template=params_sds if zero_stage >= 3 else None)

    comm_sds, comm_sh = {}, {}
    if pod_compressor is not None:  # error-feedback residual, param-shaped
        comm_sds = {"residual": jax.tree.map(
            lambda s_: jax.ShapeDtypeStruct(s_.shape, jnp.float32), params_sds)}
        comm_sh = {"residual": param_shardings_sds(
            comm_sds["residual"], mesh, cfg.sharding_mode)}
    if partition_grads:  # ZeRO: flat shard-bucket state over "pod"
        from repro.launch.sharding import zero1_state_shardings
        from repro.train.loop import zero1_opt_template
        npods = dict(mesh.shape).get("pod", 1)
        # stage 3: the f32 param shards ARE the master — the opt template
        # must not wrap a second master copy
        opt_sds = zero1_opt_template(params_sds, opt, npods,
                                     policy=None if zero_stage >= 3
                                     else policy)
        opt_sh = zero1_state_shardings(opt_sds, mesh)
    else:
        opt_sds = state_template(opt, params_sds)
        opt_sh = param_shardings_sds(opt_sds, mesh, cfg.sharding_mode)
    if zero_stage >= 3:
        from repro.launch.sharding import zero1_state_shardings
        from repro.train.loop import zero3_param_template
        npods = dict(mesh.shape).get("pod", 1)
        train_params_sds = zero3_param_template(params_sds, npods)
        psh = zero1_state_shardings(train_params_sds, mesh)
    else:
        train_params_sds = params_sds
        psh = param_shardings_sds(params_sds, mesh, cfg.sharding_mode)
    state_sds = {
        "params": train_params_sds,
        "opt_state": opt_sds,
        "comm_state": comm_sds,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_sh = {
        "params": psh,
        "opt_state": opt_sh,
        "comm_state": comm_sh,
        "step": NamedSharding(mesh, P()),
    }
    if policy is not None and policy.uses_scaling:
        state_sds["loss_scale"] = {
            "scale": jax.ShapeDtypeStruct((), jnp.float32),
            "good_steps": jax.ShapeDtypeStruct((), jnp.int32)}
        state_sh["loss_scale"] = {
            "scale": NamedSharding(mesh, P()),
            "good_steps": NamedSharding(mesh, P())}
    if policy is not None and policy.keeps_master and not partition_grads:
        # dense path: param-shaped f32 master in the train state (the
        # ZeRO-1 path keeps its 1/W master inside the opt-state shard)
        state_sds["master"] = jax.tree.map(
            lambda s_: jax.ShapeDtypeStruct(s_.shape, jnp.float32),
            params_sds)
        state_sh["master"] = param_shardings_sds(
            state_sds["master"], mesh, cfg.sharding_mode)
    batch_sds, batch_sh = train_batch_specs(cfg, shape, mesh,
                                            accum_steps=accum_steps)
    return step_fn, (state_sds, batch_sds), (state_sh, batch_sh), (0,)


def build_step(cfg: ModelConfig, shape_name: str, mesh, pod_compressor=None,
               partition_grads: bool = False, precision=None,
               accum_steps: int = 1, zero_stage: int = 0):
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh,
                                pod_compressor=pod_compressor,
                                partition_grads=partition_grads,
                                precision=precision,
                                accum_steps=accum_steps,
                                zero_stage=zero_stage)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh)
    return build_serve_step(cfg, shape, mesh)


def build_prefill_step(cfg: ModelConfig, shape: ShapeSpec, mesh):
    b, l = shape.global_batch, shape.seq_len

    def step_fn(params, batch):
        memory = None
        if cfg.is_encoder_decoder:
            memory = T.encode(params, cfg, embeds=batch["source_embeds"])
        logits, cache = T.prefill(params, cfg,
                                  tokens=batch.get("tokens"),
                                  embeds=batch.get("embeds"),
                                  memory=memory, last_only=True)
        return logits[:, -1], cache

    params_sds = model_sds(cfg)
    batch_sds, batch_sh = {}, {}
    if cfg.modality == "vision":
        batch_sds["embeds"] = jax.ShapeDtypeStruct((b, l, cfg.d_model), _emb_dtype(cfg))
        batch_sh["embeds"] = fit_sharding(batch_sds["embeds"], P(BD, None, None), mesh)
    else:
        batch_sds["tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
        batch_sh["tokens"] = fit_sharding(batch_sds["tokens"], P(BD, None), mesh)
    if cfg.is_encoder_decoder:
        batch_sds["source_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), _emb_dtype(cfg))
        batch_sh["source_embeds"] = fit_sharding(
            batch_sds["source_embeds"], P(BD, None, None), mesh)
    psh = param_shardings_sds(params_sds, mesh, cfg.sharding_mode)
    return step_fn, (params_sds, batch_sds), (psh, batch_sh), ()


def build_serve_step(cfg: ModelConfig, shape: ShapeSpec, mesh):
    b, s = shape.global_batch, shape.seq_len
    cdtype = jnp.dtype(cfg.compute_dtype)

    def step_fn(params, cache, token, pos, memory=None):
        logits, new_cache = T.decode_step(params, cfg, token=token, pos=pos,
                                          cache=cache, memory=memory)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    params_sds = model_sds(cfg)
    cache_sds = jax.eval_shape(lambda: T.init_cache(cfg, b, s, cdtype))
    cache_sh = _tree_shardings(
        cache_sds, lambda p, nd: _cache_spec(p, nd, shape.name), mesh)
    token_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    args_sds = [params_sds, cache_sds, token_sds, pos_sds]
    args_sh = [param_shardings_sds(params_sds, mesh, cfg.sharding_mode), cache_sh,
               fit_sharding(token_sds, P(BD), mesh), NamedSharding(mesh, P())]
    if cfg.is_encoder_decoder:
        mem = jax.ShapeDtypeStruct((b, cfg.encoder_seq_len, cfg.d_model), cdtype)
        args_sds.append(mem)
        args_sh.append(fit_sharding(mem, P(BD, None, None), mesh))
    return step_fn, tuple(args_sds), tuple(args_sh), (1,)


