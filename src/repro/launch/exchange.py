"""Cross-pod gradient exchange with the paper's §2.2.4 compression — the
loosely-coupled-tier program of the hierarchical deployment (DESIGN.md §2).

Each pod runs its own (single-pod) train step; this SEPARATE program then
synchronizes gradients across pods.  The exchange itself is a thin wrapper
over the bucketed ``Fabric`` (core/fabric.py): per-pod grads are flattened
into flat f32 buckets, 1-bit/int8/top-k encoded with error feedback, and
ONE packed uint8 buffer per bucket is all-gathered over "pod" — the same
code path the in-step exchange (train/loop.py) uses.  Grads carry a
leading pod dim (stacked), sharded P("pod", <intra-pod spec>).

(The fused form — compression inside the train step via partial-manual
shard_map — trips an XLA SPMD partitioner CHECK in 0.8.2; the two-program
structure is also how multi-pod deployments actually launch.)

    PYTHONPATH=src python -m repro.launch.exchange --arch gemma3-1b
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import jax_compat as compat
from repro.core.comm import ShardComm
from repro.core.compression import get_compressor
from repro.core.fabric import DEFAULT_BUCKET_BYTES, Fabric
from repro.launch.mesh import ICI_BW, make_production_mesh
from repro.launch.specs import model_sds, param_shardings_sds
from repro.roofline.analysis import parse_collectives


def force_host_devices(n: int = 512):
    """Give the CLI enough forced host devices for the multi-pod mesh.

    Called from ``main()`` ONLY (before the first jax computation touches
    the backend) — an import-time mutation of ``XLA_FLAGS`` used to leak
    512 host devices into every test or tool importing ``build_exchange``."""
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={n}")


def build_exchange(compressor, bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """(grads stacked (P, ...), residual (P, ...)) → (avg grads, residual).

    Runs inside shard_map over "pod"; delegates to ``Fabric.exchange``:
    at most one collective per bucket (an all-gather of packed bytes when
    compressed, an all-reduce of the flat f32 bucket otherwise)."""

    def per_pod(g_loc, r_loc):
        comm = ShardComm("pod", compat.axis_size("pod"))
        fab = Fabric(comm, bucket_bytes)
        g, new_r, _ = fab.exchange(g_loc, r_loc, compressor)
        return g, new_r

    return per_pod


def lower_exchange(arch: str, compressor_name: str,
                   bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    from repro.launch.specs import resolve_config

    mesh = make_production_mesh(multi_pod=True)
    cfg = resolve_config(arch, "train_4k")
    params_sds = model_sds(cfg)
    intra = param_shardings_sds(params_sds, mesh, cfg.sharding_mode)

    def stack(sds):
        return jax.ShapeDtypeStruct((2,) + sds.shape, jnp.float32)

    def stack_sh(sh):
        return NamedSharding(mesh, P(*(("pod",) + tuple(sh.spec))))

    g_sds = jax.tree.map(stack, params_sds)
    g_sh = jax.tree.map(stack_sh, intra)

    comp = None if compressor_name == "none" else get_compressor(compressor_name)
    fn = build_exchange(comp, bucket_bytes)
    smapped = compat.shard_map(
        fn, mesh=mesh, axis_names={"pod"},
        in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")),
        check_vma=False)
    with compat.set_mesh(mesh):
        compiled = jax.jit(smapped).lower(g_sds, g_sds).compile()
    pc = parse_collectives(compiled.as_text())
    total = sum(pc["bytes"].values())
    return total, pc


def main():
    force_host_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--bucket-mib", type=float, default=4.0)
    args = ap.parse_args()
    from repro.configs import get_config, list_configs
    try:
        get_config(args.arch)
    except KeyError:
        print(f"unknown arch {args.arch!r}; valid names: "
              + ", ".join(sorted(list_configs())), file=sys.stderr)
        raise SystemExit(2)
    bucket_bytes = int(args.bucket_mib * 2**20)
    base = None
    for name in ("none", "int8", "onebit", "topk"):
        total, pc = lower_exchange(args.arch, name, bucket_bytes)
        if base is None:
            base = total
        ncoll = sum(pc["counts"].values())
        print(f"{args.arch} cross-pod exchange [{name:6s}]: "
              f"{total/2**20:9.1f} MiB on the wire in {ncoll} collectives "
              f"({base/max(total,1):5.1f}× vs uncompressed)  "
              f"→ {total/ICI_BW*1e3:7.2f} ms at pod-link bw")


if __name__ == "__main__":
    main()
