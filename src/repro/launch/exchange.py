import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"  # noqa: E501

"""Cross-pod gradient exchange with the paper's §2.2.4 compression — the
loosely-coupled-tier program of the hierarchical deployment (DESIGN.md §2).

Each pod runs its own (single-pod) train step; this SEPARATE program then
synchronizes gradients across pods: per-pod grads are 1-bit/int8/top-k
encoded with error feedback, the COMPACT wire format is all-gathered over
"pod", and each pod decodes + averages.  Grads carry a leading pod dim
(stacked), sharded P("pod", <intra-pod spec>).

(The fused form — compression inside the train step via partial-manual
shard_map — trips an XLA SPMD partitioner CHECK in 0.8.2; the two-program
structure is also how multi-pod deployments actually launch.)

    PYTHONPATH=src python -m repro.launch.exchange --arch gemma3-1b
"""

import argparse  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.compression import (get_compressor, pack_signs,  # noqa: E402
                                    unpack_signs)
from repro.launch.mesh import ICI_BW, make_production_mesh  # noqa: E402
from repro.launch.specs import model_sds, param_shardings_sds  # noqa: E402
from repro.launch.sharding import _filter_spec  # noqa: E402
from repro.roofline.analysis import parse_collectives  # noqa: E402


def build_exchange(compressor):
    """(grads stacked (P, ...), residual (P, ...)) → (avg grads, residual)."""

    def per_pod(g_loc, r_loc):
        flat_g, treedef = jax.tree.flatten(g_loc)
        flat_r = jax.tree.leaves(r_loc)
        out_g, out_r = [], []
        for g, r in zip(flat_g, flat_r):
            if compressor is None:
                out_g.append(jax.lax.pmean(g, "pod"))
                out_r.append(r)
                continue
            target = g.astype(jnp.float32) + r
            wire, meta = compressor.compress(target)
            decoded_self = compressor.decompress(wire, meta, g.shape,
                                                 jnp.float32)
            if compressor.name == "onebit":
                # true 1-bit wire format: pack 8 signs/byte before the hop
                sign, scale = wire
                nsign = sign.size
                sshape = sign.shape
                wire = (pack_signs(sign.reshape(-1)), scale)

                def unpack(w):
                    return (unpack_signs(w[0], nsign).reshape(sshape), w[1])
            else:
                def unpack(w):
                    return w
            gathered = jax.tree.map(lambda w: jax.lax.all_gather(w, "pod"),
                                    wire)
            npods = jax.lax.axis_size("pod")
            dec = [compressor.decompress(
                unpack(jax.tree.map(lambda w: w[i], gathered)), meta,
                g.shape, jnp.float32) for i in range(npods)]
            out_g.append((sum(dec) / npods).astype(g.dtype))
            out_r.append(target - decoded_self)
        return (jax.tree.unflatten(treedef, out_g),
                jax.tree.unflatten(treedef, out_r))

    return per_pod


def lower_exchange(arch: str, compressor_name: str):
    import dataclasses

    from repro.launch.specs import resolve_config

    mesh = make_production_mesh(multi_pod=True)
    cfg = resolve_config(arch, "train_4k")
    params_sds = model_sds(cfg)
    intra = param_shardings_sds(params_sds, mesh, cfg.sharding_mode)

    def stack(sds):
        return jax.ShapeDtypeStruct((2,) + sds.shape, jnp.float32)

    def stack_sh(sh):
        return NamedSharding(mesh, P(*(("pod",) + tuple(sh.spec))))

    g_sds = jax.tree.map(stack, params_sds)
    g_sh = jax.tree.map(stack_sh, intra)

    comp = None if compressor_name == "none" else get_compressor(compressor_name)
    fn = build_exchange(comp)
    smapped = jax.shard_map(
        fn, mesh=mesh, axis_names={"pod"},
        in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")),
        check_vma=False)
    with jax.set_mesh(mesh):
        compiled = jax.jit(smapped).lower((g_sds,) * 0 or g_sds, g_sds).compile()
    pc = parse_collectives(compiled.as_text())
    total = sum(pc["bytes"].values())
    return total, pc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    args = ap.parse_args()
    base = None
    for name in ("none", "int8", "onebit", "topk"):
        total, pc = lower_exchange(args.arch, name)
        if base is None:
            base = total
        print(f"{args.arch} cross-pod exchange [{name:6s}]: "
              f"{total/2**20:9.1f} MiB on the wire "
              f"({base/max(total,1):5.1f}× vs uncompressed)  "
              f"→ {total/ICI_BW*1e3:7.2f} ms at pod-link bw")


if __name__ == "__main__":
    main()
