import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""HLO collective diagnosis: top-N collectives by bytes for one
(arch × shape), from the unrolled 1-super-block lowering.

    PYTHONPATH=src python -m repro.launch.diagnose --arch qwen2-moe-a2.7b --shape train_4k
"""

import argparse  # noqa: E402
from collections import defaultdict  # noqa: E402

import jax  # noqa: E402

from repro.core import jax_compat as compat  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_step, resolve_config, truncate  # noqa: E402
from repro.roofline.analysis import _INSTR_RE, _shape_bytes, COLLECTIVE_OPS  # noqa: E402


def top_collectives(arch, shape, multi_pod=False, repeat=1, n=14, mode="tp",
                    mesh=None, zero_stage=0):
    """``mesh=None`` builds the production mesh; tests inject a small mesh
    (e.g. (2, 2) over ("data", "model")) so the diagnosis runs on a
    4-device CPU container without the 512-device production env."""
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    import dataclasses
    cfg = truncate(dataclasses.replace(resolve_config(arch, shape),
                                       sharding_mode=mode), repeat)
    step_fn, sds, sh, donate = build_step(cfg, shape, mesh,
                                          zero_stage=zero_stage)
    with compat.set_mesh(mesh):
        comp = jax.jit(step_fn, in_shardings=sh,
                       donate_argnums=donate).lower(*sds).compile()
    rows = []
    for line in comp.as_text().splitlines():
        s = line.strip()
        m = _INSTR_RE.search(s)
        if not m:
            continue
        shape_str, op = m.groups()
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_OPS and not op.endswith("-done"):
            rows.append((_shape_bytes(shape_str), base, s[:170]))
    rows.sort(reverse=True)
    per_type = defaultdict(int)
    for b, base, _ in rows:
        per_type[base] += b
    total = sum(per_type.values())
    print(f"=== {arch} x {shape} [{'2x16x16' if multi_pod else '16x16'}] "
          f"R={repeat}: {total/2**30:.2f} GiB collective, {len(rows)} ops")
    for k, v in sorted(per_type.items(), key=lambda kv: -kv[1]):
        print(f"  {k:20s} {v/2**30:8.2f} GiB")
    for b, base, l in rows[:n]:
        print(f"  {b/2**20:9.1f} MiB {base:18s} {l[:130]}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--repeat", type=int, default=1)
    ap.add_argument("--mode", default="tp")
    args = ap.parse_args()
    top_collectives(args.arch, args.shape, args.multi_pod, args.repeat, mode=args.mode)
