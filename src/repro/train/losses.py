"""Losses."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, mask=None):
    """logits: (..., V) fp32; labels: (...) int32. Mean over unmasked."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(logits, tokens, aux=0.0):
    """Shifted next-token loss: predict tokens[t+1] from position t."""
    return cross_entropy(logits[:, :-1], tokens[:, 1:]) + aux
