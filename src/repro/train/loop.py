"""Training loops.

Two entry points mirroring the Comm duality (DESIGN.md §3):

  * ``make_replica_train_step`` — the *strategy simulator*: W model replicas
    stacked on axis 0 (LocalComm layout), per-worker data shards, any
    spectrum strategy.  Runs on one device; used by tests, convergence
    benchmarks, and the examples.  This is the paper's experimental rig.

  * ``make_sharded_train_step`` — the production path: one global model,
    pjit-sharded over (pod, data, model); the strategy runs across the
    ``pod`` (or ``data``) axis via shard_map + ShardComm.  ``sync`` here is
    plain global data parallelism (the paper's point 1), which is also what
    the multi-pod dry-run lowers.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import jax_compat as compat
from repro.core.comm import Comm, LocalComm, ShardComm
from repro.core.fabric import (BucketLayout, DEFAULT_BUCKET_BYTES, Fabric,
                               PartitionedLayout)
from repro.core.strategies import Strategy
from repro.models import transformer as T
from repro.optim.optimizers import Optimizer, state_template
from repro.train.losses import lm_loss


def init_train_state(params, optimizer: Optimizer, strategy: Strategy,
                     comm: Comm):
    # strategies that own the optimizer-state layout (ZeRO-1 shard buckets)
    # build it themselves; everyone else gets the dense param-shaped state
    init_opt = getattr(strategy, "init_opt", None)
    opt_state = (init_opt(params, optimizer, comm) if init_opt is not None
                 else optimizer.init(params))
    return {
        "params": params,
        "opt_state": opt_state,
        "comm_state": strategy.init(params, comm),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# replica simulator (LocalComm stacked layout)
# ---------------------------------------------------------------------------
def make_replica_train_step(loss_fn, optimizer: Optimizer, strategy: Strategy,
                            comm: LocalComm, jit: bool = True):
    """loss_fn(params, batch) -> scalar, defined for ONE replica.

    The returned step takes stacked state (leading dim W on every leaf of
    params/opt_state) and per-worker batches (leading dim W)."""

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    def step(state, batches):
        loss, grads = grad_fn(state["params"], batches)
        params, opt_state, comm_state, metrics = strategy.update(
            state["params"], grads, state["opt_state"], state["comm_state"],
            state["step"], optimizer, comm)
        new_state = {"params": params, "opt_state": opt_state,
                     "comm_state": comm_state, "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics["loss"] = jnp.mean(loss)
        metrics["replica_divergence"] = _stack_divergence(params)
        return new_state, metrics

    return jax.jit(step) if jit else step


def _stack_divergence(params):
    """Max |w_i − w_0| over replicas — the model-consistency measure of §3."""

    def per_leaf(x):
        return jnp.max(jnp.abs(x - x[0:1])) if x.ndim > 0 and x.shape[0] > 1 \
            else jnp.zeros((), x.dtype)

    leaves = [per_leaf(x).astype(jnp.float32) for x in jax.tree.leaves(params)]
    return jnp.max(jnp.stack(leaves)) if leaves else jnp.zeros(())


# ---------------------------------------------------------------------------
# production (sharded) train step — also the dry-run target
# ---------------------------------------------------------------------------
def make_loss_fn(cfg, remat: bool = True):
    def loss_fn(params, batch):
        memory = None
        if cfg.is_encoder_decoder:
            memory = T.encode(params, cfg, embeds=batch["source_embeds"])
        logits, aux = T.forward(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            memory=memory,
            remat=remat)
        return lm_loss(logits, batch["labels"], aux)
    return loss_fn


def zero1_opt_template(params, optimizer: Optimizer, n_parts: int,
                       bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """GLOBAL optimizer state for the partitioned production path: one
    padded flat f32 bucket per state leaf, to be sharded ``P("pod")`` over
    the data-parallel axis (per-device footprint 1/W).  Accepts arrays or
    ShapeDtypeStructs; returns the same flavour."""
    play = PartitionedLayout.build(
        BucketLayout.build(params, bucket_bytes, lead_axes=0), n_parts)
    sds = [jax.ShapeDtypeStruct((p,), jnp.float32)
           for p in play.padded_sizes]
    template = state_template(optimizer, sds)
    if all(isinstance(x, jax.ShapeDtypeStruct)
           for x in jax.tree.leaves(params)):
        return template
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), template)


def make_sharded_train_step(cfg, optimizer: Optimizer,
                            strategy: Optional[Strategy] = None,
                            comm: Optional[Comm] = None,
                            remat: bool = True,
                            pod_compressor=None,
                            partition_grads: bool = False,
                            bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Global-model train step.  With ``strategy=None`` this is pure
    synchronous data parallelism (gradients all-reduced by XLA across the
    batch sharding) — the paper's spectrum point 1 and the dry-run target.
    With a strategy + ShardComm, the gradient transform runs across the
    named axis (used by the hierarchical pod-level strategies).

    ``pod_compressor``: the paper's §2.2.4 technique as a first-class
    production feature — gradients are synced *completely* inside each pod
    (fast ICI, spectrum pt. 1) but the CROSS-POD hop (slow DCN, the paper's
    loosely-coupled tier) ships the COMPRESSED payload.  The exchange is
    the bucketed ``Fabric`` (core/fabric.py): per-pod gradients are
    flattened into flat f32 buckets, 1-bit/int8/top-k encoded with error
    feedback, and ONE packed byte buffer per bucket is all-gathered over
    "pod" — at most n_buckets collectives in the lowered HLO where the old
    per-leaf path emitted one (or more) per parameter.

    ``partition_grads`` (ZeRO-1): gradients are reduce-SCATTERED over the
    "pod" axis instead of all-reduced; each pod updates its 1/W parameter
    shard against 1/W of the optimizer state (``state["opt_state"]`` must
    be the flat shard buckets from ``zero1_opt_template``, sharded
    ``P("pod")``) and the updated shards are all-gathered back.  Same wire
    bytes as the all-reduce, O(W) less optimizer-state memory per device.
    Mutually exclusive with ``pod_compressor`` and ``strategy``."""

    loss_fn = make_loss_fn(cfg, remat=remat)
    if partition_grads and (pod_compressor is not None
                            or strategy is not None):
        raise ValueError("partition_grads composes with the plain sync "
                         "path only (no pod_compressor / strategy)")

    def sync_grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def pod_fabric_grads(params, batch, residual):
        from jax.sharding import PartitionSpec as P

        mesh = compat.get_abstract_mesh()
        npods = dict(mesh.shape).get("pod", 1)

        def per_pod(params, batch, residual):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            fab = Fabric(ShardComm("pod", npods), bucket_bytes)
            grads, new_r, _ = fab.exchange(grads, residual, pod_compressor)
            return jax.lax.pmean(loss, "pod"), grads, new_r

        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        rep = jax.tree.map(lambda _: P(), params)
        rep_r = jax.tree.map(lambda _: P(), residual)
        return compat.shard_map(
            per_pod, mesh=mesh, axis_names={"pod"},
            in_specs=(rep, batch_specs, rep_r),
            out_specs=(P(), rep, rep_r), check_vma=False,
        )(params, batch, residual)

    def zero1_step_body(params, batch, opt_state, t):
        """shard_map body over "pod": grads → reduce-scatter → shard update
        → all-gather, one RS + one AG per bucket, NO full all-reduce of
        gradients (the loss mean is the only scalar psum)."""
        from jax.sharding import PartitionSpec as P

        mesh = compat.get_abstract_mesh()
        npods = dict(mesh.shape).get("pod", 1)

        def per_pod(params, batch, opt_state, t):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            fab = Fabric(ShardComm("pod", npods), bucket_bytes)
            play = fab.partitioned_layout(params)
            g_shards, _ = fab.exchange_partitioned(grads, play)
            p_shards = fab.shard_params(params, play)
            p_shards, opt_state = optimizer.update(g_shards, opt_state,
                                                   p_shards, t)
            params = fab.unpartition(p_shards, play)
            return jax.lax.pmean(loss, "pod"), params, opt_state

        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        rep = jax.tree.map(lambda _: P(), params)
        shard_specs = jax.tree.map(lambda _: P("pod"), opt_state)
        return compat.shard_map(
            per_pod, mesh=mesh, axis_names={"pod"},
            in_specs=(rep, batch_specs, shard_specs, P()),
            out_specs=(P(), rep, shard_specs), check_vma=False,
        )(params, batch, opt_state, t)

    def step(state, batch):
        if partition_grads:
            loss, params, opt_state = zero1_step_body(
                state["params"], batch, state["opt_state"], state["step"])
            return ({"params": params, "opt_state": opt_state,
                     "comm_state": state["comm_state"],
                     "step": state["step"] + 1}, loss)
        if pod_compressor is not None:
            loss, grads, new_res = pod_fabric_grads(
                state["params"], batch, state["comm_state"]["residual"])
            comm_state = {"residual": new_res}
        else:
            loss, grads = sync_grads(state["params"], batch)
            comm_state = state["comm_state"]
        if strategy is not None:
            params, opt_state, comm_state, _ = strategy.update(
                state["params"], grads, state["opt_state"],
                comm_state, state["step"], optimizer, comm)
        else:
            params, opt_state = optimizer.update(
                grads, state["opt_state"], state["params"], state["step"])
        return ({"params": params, "opt_state": opt_state,
                 "comm_state": comm_state, "step": state["step"] + 1}, loss)

    return step
