"""Training loops.

Two entry points mirroring the Comm duality (DESIGN.md §3):

  * ``make_replica_train_step`` — the *strategy simulator*: W model replicas
    stacked on axis 0 (LocalComm layout), per-worker data shards, any
    spectrum strategy.  Runs on one device; used by tests, convergence
    benchmarks, and the examples.  This is the paper's experimental rig.

  * ``make_sharded_train_step`` — the production path: one global model,
    pjit-sharded over (pod, data, model); the strategy runs across the
    ``pod`` (or ``data``) axis via shard_map + ShardComm.  ``sync`` here is
    plain global data parallelism (the paper's point 1), which is also what
    the multi-pod dry-run lowers.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import jax_compat as compat
from repro.core import precision as PR
from repro.core.comm import Comm, HierComm, LocalComm, ShardComm
from repro.core.fabric import (BucketLayout, DEFAULT_BUCKET_BYTES, Fabric,
                               PartitionedLayout)
from repro.core.precision import PrecisionPolicy
from repro.core.strategies import Strategy
from repro.models import transformer as T
from repro.optim.optimizers import Optimizer, state_template
from repro.train.losses import lm_loss


def init_train_state(params, optimizer: Optimizer, strategy: Strategy,
                     comm: Comm, policy: Optional[PrecisionPolicy] = None):
    # ZeRO-3: the strategy owns the PARAMETER layout too — the dense init
    # params are sharded into 1/W flat f32 buckets up front (recording the
    # PartitionedLayout inside the strategy) and everything downstream
    # (optimizer state, comm state) is built over the shards
    if getattr(strategy, "owns_params", False):
        params = strategy.init_params(params, comm)
    # strategies that own the optimizer-state layout (ZeRO-1 shard buckets)
    # build it themselves; everyone else gets the dense param-shaped state
    init_opt = getattr(strategy, "init_opt", None)
    opt_state = (init_opt(params, optimizer, comm) if init_opt is not None
                 else optimizer.init(params))
    state = {
        "params": params,
        "opt_state": opt_state,
        "comm_state": strategy.init(params, comm),
        "step": jnp.zeros((), jnp.int32),
    }
    if policy is not None and not policy.is_noop:
        if policy.uses_scaling:
            state["loss_scale"] = PR.init_scale_state(policy)
        if policy.keeps_master and not getattr(strategy, "owns_master",
                                               False):
            # dense strategies: the wider master copy lives in the train
            # state (the ZeRO-1 strategy keeps its own 1/W master shards
            # inside opt_state instead — never both)
            state["master"] = policy.cast_to_master(params)
    return state


# ---------------------------------------------------------------------------
# replica simulator (LocalComm stacked layout)
# ---------------------------------------------------------------------------
def make_replica_train_step(loss_fn, optimizer: Optimizer, strategy: Strategy,
                            comm: LocalComm, jit: bool = True,
                            policy: Optional[PrecisionPolicy] = None,
                            accum_steps: int = 1,
                            bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                            donate: bool = True):
    """loss_fn(params, batch) -> scalar, defined for ONE replica.

    The returned step takes stacked state (leading dim W on every leaf of
    params/opt_state) and per-worker batches (leading dim W), and is jitted
    with ``donate_argnums=(0,)`` (``donate=False`` opts out): the consumed
    train state aliases the produced one, so params / optimizer state /
    master / accumulator buffers are updated in place instead of
    re-allocated every step.  Callers must not touch a donated input state
    after stepping — re-step from a state you intend to keep only with
    ``donate=False``.

    ``accum_steps > 1`` turns the step into a MICROBATCHED boundary step
    (DESIGN.md §8): batches carry a leading ``(accum_steps, W, ...)`` axis,
    a ``lax.scan`` accumulates per-microbatch gradients directly into the
    Fabric's flat f32 buckets (one flatten per microbatch, no per-microbatch
    tree unflatten), and the strategy — hence the exchange, and with it any
    compression / error-feedback state — runs ONCE per boundary on the
    microbatch-mean gradients.  ``state["step"]`` counts optimizer steps
    (boundaries), so ``sync_every``-style schedules of local-step
    strategies (``Strategy.exchange_at_boundary=False``) are unchanged by
    accumulation.  Wire bytes per sample shrink by ``accum_steps``.

    With a non-trivial precision ``policy`` (core/precision.py) the step
    becomes cast-params → forward (scaled loss) → unscale → skip-or-apply:
    the strategy/optimizer pipeline runs on the widest copy available (the
    f32 master for dense strategies, the working params for the ZeRO-1
    strategy whose master rides its opt-state shard), the fabric ships
    wire-dtype buckets, and a step with non-finite gradients leaves
    params, optimizer state and comm state untouched while the dynamic
    loss scale backs off.  Under accumulation the finite check and the
    skip decision apply to the whole boundary.  ``policy=None`` (or the
    f32 policy) takes the exact pre-precision code path — bit-for-bit
    identical (the gradient of each microbatch is accumulated in f32 in
    the same order a per-microbatch reference would sum trees)."""
    if accum_steps < 1:
        raise ValueError(f"accum_steps must be >= 1, got {accum_steps}")

    def _jit(fn):
        if not jit:
            return fn
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def accum_grads(src, batches, vgrad_fn):
        """scan over the leading microbatch axis, accumulating gradients
        into flat f32 buckets — zero collectives in here; the boundary
        exchange consumes the SUM (callers divide by accum_steps, and by
        the loss scale, exactly once)."""
        # the accumulator is purely local: it only needs the replica-axis
        # layout, which a two-tier HierComm delegates to its inner comm
        # (both tiers declare the same lead_axes)
        acc_comm = comm.inner if isinstance(comm, HierComm) else comm
        fab = Fabric(acc_comm, bucket_bytes)
        lay = fab.layout(src)

        def micro(carry, mb):
            acc, loss_sum = carry
            loss, grads = vgrad_fn(src, mb)
            return (fab.accumulate(acc, grads, lay),
                    loss_sum + jnp.mean(loss)), None

        (acc, loss_sum), _ = lax.scan(
            micro, (fab.init_accum(lay), jnp.zeros((), jnp.float32)),
            batches)
        return acc, lay, loss_sum

    owns_params = getattr(strategy, "owns_params", False)
    part_accum = accum_steps > 1 and getattr(strategy, "partitioned_accum",
                                             False)

    def accum_grads_part(full, batches, vgrad_fn):
        """ZeRO-2/3 microbatch accumulation (DESIGN.md §12): every
        microbatch's gradients are reduce-scatter-meaned and ONLY the
        local 1/W shard accumulates (``Fabric.accumulate_partitioned``) —
        the full gradient tree is never resident across microbatches.
        The RS is a cross-worker collective, so it runs on the outer comm
        (not the HierComm inner tier).  Returns (summed shard buckets,
        summed per-replica-mean loss, RS wire bytes, RS events); callers
        divide the shards ONCE at the boundary."""
        fab = Fabric(comm, bucket_bytes)
        play = fab.partitioned_layout(full)

        def micro(carry, mb):
            acc, loss_sum, wire, ev = carry
            loss, grads = vgrad_fn(full, mb)
            acc, m = fab.accumulate_partitioned(acc, grads, play)
            return (acc, loss_sum + jnp.mean(loss), wire + m["wire_bytes"],
                    ev + m["comm_events"]), None

        (acc, loss_sum, wire, ev), _ = lax.scan(
            micro, (fab.init_accum_partitioned(play),
                    jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.float32)), batches)
        return acc, loss_sum, wire, ev

    if policy is None or policy.is_noop:
        grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

        def step(state, batches):
            src = state["params"]
            # ZeRO-3: params live as 1/W shard buckets — gather the full
            # tree (per-bucket all-gather) for forward/backward only; it
            # is a temporary of the step, never part of the train state
            fwd = strategy.gather_params(src, comm) if owns_params else src
            boundary_wire = None
            if accum_steps == 1:
                loss, grads = grad_fn(fwd, batches)
                mean_loss = jnp.mean(loss)
                params, opt_state, comm_state, metrics = strategy.update(
                    src, grads, state["opt_state"],
                    state["comm_state"], state["step"], optimizer, comm)
            elif part_accum:
                acc, loss_sum, wire, ev = accum_grads_part(fwd, batches,
                                                           grad_fn)
                g_shards = [a / accum_steps for a in acc]
                mean_loss = loss_sum / accum_steps
                params, opt_state, comm_state, metrics = \
                    strategy.update_partitioned(
                        src, g_shards, state["opt_state"],
                        state["comm_state"], state["step"], optimizer, comm)
                boundary_wire = (wire, ev)
            else:
                acc, lay, loss_sum = accum_grads(fwd, batches, grad_fn)
                grads = lay.debucketize([a / accum_steps for a in acc])
                mean_loss = loss_sum / accum_steps
                params, opt_state, comm_state, metrics = strategy.update(
                    src, grads, state["opt_state"],
                    state["comm_state"], state["step"], optimizer, comm)
            new_state = {"params": params, "opt_state": opt_state,
                         "comm_state": comm_state, "step": state["step"] + 1}
            metrics = dict(metrics)
            if boundary_wire is not None:  # charge the per-microbatch RS
                metrics["wire_bytes"] = metrics["wire_bytes"] \
                    + boundary_wire[0]
                metrics["comm_events"] = metrics["comm_events"] \
                    + boundary_wire[1]
            metrics["loss"] = mean_loss
            metrics["replica_divergence"] = _stack_divergence(
                strategy.gather_params(params, comm) if owns_params
                else params)
            return new_state, metrics

        return _jit(step)

    def step(state, batches):
        sstate = state.get("loss_scale")
        scale = sstate["scale"] if sstate is not None else 1.0
        src = state.get("master", state["params"])
        fwd = strategy.gather_params(src, comm) if owns_params else src

        def scaled_loss(p_src, batch):
            # cast-params: forward consumes the param-dtype image of the
            # (possibly wider) source-of-truth copy
            return loss_fn(policy.cast_to_param(p_src), batch) * scale

        vgrad = jax.vmap(jax.value_and_grad(scaled_loss), in_axes=(0, 0))
        boundary_wire = None
        if accum_steps == 1:
            loss, grads = vgrad(fwd, batches)
            grads = PR.unscale_grads(grads, scale)
            mean_loss = jnp.mean(loss)
        elif part_accum:
            acc, loss_sum, wire, ev = accum_grads_part(fwd, batches, vgrad)
            # shard-space boundary: one division for microbatch mean AND
            # unscale, then straight into the partitioned update
            grads = [a / (accum_steps * scale) for a in acc]
            mean_loss = loss_sum / accum_steps
            boundary_wire = (wire, ev)
        else:
            acc, lay, loss_sum = accum_grads(fwd, batches, vgrad)
            # one division at the boundary: microbatch mean AND unscale
            # (the accumulator keeps f32 — cast=False — so the boundary
            # gradients are at least as wide as the legacy per-step path)
            grads = lay.debucketize([a / (accum_steps * scale) for a in acc],
                                    cast=False)
            mean_loss = loss_sum / accum_steps
        finite = PR.tree_finite(grads) if sstate is not None \
            else jnp.asarray(True)
        if boundary_wire is not None:
            new_src, opt_state, comm_state, metrics = \
                strategy.update_partitioned(
                    src, grads, state["opt_state"], state["comm_state"],
                    state["step"], optimizer, comm)
            metrics = dict(metrics)
            metrics["wire_bytes"] = metrics["wire_bytes"] + boundary_wire[0]
            metrics["comm_events"] = metrics["comm_events"] \
                + boundary_wire[1]
        else:
            new_src, opt_state, comm_state, metrics = strategy.update(
                src, grads, state["opt_state"], state["comm_state"],
                state["step"], optimizer, comm)
        if sstate is not None:  # skip-or-apply
            new_src = PR.select_tree(finite, new_src, src)
            opt_state = PR.select_tree(finite, opt_state,
                                       state["opt_state"])
            comm_state = PR.select_tree(finite, comm_state,
                                        state["comm_state"])
        new_state = {"opt_state": opt_state, "comm_state": comm_state,
                     "step": state["step"] + 1}
        if "master" in state:
            new_state["master"] = new_src
            new_state["params"] = policy.cast_to_param(new_src)
        else:
            new_state["params"] = new_src
        metrics = dict(metrics)
        metrics["loss"] = mean_loss / scale
        metrics["replica_divergence"] = _stack_divergence(
            strategy.gather_params(new_state["params"], comm) if owns_params
            else new_state["params"])
        if sstate is not None:
            new_state["loss_scale"] = PR.next_scale_state(policy, sstate,
                                                          finite)
            metrics["loss_scale"] = sstate["scale"]
            metrics["overflow"] = 1.0 - finite.astype(jnp.float32)
        return new_state, metrics

    return _jit(step)


def jit_cache_size(step_fn) -> int:
    """Compiled-variant count of a jitted step fn — the probe behind the
    retrace-detector lint rule (repro.analysis.rules.retrace): exactly 1
    in steady state; every growth is a silent recompilation in the
    training loop.  Returns -1 when the callable exposes no cache
    accounting (``jit=False``, or a jax without ``_cache_size``)."""
    probe = getattr(step_fn, "_cache_size", None)
    return int(probe()) if callable(probe) else -1


def _stack_divergence(params):
    """Max |w_i − w_0| over replicas — the model-consistency measure of §3."""

    def per_leaf(x):
        return jnp.max(jnp.abs(x - x[0:1])) if x.ndim > 0 and x.shape[0] > 1 \
            else jnp.zeros((), x.dtype)

    leaves = [per_leaf(x).astype(jnp.float32) for x in jax.tree.leaves(params)]
    return jnp.max(jnp.stack(leaves)) if leaves else jnp.zeros(())


# ---------------------------------------------------------------------------
# production (sharded) train step — also the dry-run target
# ---------------------------------------------------------------------------
def make_loss_fn(cfg, remat: bool = True):
    def loss_fn(params, batch):
        memory = None
        if cfg.is_encoder_decoder:
            memory = T.encode(params, cfg, embeds=batch["source_embeds"])
        logits, aux = T.forward(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            memory=memory,
            remat=remat)
        return lm_loss(logits, batch["labels"], aux)
    return loss_fn


def zero1_opt_template(params, optimizer: Optimizer, n_parts: int,
                       bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                       policy: Optional[PrecisionPolicy] = None):
    """GLOBAL optimizer state for the partitioned production path: one
    padded flat f32 bucket per state leaf, to be sharded ``P("pod")`` over
    the data-parallel axis (per-device footprint 1/W).  Accepts arrays or
    ShapeDtypeStructs; returns the same flavour.

    Under a master-keeping policy the template grows the f32 master
    buckets: ``{"opt": <inner>, "master": [...]}`` — matching
    ``sync_zero1(policy=...)``'s opt-state layout.  A template built from
    real arrays materializes the master FROM the params (zeros would
    silently reset the model on the first step); use
    ``zero1_master_buckets`` to fill a ShapeDtypeStruct template."""
    play = PartitionedLayout.build(
        BucketLayout.build(params, bucket_bytes, lead_axes=0), n_parts)
    sds = [jax.ShapeDtypeStruct((p,), jnp.float32)
           for p in play.padded_sizes]
    template = state_template(optimizer, sds)
    keeps_master = policy is not None and policy.keeps_master
    if keeps_master:
        template = {"opt": template, "master": list(sds)}
    if all(isinstance(x, jax.ShapeDtypeStruct)
           for x in jax.tree.leaves(params)):
        return template
    zeros = lambda t: jax.tree.map(  # noqa: E731
        lambda s: jnp.zeros(s.shape, s.dtype), t)
    if keeps_master:  # master comes FROM the params, never from zeros
        return {"opt": zeros(template["opt"]),
                "master": zero1_master_buckets(params, n_parts,
                                               bucket_bytes)}
    return zeros(template)


def zero1_master_buckets(params, n_parts: int,
                         bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """The f32 master in GLOBAL (padded flat bucket) form, initialized
    from the params — what the "master" entry of the production ZeRO-1
    opt state must hold before the first step."""
    lay = BucketLayout.build(params, bucket_bytes, lead_axes=0)
    play = PartitionedLayout.build(lay, n_parts)
    buckets = lay.bucketize(params)
    return [b if b.shape[-1] == p else
            jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, p - b.shape[-1])])
            for b, p in zip(buckets, play.padded_sizes)]


def zero3_param_template(params, n_parts: int,
                         bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """GLOBAL parameter state for the ZeRO-3 production path: one padded
    flat f32 bucket per param bucket, to be sharded ``P("pod")`` over the
    data-parallel axis — per-device footprint 1/W of the f32 model, and
    the ONLY param-shaped thing in the train state (the full tree exists
    only as a step temporary after the per-bucket all-gather).  The f32
    buckets double as the precision master under a master-keeping policy.
    Accepts arrays or ShapeDtypeStructs; returns the same flavour (arrays
    are filled FROM the params — zeros would reset the model)."""
    play = PartitionedLayout.build(
        BucketLayout.build(params, bucket_bytes, lead_axes=0), n_parts)
    if all(isinstance(x, jax.ShapeDtypeStruct)
           for x in jax.tree.leaves(params)):
        return [jax.ShapeDtypeStruct((p,), jnp.float32)
                for p in play.padded_sizes]
    return zero1_master_buckets(params, n_parts, bucket_bytes)


def make_sharded_train_step(cfg, optimizer: Optimizer,
                            strategy: Optional[Strategy] = None,
                            comm: Optional[Comm] = None,
                            remat: bool = True,
                            pod_compressor=None,
                            partition_grads: bool = False,
                            bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                            policy: Optional[PrecisionPolicy] = None,
                            accum_steps: int = 1,
                            zero_stage: int = 0,
                            param_template=None):
    """Global-model train step.  With ``strategy=None`` this is pure
    synchronous data parallelism (gradients all-reduced by XLA across the
    batch sharding) — the paper's spectrum point 1 and the dry-run target.
    With a strategy + ShardComm, the gradient transform runs across the
    named axis (used by the hierarchical pod-level strategies).

    ``pod_compressor``: the paper's §2.2.4 technique as a first-class
    production feature — gradients are synced *completely* inside each pod
    (fast ICI, spectrum pt. 1) but the CROSS-POD hop (slow DCN, the paper's
    loosely-coupled tier) ships the COMPRESSED payload.  The exchange is
    the bucketed ``Fabric`` (core/fabric.py): per-pod gradients are
    flattened into flat f32 buckets, 1-bit/int8/top-k encoded with error
    feedback, and ONE packed byte buffer per bucket is all-gathered over
    "pod" — at most n_buckets collectives in the lowered HLO where the old
    per-leaf path emitted one (or more) per parameter.

    ``partition_grads`` (ZeRO-1): gradients are reduce-SCATTERED over the
    "pod" axis instead of all-reduced; each pod updates its 1/W parameter
    shard against 1/W of the optimizer state (``state["opt_state"]`` must
    be the flat shard buckets from ``zero1_opt_template``, sharded
    ``P("pod")``) and the updated shards are all-gathered back.  Same wire
    bytes as the all-reduce, O(W) less optimizer-state memory per device.
    Mutually exclusive with ``pod_compressor`` and ``strategy``.

    ``zero_stage`` generalizes it (``partition_grads=True`` ≡ stage 1):
    stage 2 reduce-scatters every MICROBATCH's gradients into a 1/W
    shard-bucket accumulator (the full gradient never materializes across
    microbatches); stage 3 additionally shards the PARAMETERS —
    ``state["params"]`` must be the flat f32 shard buckets from
    ``zero3_param_template`` (sharded ``P("pod")``), ``param_template``
    must carry the full model's arrays/ShapeDtypeStructs, and each step
    all-gathers the wire-dtype param image as a boundary temporary.

    ``accum_steps > 1`` (DESIGN.md §8): the batch carries a leading
    ``accum_steps`` axis and the step becomes a microbatched BOUNDARY
    step.  On the restructured paths (plain sync, ZeRO-1, pod compressor)
    a ``lax.scan`` inside the "pod" shard_map accumulates per-microbatch
    per-pod gradients directly into the Fabric's flat f32 buckets — the
    scan body issues ZERO cross-pod collectives — and exactly one
    exchange's worth of collectives (≤ n_buckets all-reduces, or one
    reduce-scatter + all-gather pair per bucket on the ZeRO-1 path) fires
    per boundary, so wire bytes per sample shrink by ``accum_steps``.
    Compression / error-feedback state advances once per boundary.  The
    legacy strategy-over-ShardComm path falls back to tree-space
    accumulation (strategy semantics preserved; no HLO fusion claim)."""

    loss_fn = make_loss_fn(cfg, remat=remat)
    if partition_grads:  # legacy spelling of the first ZeRO stage
        zero_stage = max(zero_stage, 1)
    if zero_stage not in (0, 1, 2, 3):
        raise ValueError(f"zero_stage must be 0..3, got {zero_stage}")
    if zero_stage and (pod_compressor is not None or strategy is not None):
        raise ValueError("partition_grads composes with the plain sync "
                         "path only (no pod_compressor / strategy)")
    if zero_stage >= 3 and param_template is None:
        raise ValueError("zero_stage=3 needs param_template (the FULL "
                         "model's arrays or ShapeDtypeStructs) to rebuild "
                         "the shard-bucket layout inside the step")
    partition_grads = zero_stage >= 1
    if accum_steps < 1:
        raise ValueError(f"accum_steps must be >= 1, got {accum_steps}")
    if policy is not None and policy.is_noop:
        policy = None  # f32 policy: take the pre-precision path bit-for-bit
    scaling = policy is not None and policy.uses_scaling
    keeps_master = policy is not None and policy.keeps_master
    wire = policy.wire_dt if policy is not None else None

    def value_and_grad(params, batch, scale):
        """cast-params → forward → scaled loss (the backward runs against
        the scaled objective; callers unscale in f32)."""
        def lfn(p):
            p = policy.cast_to_param(p) if policy is not None else p
            loss = loss_fn(p, batch)
            return loss * scale if scaling else loss
        return jax.value_and_grad(lfn)(params)

    def sync_grads(params, batch, scale):
        return value_and_grad(params, batch, scale)

    def accum_buckets(params, batch, scale, fab, lay, play=None):
        """``lax.scan`` over the leading microbatch axis of ``batch``,
        accumulating per-microbatch gradients directly into flat f32
        buckets (padded shard layout when ``play`` is given).  The scan
        body issues NO collective; the boundary divides ONCE by
        ``accum_steps`` (and the loss scale) before the single exchange.
        Returns (mean_buckets, mean_scaled_loss)."""

        def micro(carry, mb):
            acc, loss_sum = carry
            loss, grads = value_and_grad(params, mb, scale)
            return (fab.accumulate(acc, grads, lay, play=play),
                    loss_sum + loss), None

        (acc, loss_sum), _ = lax.scan(
            micro, (fab.init_accum(lay, play), jnp.zeros((), jnp.float32)),
            batch)
        denom = accum_steps * (scale if scaling else 1.0)
        return [a / denom for a in acc], loss_sum / accum_steps

    def tree_accum_grads(params, batch, scale):
        """Tree-space microbatch accumulation for the strategy-over-
        ShardComm path (the strategy owns its own exchange; no bucket-
        fusion claim here).  Returns (mean_scaled_loss, mean_grads)."""

        def micro(carry, mb):
            acc, loss_sum = carry
            loss, grads = value_and_grad(params, mb, scale)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return (acc, loss_sum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (acc, loss_sum), _ = lax.scan(
            micro, (zeros, jnp.zeros((), jnp.float32)), batch)
        denom = accum_steps * (scale if scaling else 1.0)
        return (loss_sum / accum_steps,
                jax.tree.map(lambda a: a / denom, acc))

    def sync_fabric_accum_body(params, batch, scale):
        """Microbatched plain-sync boundary step: shard_map over the batch
        axes, scan-accumulate each shard's gradients into flat buckets,
        then ONE fused all-mean per bucket at the boundary — the HLO
        carries at most n_buckets cross-worker collectives per boundary
        regardless of accum_steps (proven in bench_roofline/check_accum
        and tests/test_accum.py).

        Like the ZeRO-1 production body above, the shard_map declares
        replicated (P()) param specs, so on the old-jax full-manual
        lowering (DESIGN.md §7) model-axis sharding is gathered at the
        body boundary — the same memory tradeoff the partition_grads path
        already makes; accum_steps=1 keeps the pjit auto-sharded path
        untouched."""
        from jax.sharding import PartitionSpec as P

        mesh = compat.get_abstract_mesh()
        sizes = dict(mesh.shape) if mesh is not None else {}
        axes = tuple(a for a in ("pod", "data") if a in sizes)
        if not axes:  # no batch axis to exchange over (single device)
            return tree_accum_grads(params, batch, scale)
        w = 1
        for a in axes:
            w *= sizes[a]
        axis_name = axes if len(axes) > 1 else axes[0]

        def per_shard(params, batch, scale):
            fab = Fabric(ShardComm(axis_name, w), bucket_bytes,
                         wire_dtype=wire)
            lay = fab.layout(params)
            acc, loss = accum_buckets(params, batch, scale, fab, lay)
            grads, _, _ = fab.exchange_accumulated(acc, lay)
            return jax.lax.pmean(loss, axis_name), grads

        batch_specs = jax.tree.map(lambda _: P(None, axes), batch)
        rep = jax.tree.map(lambda _: P(), params)
        return compat.shard_map(
            per_shard, mesh=mesh, axis_names=set(axes),
            in_specs=(rep, batch_specs, P()),
            out_specs=(P(), rep), check_vma=False,
        )(params, batch, scale)

    def pod_fabric_grads(params, batch, residual, scale):
        from jax.sharding import PartitionSpec as P

        mesh = compat.get_abstract_mesh()
        npods = dict(mesh.shape).get("pod", 1)

        def per_pod(params, batch, residual, scale):
            fab = Fabric(ShardComm("pod", npods), bucket_bytes,
                         wire_dtype=wire)
            if accum_steps == 1:
                loss, grads = value_and_grad(params, batch, scale)
                if scaling:
                    grads = PR.unscale_grads(grads, scale)
                grads, new_r, _ = fab.exchange(grads, residual,
                                               pod_compressor)
            else:
                # boundary-only compression: the error-feedback residual
                # sees ONE exchange of the microbatch-mean gradients
                lay = fab.layout(params)
                acc, loss = accum_buckets(params, batch, scale, fab, lay)
                grads, new_r, _ = fab.exchange_accumulated(
                    acc, lay, residual, pod_compressor)
            return jax.lax.pmean(loss, "pod"), grads, new_r

        bspec = P("pod") if accum_steps == 1 else P(None, "pod")
        batch_specs = jax.tree.map(lambda _: bspec, batch)
        rep = jax.tree.map(lambda _: P(), params)
        rep_r = jax.tree.map(lambda _: P(), residual)
        return compat.shard_map(
            per_pod, mesh=mesh, axis_names={"pod"},
            in_specs=(rep, batch_specs, rep_r, P()),
            out_specs=(P(), rep, rep_r), check_vma=False,
        )(params, batch, residual, scale)

    def zero1_step_body(params, batch, opt_state, t, scale):
        """shard_map body over "pod": grads → reduce-scatter → shard update
        → all-gather, one RS + one AG per bucket, NO full all-reduce of
        gradients (the loss mean is the only scalar psum).  Under a
        master-keeping policy the f32 master shards live in
        ``opt_state["master"]`` (1/W per device) and the all-gather ships
        the wire-dtype image of the updated master.  With ``accum_steps >
        1`` the scan accumulates straight into the PADDED shard-bucket
        layout, so the boundary reduce-scatter consumes the accumulator
        with no re-pad — still one RS + one AG per bucket per boundary.

        ``zero_stage=2`` changes ONLY the accumulation: each microbatch's
        gradients are reduce-scattered as they arrive and the accumulator
        holds 1/W shard buckets (the full gradient is never resident),
        trading accum_steps× the RS traffic for a W× smaller accumulator
        — the wire-vs-memory axis the launch planner searches."""
        from jax.sharding import PartitionSpec as P

        mesh = compat.get_abstract_mesh()
        npods = dict(mesh.shape).get("pod", 1)

        def per_pod(params, batch, opt_state, t, scale):
            fab = Fabric(ShardComm("pod", npods), bucket_bytes,
                         wire_dtype=wire)
            play = fab.partitioned_layout(params)
            if accum_steps == 1:
                loss, grads = value_and_grad(params, batch, scale)
                if scaling:
                    grads = PR.unscale_grads(grads, scale)
                g_shards, _ = fab.exchange_partitioned(grads, play)
            elif zero_stage >= 2:
                def micro(carry, mb):
                    acc, loss_sum = carry
                    loss, grads = value_and_grad(params, mb, scale)
                    acc, _ = fab.accumulate_partitioned(acc, grads, play)
                    return (acc, loss_sum + loss), None

                (acc, loss_sum), _ = lax.scan(
                    micro, (fab.init_accum_partitioned(play),
                            jnp.zeros((), jnp.float32)), batch)
                denom = accum_steps * (scale if scaling else 1.0)
                g_shards = [a / denom for a in acc]
                loss = loss_sum / accum_steps
            else:
                acc, loss = accum_buckets(params, batch, scale, fab,
                                          play.layout, play=play)
                g_shards, _ = fab.exchange_partitioned_accumulated(acc, play)
            # every pod must take the same skip decision: the finite check
            # runs on this pod's reduced shards, pmin'ed across pods
            ok = PR.tree_finite(g_shards).astype(jnp.float32) if scaling \
                else jnp.ones((), jnp.float32)
            ok = jax.lax.pmin(ok, "pod") if scaling else ok
            if keeps_master:
                inner, p_shards = opt_state["opt"], opt_state["master"]
            else:
                inner, p_shards = opt_state, fab.shard_params(params, play)
            p_shards, inner = optimizer.update(g_shards, inner, p_shards, t)
            new_params = fab.unpartition(p_shards, play)
            new_opt = {"opt": inner, "master": p_shards} if keeps_master \
                else inner
            return (jax.lax.pmean(loss, "pod"), new_params, new_opt, ok)

        bspec = P("pod") if accum_steps == 1 else P(None, "pod")
        batch_specs = jax.tree.map(lambda _: bspec, batch)
        rep = jax.tree.map(lambda _: P(), params)
        shard_specs = jax.tree.map(lambda _: P("pod"), opt_state)
        return compat.shard_map(
            per_pod, mesh=mesh, axis_names={"pod"},
            in_specs=(rep, batch_specs, shard_specs, P(), P()),
            out_specs=(P(), rep, shard_specs, P()), check_vma=False,
        )(params, batch, opt_state, t, scale)

    def zero3_step_body(p_shards, batch, opt_state, t, scale):
        """ZeRO-3 shard_map body over "pod": the train state holds ONLY
        flat f32 param shard buckets (``zero3_param_template``, sharded
        ``P("pod")`` — 1/W of the f32 model per device, doubling as the
        precision master) plus the matching shard-bucket optimizer state.
        Each boundary: per-bucket all-gather of the wire-dtype param image
        (``unpartition``) → forward/backward on the full model →
        reduce-scatter of the gradients → elementwise shard update.  The
        full parameter tree is a TEMPORARY of the step, never part of the
        state, so ``step_state_peak_bytes`` sheds the dense param term —
        the W× shrink the roofline's ``opt_state_bytes(partitioned=True)``
        already models for optimizer state, now applied to params too."""
        from jax.sharding import PartitionSpec as P

        mesh = compat.get_abstract_mesh()
        npods = dict(mesh.shape).get("pod", 1)
        play = PartitionedLayout.build(
            BucketLayout.build(param_template, bucket_bytes, lead_axes=0),
            npods)

        def per_pod(p_shards, batch, opt_state, t, scale):
            fab = Fabric(ShardComm("pod", npods), bucket_bytes,
                         wire_dtype=wire)
            params = fab.unpartition(p_shards, play)
            if accum_steps == 1:
                loss, grads = value_and_grad(params, batch, scale)
                if scaling:
                    grads = PR.unscale_grads(grads, scale)
                g_shards, _ = fab.exchange_partitioned(grads, play)
            else:
                def micro(carry, mb):
                    acc, loss_sum = carry
                    loss, grads = value_and_grad(params, mb, scale)
                    acc, _ = fab.accumulate_partitioned(acc, grads, play)
                    return (acc, loss_sum + loss), None

                (acc, loss_sum), _ = lax.scan(
                    micro, (fab.init_accum_partitioned(play),
                            jnp.zeros((), jnp.float32)), batch)
                denom = accum_steps * (scale if scaling else 1.0)
                g_shards = [a / denom for a in acc]
                loss = loss_sum / accum_steps
            ok = PR.tree_finite(g_shards).astype(jnp.float32) if scaling \
                else jnp.ones((), jnp.float32)
            ok = jax.lax.pmin(ok, "pod") if scaling else ok
            new_shards, new_opt = optimizer.update(g_shards, opt_state,
                                                   p_shards, t)
            return (jax.lax.pmean(loss, "pod"), new_shards, new_opt, ok)

        bspec = P("pod") if accum_steps == 1 else P(None, "pod")
        batch_specs = jax.tree.map(lambda _: bspec, batch)
        p_specs = jax.tree.map(lambda _: P("pod"), p_shards)
        o_specs = jax.tree.map(lambda _: P("pod"), opt_state)
        return compat.shard_map(
            per_pod, mesh=mesh, axis_names={"pod"},
            in_specs=(p_specs, batch_specs, o_specs, P(), P()),
            out_specs=(P(), p_specs, o_specs, P()), check_vma=False,
        )(p_shards, batch, opt_state, t, scale)

    def step(state, batch):
        sstate = state.get("loss_scale")
        scale = sstate["scale"] if scaling else jnp.ones((), jnp.float32)
        if partition_grads:
            body = zero3_step_body if zero_stage >= 3 else zero1_step_body
            loss, params, opt_state, ok = body(
                state["params"], batch, state["opt_state"], state["step"],
                scale)
            finite = ok > 0.5
            if scaling:  # skip-or-apply
                params = PR.select_tree(finite, params, state["params"])
                opt_state = PR.select_tree(finite, opt_state,
                                           state["opt_state"])
                loss = loss / scale
            new_state = {"params": params, "opt_state": opt_state,
                         "comm_state": state["comm_state"],
                         "step": state["step"] + 1}
            if scaling:
                new_state["loss_scale"] = PR.next_scale_state(
                    policy, sstate, finite)
            return new_state, loss
        # dense paths: the f32 master (when the policy keeps one) lives in
        # state["master"] and is the source of truth — forward casts it to
        # the param dtype inside value_and_grad, the optimizer/strategy
        # update runs on it in full precision, and state["params"] is its
        # param-dtype image
        src = state.get("master", state["params"])
        if pod_compressor is not None:
            loss, grads, new_res = pod_fabric_grads(
                src, batch, state["comm_state"]["residual"], scale)
            comm_state = {"residual": new_res}
        elif accum_steps > 1 and strategy is None:
            loss, grads = sync_fabric_accum_body(src, batch, scale)
            comm_state = state["comm_state"]
        elif accum_steps > 1:
            loss, grads = tree_accum_grads(src, batch, scale)
            comm_state = state["comm_state"]
        else:
            loss, grads = sync_grads(src, batch, scale)
            if scaling:
                grads = PR.unscale_grads(grads, scale)
            comm_state = state["comm_state"]
        finite = PR.tree_finite(grads) if scaling else jnp.asarray(True)
        if strategy is not None:
            new_src, opt_state, comm_state, _ = strategy.update(
                src, grads, state["opt_state"],
                comm_state, state["step"], optimizer, comm)
        else:
            new_src, opt_state = optimizer.update(
                grads, state["opt_state"], src, state["step"])
        if scaling:  # skip-or-apply
            new_src = PR.select_tree(finite, new_src, src)
            opt_state = PR.select_tree(finite, opt_state,
                                       state["opt_state"])
            comm_state = PR.select_tree(finite, comm_state,
                                        state["comm_state"])
            loss = loss / scale
        new_state = {"opt_state": opt_state, "comm_state": comm_state,
                     "step": state["step"] + 1}
        if "master" in state:
            new_state["master"] = new_src
            new_state["params"] = policy.cast_to_param(new_src)
        else:
            new_state["params"] = new_src
        if scaling:
            new_state["loss_scale"] = PR.next_scale_state(policy, sstate,
                                                          finite)
        return new_state, loss

    return step
