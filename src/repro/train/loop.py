"""Training loops.

Two entry points mirroring the Comm duality (DESIGN.md §3):

  * ``make_replica_train_step`` — the *strategy simulator*: W model replicas
    stacked on axis 0 (LocalComm layout), per-worker data shards, any
    spectrum strategy.  Runs on one device; used by tests, convergence
    benchmarks, and the examples.  This is the paper's experimental rig.

  * ``make_sharded_train_step`` — the production path: one global model,
    pjit-sharded over (pod, data, model); the strategy runs across the
    ``pod`` (or ``data``) axis via shard_map + ShardComm.  ``sync`` here is
    plain global data parallelism (the paper's point 1), which is also what
    the multi-pod dry-run lowers.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import jax_compat as compat
from repro.core.comm import Comm, LocalComm, ShardComm
from repro.core.fabric import DEFAULT_BUCKET_BYTES, Fabric
from repro.core.strategies import Strategy
from repro.models import transformer as T
from repro.optim.optimizers import Optimizer
from repro.train.losses import lm_loss


def init_train_state(params, optimizer: Optimizer, strategy: Strategy,
                     comm: Comm):
    return {
        "params": params,
        "opt_state": optimizer.init(params),
        "comm_state": strategy.init(params, comm),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# replica simulator (LocalComm stacked layout)
# ---------------------------------------------------------------------------
def make_replica_train_step(loss_fn, optimizer: Optimizer, strategy: Strategy,
                            comm: LocalComm, jit: bool = True):
    """loss_fn(params, batch) -> scalar, defined for ONE replica.

    The returned step takes stacked state (leading dim W on every leaf of
    params/opt_state) and per-worker batches (leading dim W)."""

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    def step(state, batches):
        loss, grads = grad_fn(state["params"], batches)
        params, opt_state, comm_state, metrics = strategy.update(
            state["params"], grads, state["opt_state"], state["comm_state"],
            state["step"], optimizer, comm)
        new_state = {"params": params, "opt_state": opt_state,
                     "comm_state": comm_state, "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics["loss"] = jnp.mean(loss)
        metrics["replica_divergence"] = _stack_divergence(params)
        return new_state, metrics

    return jax.jit(step) if jit else step


def _stack_divergence(params):
    """Max |w_i − w_0| over replicas — the model-consistency measure of §3."""

    def per_leaf(x):
        return jnp.max(jnp.abs(x - x[0:1])) if x.ndim > 0 and x.shape[0] > 1 \
            else jnp.zeros((), x.dtype)

    leaves = [per_leaf(x).astype(jnp.float32) for x in jax.tree.leaves(params)]
    return jnp.max(jnp.stack(leaves)) if leaves else jnp.zeros(())


# ---------------------------------------------------------------------------
# production (sharded) train step — also the dry-run target
# ---------------------------------------------------------------------------
def make_loss_fn(cfg, remat: bool = True):
    def loss_fn(params, batch):
        memory = None
        if cfg.is_encoder_decoder:
            memory = T.encode(params, cfg, embeds=batch["source_embeds"])
        logits, aux = T.forward(
            params, cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            memory=memory,
            remat=remat)
        return lm_loss(logits, batch["labels"], aux)
    return loss_fn


def make_sharded_train_step(cfg, optimizer: Optimizer,
                            strategy: Optional[Strategy] = None,
                            comm: Optional[Comm] = None,
                            remat: bool = True,
                            pod_compressor=None,
                            bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Global-model train step.  With ``strategy=None`` this is pure
    synchronous data parallelism (gradients all-reduced by XLA across the
    batch sharding) — the paper's spectrum point 1 and the dry-run target.
    With a strategy + ShardComm, the gradient transform runs across the
    named axis (used by the hierarchical pod-level strategies).

    ``pod_compressor``: the paper's §2.2.4 technique as a first-class
    production feature — gradients are synced *completely* inside each pod
    (fast ICI, spectrum pt. 1) but the CROSS-POD hop (slow DCN, the paper's
    loosely-coupled tier) ships the COMPRESSED payload.  The exchange is
    the bucketed ``Fabric`` (core/fabric.py): per-pod gradients are
    flattened into flat f32 buckets, 1-bit/int8/top-k encoded with error
    feedback, and ONE packed byte buffer per bucket is all-gathered over
    "pod" — at most n_buckets collectives in the lowered HLO where the old
    per-leaf path emitted one (or more) per parameter."""

    loss_fn = make_loss_fn(cfg, remat=remat)

    def sync_grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def pod_fabric_grads(params, batch, residual):
        from jax.sharding import PartitionSpec as P

        mesh = compat.get_abstract_mesh()
        npods = dict(mesh.shape).get("pod", 1)

        def per_pod(params, batch, residual):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            fab = Fabric(ShardComm("pod", npods), bucket_bytes)
            grads, new_r, _ = fab.exchange(grads, residual, pod_compressor)
            return jax.lax.pmean(loss, "pod"), grads, new_r

        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        rep = jax.tree.map(lambda _: P(), params)
        rep_r = jax.tree.map(lambda _: P(), residual)
        return compat.shard_map(
            per_pod, mesh=mesh, axis_names={"pod"},
            in_specs=(rep, batch_specs, rep_r),
            out_specs=(P(), rep, rep_r), check_vma=False,
        )(params, batch, residual)

    def step(state, batch):
        if pod_compressor is not None:
            loss, grads, new_res = pod_fabric_grads(
                state["params"], batch, state["comm_state"]["residual"])
            comm_state = {"residual": new_res}
        else:
            loss, grads = sync_grads(state["params"], batch)
            comm_state = state["comm_state"]
        if strategy is not None:
            params, opt_state, comm_state, _ = strategy.update(
                state["params"], grads, state["opt_state"],
                comm_state, state["step"], optimizer, comm)
        else:
            params, opt_state = optimizer.update(
                grads, state["opt_state"], state["params"], state["step"])
        return ({"params": params, "opt_state": opt_state,
                 "comm_state": comm_state, "step": state["step"] + 1}, loss)

    return step
