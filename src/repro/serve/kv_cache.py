"""Paged KV cache memory management (serving tier, DESIGN.md §10).

The device side is a pool of fixed-size token pages per layer
(``models/transformer.init_paged_cache``); this module is the HOST side:
a free-list block allocator and the per-slot block tables that map each
sequence's logical pages to physical ones.

Design points (vLLM-style):
  * Physical page 0 is RESERVED as the trash page.  Idle/padded lanes in
    a batched step write their (garbage) KV there, so no live table ever
    references it and admission never has to zero the cache — recycling
    a block is a free-list push, not a ``tree.map`` over the pool.
  * Allocation is all-or-nothing: a request either gets every page it
    asked for or none, so a failed admission/growth leaves no partial
    state to unwind.
  * The free list is LIFO — recently released pages are re-used first
    (warm in cache, and keeps the allocated set compact).
"""

from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

TRASH_PAGE = 0


class BlockAllocator:
    """Free-list allocator over physical pages 1..num_pages-1 (page 0 is
    the reserved trash page and is never handed out)."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._allocated: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._allocated)

    def blocks_for(self, num_tokens: int) -> int:
        return max(0, math.ceil(num_tokens / self.page_size))

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n pages off the free list — all-or-nothing: returns None
        (and allocates nothing) if fewer than n are free."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._allocated.update(got)
        return got

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._allocated:
                raise ValueError(f"double-free or foreign page {p}")
            self._allocated.remove(p)
            self._free.append(p)

    def utilization(self) -> float:
        usable = self.num_pages - 1
        return self.num_allocated / usable if usable else 0.0

    def check(self) -> None:
        """Invariant: free ∪ allocated partitions pages 1..num_pages-1."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate pages on the free list")
        if free & self._allocated:
            raise AssertionError("page both free and allocated")
        if free | self._allocated != set(range(1, self.num_pages)):
            raise AssertionError("page leak: free+allocated != all pages")
        if TRASH_PAGE in free or TRASH_PAGE in self._allocated:
            raise AssertionError("trash page 0 entered circulation")


class PagedKVCache:
    """Per-slot block tables over a :class:`BlockAllocator`.

    ``tables`` is the (num_slots, pages_per_seq) int32 array handed to the
    model's paged attention each step; unallocated entries stay at the
    trash page.  ``owned[slot]`` tracks the slot's physical pages in
    logical order so release/growth are O(pages)."""

    def __init__(self, num_slots: int, pages_per_seq: int,
                 allocator: BlockAllocator):
        self.allocator = allocator
        self.pages_per_seq = pages_per_seq
        self.tables = np.full((num_slots, pages_per_seq), TRASH_PAGE,
                              np.int32)
        self.owned: List[List[int]] = [[] for _ in range(num_slots)]

    def admit(self, slot: int, num_tokens: int) -> bool:
        """Allocate pages covering ``num_tokens`` for an empty slot."""
        assert not self.owned[slot], "admit into a non-empty slot"
        need = self.allocator.blocks_for(num_tokens)
        got = self.allocator.alloc(need)
        if got is None:
            return False
        self.owned[slot] = got
        self.tables[slot, :len(got)] = got
        return True

    def ensure(self, slot: int, num_tokens: int) -> bool:
        """Grow the slot to cover ``num_tokens`` total tokens (no-op when
        already covered).  All-or-nothing; False ⇒ caller must evict."""
        need = self.allocator.blocks_for(num_tokens) - len(self.owned[slot])
        if need <= 0:
            return True
        got = self.allocator.alloc(need)
        if got is None:
            return False
        start = len(self.owned[slot])
        self.owned[slot].extend(got)
        self.tables[slot, start:start + len(got)] = got
        return True

    def release(self, slot: int) -> None:
        """Return the slot's pages to the free list; its table row points
        back at the trash page (no cache zeroing — stale page contents
        are unreachable once no table references them)."""
        if self.owned[slot]:
            self.allocator.free(self.owned[slot])
            self.owned[slot] = []
        self.tables[slot, :] = TRASH_PAGE

    def utilization(self) -> float:
        return self.allocator.utilization()
