"""Batched decode engine with TRUE continuous batching.

Every slot carries its own position (ragged (B,) write positions in the
cache — models/layers.py decode path): a freed slot is refilled from the
queue immediately and ingests its prompt token-by-token while neighbouring
slots keep generating.  One jitted decode step serves both phases.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (Lp,) int32
    max_new_tokens: int
    generated: list = field(default_factory=list)
    done: bool = False
    truncated: bool = False  # prompt tail-clipped to the engine's max_seq
    preempted: bool = False  # evicted in-flight by run(max_steps=...)


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, batch_slots: int,
                 max_seq: int, memory=None, pad_token: int = 0,
                 cache_dtype=None):
        """``cache_dtype``: dtype of the KV/activation decode cache
        (defaults to ``cfg.compute_dtype``) — a bf16 cache halves the
        dominant decode-memory term.  Recurrent state leaves (mamba/xlstm)
        stay f32 regardless (models/ssm.py precision contract)."""
        self.params = params
        self.cfg = cfg
        self.b = batch_slots
        self.max_seq = max_seq
        self.memory = memory
        self.pad = pad_token
        self.cache_dtype = jnp.dtype(cache_dtype if cache_dtype is not None
                                     else cfg.compute_dtype)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.steps = 0
        self.cache = T.init_cache(cfg, batch_slots, max_seq,
                                  dtype=self.cache_dtype)
        self.pos = np.zeros(batch_slots, np.int32)  # per-slot write position
        self.slot: List[Optional[Request]] = [None] * batch_slots
        self.phase = ["idle"] * batch_slots  # idle | prompt | decode
        self.prompt_cursor = np.zeros(batch_slots, np.int32)
        self._next_tok = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(
            lambda p, tok, pos, cache: T.decode_step(
                p, cfg, token=tok, pos=pos, cache=cache, memory=self.memory))

    def submit(self, req: Request):
        """Cache positions run 0..max_seq-1; an over-long prompt would keep
        a slot in the prompt phase past the decode-phase termination check
        and write past the cache.  Keep the TAIL (the context that matters
        for continuation), leaving room for ≥ 1 generated token — recorded
        on the request via ``truncated=True``."""
        # max(1, ·): at max_seq == 1 a -0 slice would keep the WHOLE
        # prompt; keep one token and let the cache-full check finish the
        # slot after its single generated token
        limit = max(1, self.max_seq - 1)
        if len(req.prompt) == 0:
            # nothing to condition on and no first token to feed _admit
            # (req.prompt[0] would raise): complete immediately with an
            # empty generation instead of crashing the whole batch
            req.done = True
            self.finished.append(req)
            return
        if len(req.prompt) > limit:
            req.prompt = np.asarray(req.prompt[-limit:])
            req.truncated = True
        self.queue.append(req)

    def _reset_slot(self, i: int):
        """Zero slot i across the cache: the causal mask hides stale KV, but
        recurrent state (mamba/xlstm) genuinely carries over and must clear."""
        self.cache = jax.tree.map(
            lambda x: x.at[:, i].set(0) if hasattr(x, "ndim") and x.ndim >= 2
            else x, self.cache)

    def _admit(self):
        for i in range(self.b):
            if self.phase[i] == "idle" and self.queue:
                req = self.queue.pop(0)
                self.slot[i] = req
                self.phase[i] = "prompt"
                self.prompt_cursor[i] = 0
                self.pos[i] = 0
                self._reset_slot(i)
                self._next_tok[i] = req.prompt[0]

    def step(self):
        self._admit()
        if all(p == "idle" for p in self.phase):
            return
        toks = np.where(np.array([p != "idle" for p in self.phase]),
                        self._next_tok, self.pad).astype(np.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), jnp.asarray(self.pos), self.cache)
        argmax = np.asarray(jnp.argmax(logits, -1), np.int32)
        self.steps += 1
        for i in range(self.b):
            req = self.slot[i]
            if req is None:
                continue
            self.pos[i] += 1
            if self.phase[i] == "prompt":
                self.prompt_cursor[i] += 1
                if self.prompt_cursor[i] < len(req.prompt):
                    self._next_tok[i] = req.prompt[self.prompt_cursor[i]]
                else:  # prompt consumed: this step produced the first token
                    req.generated.append(int(argmax[i]))
                    self._next_tok[i] = argmax[i]
                    self.phase[i] = "decode"
            else:
                req.generated.append(int(argmax[i]))
                self._next_tok[i] = argmax[i]
            # Termination: decode slots finish at max_new_tokens; ANY slot
            # (prompt phase included — belt over the submit-time truncation)
            # finishes when the cache is full, so pos never passes max_seq.
            if (self.phase[i] == "decode"
                    and len(req.generated) >= req.max_new_tokens) \
                    or self.pos[i] >= self.max_seq:
                req.done = True
                self.finished.append(req)
                self.slot[i] = None
                self.phase[i] = "idle"

    def run(self, max_steps: int = 100_000) -> List[Request]:
        """Serve until the queue and all slots drain, or ``max_steps``
        decode steps have run.  On early exit every in-flight slot is
        DRAINED, not dropped: its request lands in ``finished`` with
        ``preempted=True`` / ``done=False`` and whatever partial
        generation it accumulated; the slot is freed so the engine stays
        usable for fresh submissions."""
        while (self.queue or any(p != "idle" for p in self.phase)) \
                and self.steps < max_steps:
            self.step()
        for i in range(self.b):
            req = self.slot[i]
            if req is not None:
                req.preempted = True
                self.finished.append(req)
                self.slot[i] = None
                self.phase[i] = "idle"
        return self.finished


def greedy_generate(params, cfg: ModelConfig, prompt, max_new_tokens: int,
                    memory=None):
    """Reference single-sequence generation: prefill + greedy decode."""
    prompt = jnp.asarray(prompt)[None]  # (1, Lp)
    lp = prompt.shape[1]
    total = lp + max_new_tokens
    logits, cache = T.prefill(params, cfg, tokens=prompt, memory=memory,
                              last_only=True)

    def pad(x):  # prefill cache has S=lp for attention layers: grow to total
        if x.ndim >= 3 and x.shape[2] == lp:
            w = [(0, 0)] * x.ndim
            w[2] = (0, total - lp)
            return jnp.pad(x, w)
        return x

    cache = jax.tree.map(pad, cache)
    tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits, -1)
    out = [int(tok[0])]
    decode = jax.jit(lambda p, t, pos, c: T.decode_step(
        p, cfg, token=t, pos=pos, cache=c, memory=memory))
    for i in range(max_new_tokens - 1):
        logits, cache = decode(params, tok.astype(jnp.int32),
                               jnp.int32(lp + i), cache)
        tok = jnp.argmax(logits, -1)
        out.append(int(tok[0]))
    return out
