"""Batched decode engines with TRUE continuous batching.

``DecodeEngine`` (dense): every slot carries its own position (ragged
(B,) write positions in the cache — models/layers.py decode path); a
freed slot is refilled from the queue immediately and ingests its prompt
token-by-token while neighbouring slots keep generating.  One jitted
decode step serves both phases.  Works for EVERY stack, including
recurrent mixers (mamba/xlstm).

``PagedDecodeEngine`` (serving production path, DESIGN.md §10): the KV
cache is a pool of fixed-size token pages (serve/kv_cache.py) instead of
a dense (B, max_seq) arena — memory follows LIVE context, admission is
gated on free pages (evict-to-queue on exhaustion), and prefill is
CHUNKED: whole (B, chunk) prompt windows per step instead of one token
per slot per step.  Attention-only decoder stacks.
"""

from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serve.kv_cache import BlockAllocator, PagedKVCache


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (Lp,) int32
    max_new_tokens: int
    generated: list = field(default_factory=list)
    done: bool = False
    truncated: bool = False  # prompt tail-clipped to the engine's max_seq
    preempted: bool = False  # evicted in-flight by run(max_steps=...)
    evictions: int = 0       # times evicted-to-queue under memory pressure
    t_submit: float = 0.0    # perf_counter stamps for the serving bench
    token_times: list = field(default_factory=list)


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, batch_slots: int,
                 max_seq: int, memory=None, pad_token: int = 0,
                 cache_dtype=None):
        """``cache_dtype``: dtype of the KV/activation decode cache
        (defaults to ``cfg.compute_dtype``) — a bf16 cache halves the
        dominant decode-memory term.  Recurrent state leaves (mamba/xlstm)
        stay f32 regardless (models/ssm.py precision contract)."""
        self.params = params
        self.cfg = cfg
        self.b = batch_slots
        self.max_seq = max_seq
        self.memory = memory
        self.pad = pad_token
        self.cache_dtype = jnp.dtype(cache_dtype if cache_dtype is not None
                                     else cfg.compute_dtype)
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.steps = 0
        self.cache = T.init_cache(cfg, batch_slots, max_seq,
                                  dtype=self.cache_dtype)
        self.pos = np.zeros(batch_slots, np.int32)  # per-slot write position
        self.slot: List[Optional[Request]] = [None] * batch_slots
        self.phase = ["idle"] * batch_slots  # idle | prompt | decode
        self.prompt_cursor = np.zeros(batch_slots, np.int32)
        self._next_tok = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(
            lambda p, tok, pos, cache: T.decode_step(
                p, cfg, token=tok, pos=pos, cache=cache, memory=self.memory))
        specs, _ = cfg.superblock()
        # only recurrent mixers need a per-admission state reset: attention
        # slots are hidden by the causal mask (every j <= pos is rewritten
        # by the new request before it is read), but mamba/xlstm state
        # genuinely carries over
        self._recurrent = [str(i) for i, s in enumerate(specs)
                           if s.mixer not in ("attn", "none")]

    def submit(self, req: Request):
        """Cache positions run 0..max_seq-1; an over-long prompt would keep
        a slot in the prompt phase past the decode-phase termination check
        and write past the cache.  Keep the TAIL (the context that matters
        for continuation), leaving room for ≥ 1 generated token — recorded
        on the request via ``truncated=True``."""
        # max(1, ·): at max_seq == 1 a -0 slice would keep the WHOLE
        # prompt; keep one token and let the cache-full check finish the
        # slot after its single generated token
        limit = max(1, self.max_seq - 1)
        if len(req.prompt) == 0:
            # nothing to condition on and no first token to feed _admit
            # (req.prompt[0] would raise): complete immediately with an
            # empty generation instead of crashing the whole batch
            req.done = True
            self.finished.append(req)
            return
        if len(req.prompt) > limit:
            req.prompt = np.asarray(req.prompt[-limit:])
            req.truncated = True
        self.queue.append(req)

    def _reset_slot(self, i: int):
        """Targeted reset: zero slot i of RECURRENT state leaves only.
        Attention KV needs no reset — the causal mask hides stale entries
        (every j <= pos is rewritten by the new request before it is
        read), so all-attention stacks skip the tree.map entirely."""
        for li in self._recurrent:
            self.cache[li] = jax.tree.map(
                lambda x: x.at[:, i].set(0), self.cache[li])

    def _admit(self):
        for i in range(self.b):
            if self.phase[i] == "idle" and self.queue:
                req = self.queue.popleft()
                self.slot[i] = req
                self.phase[i] = "prompt"
                self.prompt_cursor[i] = 0
                self.pos[i] = 0
                self._reset_slot(i)
                self._next_tok[i] = req.prompt[0]

    def step(self):
        self._admit()
        if all(p == "idle" for p in self.phase):
            return
        toks = np.where(np.array([p != "idle" for p in self.phase]),
                        self._next_tok, self.pad).astype(np.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), jnp.asarray(self.pos), self.cache)
        argmax = np.asarray(jnp.argmax(logits, -1), np.int32)
        self.steps += 1
        now = time.perf_counter()
        for i in range(self.b):
            req = self.slot[i]
            if req is None:
                continue
            self.pos[i] += 1
            if self.phase[i] == "prompt":
                self.prompt_cursor[i] += 1
                if self.prompt_cursor[i] < len(req.prompt):
                    self._next_tok[i] = req.prompt[self.prompt_cursor[i]]
                else:  # prompt consumed: this step produced the first token
                    req.generated.append(int(argmax[i]))
                    req.token_times.append(now)
                    self._next_tok[i] = argmax[i]
                    self.phase[i] = "decode"
            else:
                req.generated.append(int(argmax[i]))
                req.token_times.append(now)
                self._next_tok[i] = argmax[i]
            # Termination: decode slots finish at max_new_tokens; ANY slot
            # (prompt phase included — belt over the submit-time truncation)
            # finishes when the cache is full, so pos never passes max_seq.
            if (self.phase[i] == "decode"
                    and len(req.generated) >= req.max_new_tokens) \
                    or self.pos[i] >= self.max_seq:
                req.done = True
                self.finished.append(req)
                self.slot[i] = None
                self.phase[i] = "idle"

    def run(self, max_steps: int = 100_000) -> List[Request]:
        """Serve until the queue and all slots drain, or ``max_steps``
        decode steps have run.  On early exit every in-flight slot is
        DRAINED, not dropped: its request lands in ``finished`` with
        ``preempted=True`` / ``done=False`` and whatever partial
        generation it accumulated; the slot is freed so the engine stays
        usable for fresh submissions."""
        while (self.queue or any(p != "idle" for p in self.phase)) \
                and self.steps < max_steps:
            self.step()
        for i in range(self.b):
            req = self.slot[i]
            if req is not None:
                req.preempted = True
                self.finished.append(req)
                self.slot[i] = None
                self.phase[i] = "idle"
        return self.finished


class PagedDecodeEngine:
    """Continuous-batching engine over a PAGED KV cache (DESIGN.md §10).

    Differences from the dense ``DecodeEngine``:
      * Memory follows live context: the cache is a pool of fixed-size
        token pages; a slot owns only the pages its sequence has reached,
        and releasing a finished request is a free-list push — no cache
        zeroing at admission (stale pages are unreachable once no block
        table references them).
      * CHUNKED PREFILL: prompts are ingested ``chunk_size`` tokens per
        step through one batched call (write-then-attend, so in-chunk
        causality needs no dense pass) instead of one token per step.
      * Admission is gated on free pages, FIFO with head-of-line blocking
        (no overtaking ⇒ same admission order as the dense engine).  On
        page exhaustion during decode growth, the youngest-admitted slot
        is EVICTED back to the queue front recompute-style: greedy decode
        is deterministic, so the re-run reproduces the same tokens and
        the engine degrades gracefully instead of over-allocating.
      * ``use_kernel`` routes decode attention through the Pallas paged
        kernel (default: on TPU/GPU backends; the interpret-mode kernel
        is correct everywhere but slow, so CPU defaults to the jnp gather
        path — same policy as kernels/ops.default_interpret).  int8
        pages always take the gather path (quantized via scale pools).

    Attention-only decoder stacks (recurrent mixers keep dense per-slot
    state — use ``DecodeEngine``).
    """

    def __init__(self, params, cfg: ModelConfig, batch_slots: int,
                 max_seq: int, *, page_size: int = 16,
                 num_pages: Optional[int] = None, chunk_size: int = 32,
                 pad_token: int = 0, cache_dtype=None, use_kernel=None):
        self.params = params
        self.cfg = cfg
        self.b = batch_slots
        self.max_seq = max_seq
        self.pad = pad_token
        self.page_size = page_size
        self.chunk = chunk_size
        self.cache_dtype = jnp.dtype(cache_dtype if cache_dtype is not None
                                     else cfg.compute_dtype)
        self.pages_per_seq = math.ceil(max_seq / page_size)
        if num_pages is None:  # fully provisioned: every slot can hit max_seq
            num_pages = 1 + batch_slots * self.pages_per_seq
        self.kv = PagedKVCache(batch_slots, self.pages_per_seq,
                               BlockAllocator(num_pages, page_size))
        self.cache = T.init_paged_cache(cfg, num_pages, page_size,
                                        dtype=self.cache_dtype)
        if use_kernel is None:
            use_kernel = jax.default_backend() in ("tpu", "gpu")
        if self.cache_dtype == jnp.int8:
            use_kernel = False  # kernel reads f32/bf16 pages only
        self.use_kernel = bool(use_kernel)

        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.steps = 0
        self.slot: List[Optional[Request]] = [None] * batch_slots
        self.phase = ["idle"] * batch_slots  # idle | prefill | decode
        self.pos = np.zeros(batch_slots, np.int32)  # next write position
        self.prompt_cursor = np.zeros(batch_slots, np.int32)
        self._next_tok = np.zeros(batch_slots, np.int32)
        self._admit_seq = np.zeros(batch_slots, np.int64)
        self._admitted = 0

        uk = self.use_kernel
        self._decode = jax.jit(
            lambda p, tok, pos, cache, bt: T.decode_step_paged(
                p, cfg, tok, pos, cache, bt, use_kernel=uk))
        self._prefill = jax.jit(
            lambda p, tk, ps, cache, bt, last: T.prefill_chunk_paged(
                p, cfg, tk, ps, cache, bt, last))

    # ------------------------------------------------------------------
    # admission / eviction
    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Same contract as DecodeEngine.submit (tail truncation, empty
        prompt completes immediately)."""
        limit = max(1, self.max_seq - 1)
        req.t_submit = time.perf_counter()
        if len(req.prompt) == 0:
            req.done = True
            self.finished.append(req)
            return
        if len(req.prompt) > limit:
            req.prompt = np.asarray(req.prompt[-limit:])
            req.truncated = True
        self.queue.append(req)

    def _admit(self):
        """FIFO with head-of-line blocking on free pages: if the queue
        head does not fit, nothing is admitted this step — no small-
        request overtaking, so admission order matches the dense engine."""
        for i in range(self.b):
            if not self.queue:
                return
            if self.phase[i] != "idle":
                continue
            req = self.queue[0]
            # reserve prompt + first generated token so the prefill →
            # decode transition never needs an immediate grow
            if not self.kv.admit(i, min(len(req.prompt) + 1, self.max_seq)):
                return
            self.queue.popleft()
            self.slot[i] = req
            self.phase[i] = "prefill"
            self.prompt_cursor[i] = 0
            self.pos[i] = 0
            self._admitted += 1
            self._admit_seq[i] = self._admitted

    def _evict(self, i: int):
        """Evict slot i back to the queue FRONT, recompute-style: greedy
        decode is deterministic, so re-running the request reproduces the
        exact same tokens — eviction changes latency, never output."""
        req = self.slot[i]
        req.generated = []
        req.token_times = []
        req.evictions += 1
        self.kv.release(i)
        self.slot[i] = None
        self.phase[i] = "idle"
        self.queue.appendleft(req)

    def _evict_youngest(self, exclude=None) -> bool:
        cands = [i for i in range(self.b)
                 if self.slot[i] is not None and i != exclude]
        if not cands:
            return False
        self._evict(max(cands, key=lambda i: self._admit_seq[i]))
        return True

    def _finish(self, i: int, *, preempted=False):
        req = self.slot[i]
        req.done = not preempted
        req.preempted = preempted
        self.kv.release(i)
        self.finished.append(req)
        self.slot[i] = None
        self.phase[i] = "idle"

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def step(self):
        self._admit()
        if all(p == "idle" for p in self.phase):
            return
        self.steps += 1
        self._step_prefill()
        self._step_decode()

    def _step_prefill(self):
        rows = [i for i in range(self.b) if self.phase[i] == "prefill"]
        if not rows:
            return
        c = self.chunk
        toks = np.zeros((self.b, c), np.int32)
        poss = np.full((self.b, c), -1, np.int32)
        last = np.zeros((self.b,), np.int32)
        take = {}
        for i in rows:
            req = self.slot[i]
            cur = int(self.prompt_cursor[i])
            n = min(c, len(req.prompt) - cur)
            toks[i, :n] = req.prompt[cur:cur + n]
            poss[i, :n] = np.arange(cur, cur + n, dtype=np.int32)
            last[i] = n - 1
            take[i] = n
        logits, self.cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(poss), self.cache,
            jnp.asarray(self.kv.tables), jnp.asarray(last))
        argmax = np.asarray(jnp.argmax(logits, -1), np.int32)
        now = time.perf_counter()
        for i in rows:
            req = self.slot[i]
            self.prompt_cursor[i] += take[i]
            self.pos[i] += take[i]
            if self.prompt_cursor[i] >= len(req.prompt):
                # this chunk held the last prompt token ⇒ its logits are
                # the first generated token (same contract as the dense
                # engine's prompt-consumed step)
                req.generated.append(int(argmax[i]))
                req.token_times.append(now)
                self._next_tok[i] = argmax[i]
                self.phase[i] = "decode"
                if len(req.generated) >= req.max_new_tokens \
                        or self.pos[i] >= self.max_seq:
                    self._finish(i)

    def _step_decode(self):
        # grow each decode row to cover this step's write; on exhaustion
        # evict the youngest-admitted slot (possibly this one) to queue
        for i in range(self.b):
            if self.phase[i] != "decode":
                continue
            while not self.kv.ensure(i, int(self.pos[i]) + 1):
                if not self._evict_youngest(exclude=i):
                    self._evict(i)
                    break
        rows = [i for i in range(self.b) if self.phase[i] == "decode"]
        if not rows:
            return
        active = np.array([self.phase[i] == "decode" for i in range(self.b)])
        toks = np.where(active, self._next_tok, self.pad).astype(np.int32)
        pos = np.where(active, self.pos, -1).astype(np.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), jnp.asarray(pos), self.cache,
            jnp.asarray(self.kv.tables))
        argmax = np.asarray(jnp.argmax(logits, -1), np.int32)
        now = time.perf_counter()
        for i in rows:
            req = self.slot[i]
            self.pos[i] += 1
            req.generated.append(int(argmax[i]))
            req.token_times.append(now)
            self._next_tok[i] = argmax[i]
            if len(req.generated) >= req.max_new_tokens \
                    or self.pos[i] >= self.max_seq:
                self._finish(i)

    def run(self, max_steps: int = 100_000) -> List[Request]:
        """Serve until queue + slots drain or ``max_steps``.  Early-exit
        drains in-flight requests as ``preempted=True`` AND releases
        their pages (allocator invariants hold after a drain)."""
        while (self.queue or any(p != "idle" for p in self.phase)) \
                and self.steps < max_steps:
            self.step()
        for i in range(self.b):
            if self.slot[i] is not None:
                self._finish(i, preempted=True)
        return self.finished

    def utilization(self) -> float:
        return self.kv.utilization()


def greedy_generate(params, cfg: ModelConfig, prompt, max_new_tokens: int,
                    memory=None):
    """Reference single-sequence generation: prefill + greedy decode."""
    prompt = jnp.asarray(prompt)[None]  # (1, Lp)
    lp = prompt.shape[1]
    total = lp + max_new_tokens
    logits, cache = T.prefill(params, cfg, tokens=prompt, memory=memory,
                              last_only=True)
    # grow attention layers' S=lp cache to `total` — keyed off the cache
    # layout (layer specs), not `shape[2] == lp` coincidence, which used
    # to mis-pad recurrent leaves whose dims happened to equal lp
    cache = T.pad_prefill_cache(cfg, cache, total)
    tok = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits, -1)
    out = [int(tok[0])]
    decode = jax.jit(lambda p, t, pos, c: T.decode_step(
        p, cfg, token=t, pos=pos, cache=c, memory=memory))
    for i in range(max_new_tokens - 1):
        logits, cache = decode(params, tok.astype(jnp.int32),
                               jnp.int32(lp + i), cache)
        tok = jnp.argmax(logits, -1)
        out.append(int(tok[0]))
    return out
