"""Paged attention Pallas TPU kernel (decode over a block KV cache).

The serving tier (serve/kv_cache.py) stores KV in fixed-size token pages:
``k_pages/v_pages: (num_pages, page_size, KV, Dh)`` plus a per-sequence
``block_table: (B, pages_per_seq)`` mapping logical page j of sequence b
to a physical page id.  This kernel computes one decode step — q is a
single token per sequence — attending over that paged layout WITHOUT
gathering the pages into a dense (B, S, KV, Dh) cache first.

Mechanically it extends the ``flash_attention.py`` online-softmax
pattern: grid = (batch, kv_heads, pages_per_seq) with f32 accumulators
(acc, row-max m, row-sum l) in VMEM scratch persisting across the
trailing (innermost, sequential) page dimension.  The page indirection
rides ``pltpu.PrefetchScalarGridSpec``: the block table, context lengths
and sliding window arrive as scalar-prefetch operands, so each k/v
BlockSpec index map reads ``block_tables[b, j]`` and the pipeline DMAs
exactly the physical page the sequence needs — the canonical TPU paged
attention mechanism.  Dead pages (entirely past the context length, or
entirely left of the sliding window) are skipped via ``@pl.when``, so
decode compute is proportional to the LIVE context, not the allocated
maximum.

GQA queries come in grouped as (B, KV, G, Dh) — the G = H/KV query rows
of one kv head share its pages, giving the MXU a (G, page_size) matmul
per page.  Numerics follow the dense decode contract (models/layers.py
``_sdpa_decode``): logits, softmax and the accumulator are f32 whatever
the page dtype (f32/bf16); logit softcap, causal mask (j <= pos) and
sliding window (pos - j < w) are applied per element inside the page.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _paged_kernel(bt_ref, ctx_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page_size: int, scale: float,
                  softcap: Optional[float]):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = ctx_ref[b]          # tokens 0..ctx-1 are live
    pos = ctx - 1             # the query's position (token already written)
    w = win_ref[0]            # <= 0 ⇒ full attention
    start = j * page_size
    lo = jnp.where(w > 0, jnp.maximum(pos - w + 1, 0), 0)
    live = jnp.logical_and(start < ctx, start + page_size > lo)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (G, Dh)
        k = k_ref[0, :, 0].astype(jnp.float32)        # (page, Dh)
        v = v_ref[0, :, 0].astype(jnp.float32)        # (page, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        jj = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.logical_and(jj <= pos, jj >= lo)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                    window=None, softcap: Optional[float] = None,
                    interpret: Optional[bool] = None):
    """q: (B, KV, G, Dh) grouped queries (one decode token per sequence);
    k_pages/v_pages: (num_pages, page_size, KV, Dh); block_tables:
    (B, pages_per_seq) int32 physical page ids; ctx_lens: (B,) int32 live
    context length per sequence (query position + 1).  ``window`` is a
    traced scalar (sliding window in tokens, <= 0 ⇒ full attention) so
    per-layer windows can ride a ``lax.scan`` over the stack.  Returns
    (B, KV, G, Dh) in q.dtype.

    Unallocated block-table entries may point anywhere valid (the engine
    points them at the reserved trash page 0): pages past ``ctx_lens``
    are skipped, in-page tails are masked.
    """
    from repro.kernels.ops import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    b, kv, g, dh = q.shape
    n_pages, page_size, kv_p, dh_p = k_pages.shape
    assert (kv, dh) == (kv_p, dh_p), (q.shape, k_pages.shape)
    mb = block_tables.shape[1]

    win = jnp.full((1,), -1, jnp.int32) if window is None \
        else jnp.asarray(window, jnp.int32).reshape(1)
    bt = block_tables.astype(jnp.int32)
    ctx = ctx_lens.astype(jnp.int32)

    grid = (b, kv, mb)
    kernel = functools.partial(_paged_kernel, page_size=page_size,
                               scale=dh ** -0.5, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda b_, h_, j_, bt_, ctx_, win_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b_, h_, j_, bt_, ctx_, win_:
                         (bt_[b_, j_], 0, h_, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b_, h_, j_, bt_, ctx_, win_:
                         (bt_[b_, j_], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, dh),
            lambda b_, h_, j_, bt_, ctx_, win_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),   # acc
            pltpu.VMEM((g, 1), jnp.float32),    # running max m
            pltpu.VMEM((g, 1), jnp.float32),    # running sum l
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), q.dtype),
        interpret=interpret,
    )(bt, ctx, win, q, k_pages, v_pages)
