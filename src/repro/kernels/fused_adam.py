"""Fused Adam update Pallas kernel.

One VMEM pass per tile updates (p, m, v) in place of the 10+ elementwise
HLO ops of the unfused optimizer — the optimizer is HBM-bandwidth-bound,
so fusing the read-modify-write chain is the whole win.  Bias correction
factors are precomputed on the host side of the call (scalar prefetch).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adam_kernel(c_ref, p_ref, g_ref, m_ref, v_ref,
                 p_out, m_out, v_out, *, b1, b2, eps):
    lr, bc1, bc2 = c_ref[0], c_ref[1], c_ref[2]
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...] + (1 - b1) * g
    v = b2 * v_ref[...] + (1 - b2) * g * g
    mh = m / bc1
    vh = v / bc2
    p = p_ref[...].astype(jnp.float32) - lr * mh / (jnp.sqrt(vh) + eps)
    p_out[...] = p.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


@functools.partial(jax.jit,
                   static_argnames=("b1", "b2", "eps", "block", "interpret"))
def fused_adam(p, g, m, v, lr, t, b1=0.9, b2=0.999, eps=1e-8,
               block: int = 4096, interpret: Optional[bool] = None):
    """p,g,m,v: (N,) flat; lr scalar; t: 1-based step. → (p', m', v')."""
    from repro.kernels.ops import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    n = p.shape[0]
    pad = (-n) % block
    if pad:
        p, g, m, v = (jnp.pad(a, (0, pad)) for a in (p, g, m, v))
    npad = n + pad
    tt = jnp.asarray(t, jnp.float32)
    consts = jnp.stack([jnp.asarray(lr, jnp.float32),
                        1.0 - b1 ** tt, 1.0 - b2 ** tt])
    grid = (npad // block,)
    kernel = functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps)
    p1, m1, v1 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), p.dtype),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=interpret,
    )(consts, p, g, m, v)
    return p1[:n], m1[:n], v1[:n]
