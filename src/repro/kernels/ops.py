"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else they execute in
interpret mode (the kernel body runs in Python on CPU) — numerically
identical, validated against ``ref.py`` in tests/test_kernels_*.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_adam import fused_adam as _adam
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.onebit_quant import onebit_quant as _onebit
from repro.kernels.topk_sparsify import topk_sparsify as _topk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=-1,
                    block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=_interpret())


def topk_sparsify(x, k, rows_per_step=8):
    return _topk(x, k, rows_per_step=rows_per_step, interpret=_interpret())


def onebit_quant(g, r, rows_per_step=8):
    return _onebit(g, r, rows_per_step=rows_per_step, interpret=_interpret())


def fused_adam(p, g, m, v, lr, t, **kw):
    return _adam(p, g, m, v, lr, t, interpret=_interpret(), **kw)


def mamba_scan(u, delta, a, b, c, d_skip, d_block=128):
    return _mamba(u, delta, a, b, c, d_skip, d_block=d_block,
                  interpret=_interpret())
