"""jit'd dispatch wrappers for the Pallas kernels.

On an accelerator backend (TPU/GPU) the kernels compile natively;
everywhere else they execute in interpret mode (the kernel body runs in
Python on CPU) — numerically identical, validated against ``ref.py`` in
tests/test_kernels_*.  The policy lives in ``default_interpret`` and the
kernel entry points resolve it lazily from an ``interpret=None`` default,
so a direct kernel-module call gets the same backend-aware behaviour as
these wrappers.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_adam import fused_adam as _adam
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.onebit_quant import onebit_quant as _onebit
from repro.kernels.onebit_quant import onebit_quant_packed as _onebit_packed
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.topk_sparsify import topk_encode_ef as _topk_ef
from repro.kernels.topk_sparsify import topk_sparsify as _topk


def default_interpret() -> bool:
    """THE backend-aware interpret policy (single definition, threaded
    through every kernel): compile natively on an accelerator backend
    (TPU/GPU), interpret everywhere else.  Kernel entry points default
    ``interpret=None`` and resolve it here lazily, so importing a kernel
    module never forces backend initialization."""
    return jax.default_backend() not in ("tpu", "gpu")


_interpret = default_interpret  # backward-compat alias


def flash_attention(q, k, v, *, causal=True, window=-1,
                    block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, window=window,
                  block_q=block_q, block_k=block_k)


def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                    window=None, softcap=None):
    return _paged(q, k_pages, v_pages, block_tables, ctx_lens,
                  window=window, softcap=softcap)


def topk_sparsify(x, k, rows_per_step=8):
    return _topk(x, k, rows_per_step=rows_per_step)


def topk_encode_ef(g, r, k, rows_per_step=8):
    return _topk_ef(g, r, k, rows_per_step=rows_per_step)


def onebit_quant(g, r, rows_per_step=8):
    return _onebit(g, r, rows_per_step=rows_per_step)


def onebit_quant_packed(g, r, rows_per_step=8):
    return _onebit_packed(g, r, rows_per_step=rows_per_step)


def fused_adam(p, g, m, v, lr, t, **kw):
    return _adam(p, g, m, v, lr, t, **kw)


def mamba_scan(u, delta, a, b, c, d_skip, d_block=128):
    return _mamba(u, delta, a, b, c, d_skip, d_block=d_block)
