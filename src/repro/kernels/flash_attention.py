"""Flash attention Pallas TPU kernel (blocked online softmax).

TPU adaptation (DESIGN.md §2): grid = (batch·heads, q_blocks, kv_blocks)
with f32 accumulators (acc, row-max m, row-sum l) in VMEM scratch that
persist across the kv_block grid dimension (TPU grids iterate the trailing
dimension innermost, sequentially per core).  Block shapes default to
(128, 128) — MXU-aligned on the (8,128)/(128,128) tiles.  Sliding windows
(gemma3's 5:1 local:global) are handled by masking inside the block and by
*skipping* fully-masked kv blocks via ``@pl.when`` (compute proportional to
the window, the sub-quadratic property the long-context shapes need).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 causal: bool, window: int, block_q: int, block_k: int,
                 scale: float, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip: causal ⇒ kv blocks entirely above the diagonal are
    # dead; sliding window ⇒ kv blocks entirely left of the window are dead.
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window > 0:
        live = jnp.logical_and(
            live, (q_start - (k_start + block_k - 1)) < window)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = q @ k.T  # (bq, bk)

        ii = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        jj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jj < kv_len
        if causal:
            mask &= jj <= ii
        if window > 0:
            mask &= (ii - jj) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q,k,v: (B, H, L, D) → (B, H, L, D)."""
    from repro.kernels.ops import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    b, h, l, d = q.shape
    lk = k.shape[2]
    block_q = min(block_q, l)
    block_k = min(block_k, lk)
    pad_q = (-l) % block_q
    pad_k = (-lk) % block_k
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    lq_p, lk_p = l + pad_q, lk + pad_k
    qf = q.reshape(b * h, lq_p, d)
    kf = k.reshape(b * h, lk_p, d)
    vf = v.reshape(b * h, lk_p, d)

    grid = (b * h, lq_p // block_q, lk_p // block_k)
    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, scale=d ** -0.5, kv_len=lk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, lq_p, d)[:, :, :l]
