"""Selective-scan (Mamba S6) Pallas TPU kernel.

TPU adaptation of the paper's "hardware-aware" CUDA scan (DESIGN.md §2):
the CUDA kernel keeps state in SRAM across a warp-parallel scan; here each
grid cell owns a (d_blk, N) state tile in VMEM and walks time sequentially,
FUSING discretization (Δ·A exponential, Δ·u·B) with the recurrence and the
C-projection so the (B, L, D, N) discretized tensors are never
materialized in HBM — the memory blow-up that forces chunking in the jnp
path (models/ssm.py) disappears entirely.

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t u_t) ⊙ B_t
    y_t = (h_t · C_t) + D ⊙ u_t

Grid: (batch, D/d_blk); block shapes keep the working set
(L·d_blk activations + d_blk·N state) inside VMEM with MXU-aligned tiles.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(u_ref, delta_ref, a_ref, b_ref, c_ref, dskip_ref,
                 y_ref, hlast_ref, h_scratch, *, length: int):
    h_scratch[...] = jnp.zeros_like(h_scratch)

    def step(t, _):
        u_t = u_ref[0, t].astype(jnp.float32)  # (d_blk,)
        dt = delta_ref[0, t].astype(jnp.float32)  # (d_blk,)
        b_t = b_ref[0, t].astype(jnp.float32)  # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)  # (N,)
        a = a_ref[...].astype(jnp.float32)  # (d_blk, N)
        abar = jnp.exp(dt[:, None] * a)
        h = abar * h_scratch[...] + (dt * u_t)[:, None] * b_t[None, :]
        h_scratch[...] = h
        y = h @ c_t + dskip_ref[...].astype(jnp.float32) * u_t
        y_ref[0, t] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, length, step, 0)
    hlast_ref[0] = h_scratch[...]


@functools.partial(jax.jit, static_argnames=("d_block", "interpret"))
def mamba_scan(u, delta, a, b, c, d_skip, d_block: int = 128,
               interpret: Optional[bool] = None):
    """u, delta: (B, L, D); a: (D, N); b, c: (B, L, N); d_skip: (D,).
    Returns (y (B, L, D), h_last (B, D, N))."""
    from repro.kernels.ops import default_interpret
    interpret = default_interpret() if interpret is None else interpret
    bsz, l, d = u.shape
    n = a.shape[1]
    d_block = min(d_block, d)
    pad = (-d) % d_block
    if pad:
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad)))
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, pad)))
        a = jnp.pad(a, ((0, pad), (0, 0)))
        d_skip = jnp.pad(d_skip, (0, pad))
    dp = d + pad
    grid = (bsz, dp // d_block)
    kernel = functools.partial(_scan_kernel, length=l)
    y, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, d_block), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, l, d_block), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((d_block, n), lambda bi, di: (di, 0)),
            pl.BlockSpec((1, l, n), lambda bi, di: (bi, 0, 0)),
            pl.BlockSpec((1, l, n), lambda bi, di: (bi, 0, 0)),
            pl.BlockSpec((d_block,), lambda bi, di: (di,)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, d_block), lambda bi, di: (bi, 0, di)),
            pl.BlockSpec((1, d_block, n), lambda bi, di: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, dp), u.dtype),
            jax.ShapeDtypeStruct((bsz, dp, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(u, delta, a, b, c, d_skip)
    return y[..., :d], hlast[:, :d]
