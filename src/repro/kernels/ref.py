"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` matches its kernel bit-for-bit up to float tolerance; tests
sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# flash attention (causal + optional sliding window)
# ---------------------------------------------------------------------------
def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = -1):
    """q,k,v: (B, H, L, D).  window: -1 ⇒ unlimited; else i−j < window."""
    b, h, l, d = q.shape
    logits = jnp.einsum("bhid,bhjd->bhij", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    i = jnp.arange(l)[:, None]
    j = jnp.arange(l)[None, :]
    mask = jnp.ones((l, l), bool)
    if causal:
        mask &= j <= i
    if window > 0:
        mask &= (i - j) < window
    logits = jnp.where(mask, logits, -2.0e38)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhij,bhjd->bhid", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged attention (decode over a block KV cache)
# ---------------------------------------------------------------------------
def paged_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens, *,
                        window=None, softcap=None):
    """q: (B, KV, G, Dh); k_pages/v_pages: (num_pages, page_size, KV, Dh);
    block_tables: (B, MB) int32; ctx_lens: (B,) int32.  The jnp gather
    oracle for kernels/paged_attention.py: pages are gathered into a dense
    (B, MB*page_size, KV, Dh) view and masked by ``j < ctx`` (causal — the
    query sits at ctx-1) and the sliding window.  Returns (B, KV, G, Dh)."""
    b, kv, g, dh = q.shape
    n_pages, ps, _, _ = k_pages.shape
    ks = k_pages[block_tables].reshape(b, -1, kv, dh)  # (B, S, KV, Dh)
    vs = v_pages[block_tables].reshape(b, -1, kv, dh)
    s_max = ks.shape[1]
    logits = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                        ks.astype(jnp.float32)) * (dh ** -0.5)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    j = jnp.arange(s_max, dtype=jnp.int32)[None, None, None, :]
    pos = (ctx_lens.astype(jnp.int32) - 1)[:, None, None, None]
    mask = j <= pos
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        wide = jnp.iinfo(jnp.int32).max
        mask = mask & ((pos - j) < jnp.where(w > 0, w, wide))
    logits = jnp.where(mask, logits, -2.0e38)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vs.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# block-local top-k sparsification (DGC)
# ---------------------------------------------------------------------------
def topk_sparsify_ref(x, k: int):
    """x: (nblocks, block). Returns (values (nb,k), indices (nb,k) int32,
    dense (nb, block) with only the top-k kept."""
    mag = jnp.abs(x.astype(jnp.float32))
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(x, idx, axis=-1)
    dense = jnp.zeros_like(x).at[
        jnp.arange(x.shape[0])[:, None], idx].set(vals)
    return vals, idx.astype(jnp.int32), dense


# ---------------------------------------------------------------------------
# 1-bit quantization with error feedback
# ---------------------------------------------------------------------------
def onebit_quant_ref(x, residual):
    """x, residual: (nblocks, block) f32.
    Returns (sign int8, scale (nb,1) f32, new_residual)."""
    t = x.astype(jnp.float32) + residual
    sign = jnp.where(t >= 0, 1, -1).astype(jnp.int8)
    scale = jnp.mean(jnp.abs(t), axis=-1, keepdims=True)
    decoded = sign.astype(jnp.float32) * scale
    return sign, scale, t - decoded


# ---------------------------------------------------------------------------
# selective scan (Mamba S6)
# ---------------------------------------------------------------------------
def mamba_scan_ref(u, delta, a, b, c, d_skip):
    """u, delta: (B, L, D); a: (D, N); b, c: (B, L, N); d_skip: (D,).
    Returns (y (B, L, D), h_last (B, D, N))."""
    bsz, l, d = u.shape
    n = a.shape[1]

    def step(h, xs):
        u_t, dt, b_t, c_t = xs  # (B,D),(B,D),(B,N),(B,N)
        abar = jnp.exp(dt[..., None] * a[None])  # (B, D, N)
        h = abar * h + (dt * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + d_skip * u_t
        return h, y

    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    xs = (u.swapaxes(0, 1).astype(jnp.float32),
          delta.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32))
    hlast, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(u.dtype), hlast


# ---------------------------------------------------------------------------
# fused Adam step
# ---------------------------------------------------------------------------
def fused_adam_ref(p, g, m, v, lr, b1=0.9, b2=0.999, eps=1e-8, t=1):
    """All (N,) arrays; t is the 1-based step. Returns (p, m, v)."""
    gf = g.astype(jnp.float32)
    m1 = b1 * m + (1 - b1) * gf
    v1 = b2 * v + (1 - b2) * gf * gf
    mh = m1 / (1 - b1 ** t)
    vh = v1 / (1 - b2 ** t)
    p1 = p - lr * mh / (jnp.sqrt(vh) + eps)
    return p1.astype(p.dtype), m1, v1
