"""Block-local top-k gradient sparsification Pallas kernel (DGC, paper
§2.2.4).

TPU adaptation (DESIGN.md §2): Deep Gradient Compression's global top-k
needs a full sort — hostile to the VPU.  Block-local top-k keeps each
block's working set in VMEM, preserves the compression ratio, and each
grid step is independent (embarrassingly parallel over blocks).  Inside
the kernel we avoid sort entirely: k iterations of (max, mask) — for the
k ≪ block regime of gradient sparsification this is O(k·block) VPU work
with no data-dependent control flow.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1.0


def _resolve(interpret):
    if interpret is not None:
        return interpret
    from repro.kernels.ops import default_interpret
    return default_interpret()


def _topk_kernel(x_ref, vals_ref, idx_ref, dense_ref, *, k: int, block: int):
    x = x_ref[...]  # (rows, block)
    mag = jnp.abs(x.astype(jnp.float32))
    dense = jnp.zeros_like(x)
    cols = jax.lax.broadcasted_iota(jnp.int32, mag.shape, 1)

    def body(i, carry):
        mag_c, dense_c = carry
        m = jnp.max(mag_c, axis=-1, keepdims=True)  # (rows,1)
        # first column achieving the max
        hit = mag_c == m
        first = jnp.min(jnp.where(hit, cols, block), axis=-1, keepdims=True)
        sel = cols == first
        vals_ref[:, i] = jnp.sum(jnp.where(sel, x, 0.0), axis=-1)
        idx_ref[:, i] = first[:, 0]
        dense_c = jnp.where(sel, x, dense_c)
        mag_c = jnp.where(sel, NEG, mag_c)
        return mag_c, dense_c

    mag, dense = jax.lax.fori_loop(0, k, body, (mag, dense))
    dense_ref[...] = dense


@functools.partial(jax.jit, static_argnames=("k", "rows_per_step", "interpret"))
def topk_sparsify(x, k: int, rows_per_step: int = 8,
                  interpret: Optional[bool] = None):
    """x: (nblocks, block) → (vals (nb,k), idx (nb,k) int32, dense (nb,block))."""
    interpret = _resolve(interpret)
    nb, block = x.shape
    pad = (-nb) % rows_per_step
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // rows_per_step,)
    kernel = functools.partial(_topk_kernel, k=k, block=block)
    vals, idx, dense = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_step, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows_per_step, k), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, k), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, k), x.dtype),
            jax.ShapeDtypeStruct((nbp, k), jnp.int32),
            jax.ShapeDtypeStruct((nbp, block), x.dtype),
        ],
        interpret=interpret,
    )(x)
    return vals[:nb], idx[:nb], dense[:nb]


def _topk_ef_kernel(g_ref, r_ref, vals_ref, idx_ref, newr_ref,
                    *, k: int, block: int):
    """Fused DGC round: t = g + r, block-local top-k of |t| (same
    (max, lowest-index, mask) iteration as ``_topk_kernel``), and the
    error-feedback residual t − dense(sent) — one VMEM pass."""
    t = g_ref[...].astype(jnp.float32) + r_ref[...]
    mag = jnp.abs(t)
    dense = jnp.zeros_like(t)
    cols = jax.lax.broadcasted_iota(jnp.int32, mag.shape, 1)

    def body(i, carry):
        mag_c, dense_c = carry
        m = jnp.max(mag_c, axis=-1, keepdims=True)  # (rows,1)
        hit = mag_c == m
        first = jnp.min(jnp.where(hit, cols, block), axis=-1, keepdims=True)
        sel = cols == first
        vals_ref[:, i] = jnp.sum(jnp.where(sel, t, 0.0), axis=-1)
        idx_ref[:, i] = first[:, 0]
        dense_c = jnp.where(sel, t, dense_c)
        mag_c = jnp.where(sel, NEG, mag_c)
        return mag_c, dense_c

    mag, dense = jax.lax.fori_loop(0, k, body, (mag, dense))
    newr_ref[...] = t - dense


@functools.partial(jax.jit, static_argnames=("k", "rows_per_step", "interpret"))
def topk_encode_ef(g, r, k: int, rows_per_step: int = 8,
                   interpret: Optional[bool] = None):
    """g, r: (nblocks, block) → (vals (nb,k) f32, idx (nb,k) int32,
    new_r (nb,block) f32).  The production Fabric-path variant of
    ``topk_sparsify``: the target t = g + r and the residual update
    happen inside the kernel, so the whole encode+error-feedback round
    is one pass over VMEM."""
    interpret = _resolve(interpret)
    nb, block = g.shape
    pad = (-nb) % rows_per_step
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // rows_per_step,)
    kernel = functools.partial(_topk_ef_kernel, k=k, block=block)
    vals, idx, newr = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_step, block), lambda i: (i, 0))] * 2,
        out_specs=[
            pl.BlockSpec((rows_per_step, k), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, k), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, k), jnp.float32),
            jax.ShapeDtypeStruct((nbp, k), jnp.int32),
            jax.ShapeDtypeStruct((nbp, block), jnp.float32),
        ],
        interpret=interpret,
    )(g, r)
    return vals[:nb], idx[:nb], newr[:nb]
