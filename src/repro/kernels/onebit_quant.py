"""1-bit gradient quantization with error feedback — Pallas kernel
(Seide et al. [55], paper §2.2.4).

Fuses the whole error-feedback round in one VMEM pass:
    t = g + r;  sign = sgn(t);  scale = mean|t|;  r' = t − sign·scale
int8 signs + one f32 scale per block (8,128)-tile aligned; the final
8→1-bit packing is a bitcast-level wire detail left to XLA (DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onebit_kernel(g_ref, r_ref, sign_ref, scale_ref, newr_ref):
    t = g_ref[...].astype(jnp.float32) + r_ref[...]
    sign = jnp.where(t >= 0, 1, -1).astype(jnp.int8)
    scale = jnp.mean(jnp.abs(t), axis=-1, keepdims=True)  # (rows, 1)
    decoded = sign.astype(jnp.float32) * scale
    sign_ref[...] = sign
    scale_ref[...] = scale
    newr_ref[...] = t - decoded


@functools.partial(jax.jit, static_argnames=("rows_per_step", "interpret"))
def onebit_quant(g, r, rows_per_step: int = 8, interpret: bool = True):
    """g, r: (nblocks, block) → (sign int8, scale (nb,1) f32, new_r f32)."""
    nb, block = g.shape
    pad = (-nb) % rows_per_step
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // rows_per_step,)
    sign, scale, newr = pl.pallas_call(
        _onebit_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_step, block), lambda i: (i, 0))] * 2,
        out_specs=[
            pl.BlockSpec((rows_per_step, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, block), jnp.int8),
            jax.ShapeDtypeStruct((nbp, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbp, block), jnp.float32),
        ],
        interpret=interpret,
    )(g, r)
    return sign[:nb], scale[:nb], newr[:nb]
