"""1-bit gradient quantization with error feedback — Pallas kernel
(Seide et al. [55], paper §2.2.4).

Fuses the whole error-feedback round in one VMEM pass:
    t = g + r;  sign = sgn(t);  scale = mean|t|;  r' = t − sign·scale
int8 signs + one f32 scale per block, (8,128)-tile aligned.

``onebit_quant_packed`` is the production variant on the Fabric path
(core/fabric.py): it additionally emits the TRUE wire format from inside
the kernel — packed uint8 sign bytes (8 signs/byte, via one MXU matmul
against a constant bit-weight matrix) and bf16 scales — and computes the
residual against the bf16-rounded decode, so the encode+pack+error-
feedback round is ONE pass with no separate XLA ``pack_signs`` op and is
bitwise identical to the pure-jnp wire codec.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resolve(interpret):
    if interpret is not None:
        return interpret
    from repro.kernels.ops import default_interpret
    return default_interpret()


def _onebit_kernel(g_ref, r_ref, sign_ref, scale_ref, newr_ref):
    t = g_ref[...].astype(jnp.float32) + r_ref[...]
    sign = jnp.where(t >= 0, 1, -1).astype(jnp.int8)
    scale = jnp.mean(jnp.abs(t), axis=-1, keepdims=True)  # (rows, 1)
    decoded = sign.astype(jnp.float32) * scale
    sign_ref[...] = sign
    scale_ref[...] = scale
    newr_ref[...] = t - decoded


@functools.partial(jax.jit, static_argnames=("rows_per_step", "interpret"))
def onebit_quant(g, r, rows_per_step: int = 8,
                 interpret: Optional[bool] = None):
    """g, r: (nblocks, block) → (sign int8, scale (nb,1) f32, new_r f32)."""
    interpret = _resolve(interpret)
    nb, block = g.shape
    pad = (-nb) % rows_per_step
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // rows_per_step,)
    sign, scale, newr = pl.pallas_call(
        _onebit_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_step, block), lambda i: (i, 0))] * 2,
        out_specs=[
            pl.BlockSpec((rows_per_step, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, block), jnp.int8),
            jax.ShapeDtypeStruct((nbp, 1), jnp.float32),
            jax.ShapeDtypeStruct((nbp, block), jnp.float32),
        ],
        interpret=interpret,
    )(g, r)
    return sign[:nb], scale[:nb], newr[:nb]


def _pack_matrix(block: int):
    """(block, block//8) bit-weight matrix P with P[i, i//8] = 1 << (i%8):
    ``bits_f32 @ P`` packs 8 consecutive sign bits into one byte value —
    exactly the ``compression.pack_signs`` order — as one MXU matmul."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (block, block // 8), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, block // 8), 1)
    weight = jnp.left_shift(1, rows % 8)
    return jnp.where(rows // 8 == cols, weight, 0).astype(jnp.float32)


def _onebit_packed_kernel(g_ref, r_ref, packed_ref, scale_ref, newr_ref,
                          *, block: int):
    t = g_ref[...].astype(jnp.float32) + r_ref[...]
    bits = (t >= 0).astype(jnp.float32)
    packed = jnp.dot(bits, _pack_matrix(block),
                     preferred_element_type=jnp.float32)
    packed_ref[...] = packed.astype(jnp.uint8)
    scale = jnp.mean(jnp.abs(t), axis=-1, keepdims=True)  # (rows, 1) f32
    scale_bf16 = scale.astype(jnp.bfloat16)
    scale_ref[...] = scale_bf16
    # residual against the bf16-rounded decode the receivers will see
    sign = jnp.where(t >= 0, 1.0, -1.0)
    newr_ref[...] = t - sign * scale_bf16.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("rows_per_step", "interpret"))
def onebit_quant_packed(g, r, rows_per_step: int = 8,
                        interpret: Optional[bool] = None):
    """g, r: (nblocks, block) → (packed (nb, block//8) uint8,
    scale (nb, 1) bf16, new_r (nb, block) f32).

    The wire-format-emitting fused round: packed bytes and bf16 scales
    come straight out of VMEM, and ``new_r`` already accounts for the
    bf16 scale rounding (t − sign·f32(bf16(scale)))."""
    interpret = _resolve(interpret)
    nb, block = g.shape
    if block % 8:
        raise ValueError(f"packed onebit needs block % 8 == 0, got {block}")
    pad = (-nb) % rows_per_step
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
        r = jnp.pad(r, ((0, pad), (0, 0)))
    nbp = nb + pad
    grid = (nbp // rows_per_step,)
    kernel = functools.partial(_onebit_packed_kernel, block=block)
    packed, scale, newr = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_step, block), lambda i: (i, 0))] * 2,
        out_specs=[
            pl.BlockSpec((rows_per_step, block // 8), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, block // 8), jnp.uint8),
            jax.ShapeDtypeStruct((nbp, 1), jnp.bfloat16),
            jax.ShapeDtypeStruct((nbp, block), jnp.float32),
        ],
        interpret=interpret,
    )(g, r)
    return packed[:nb], scale[:nb], newr[:nb]
