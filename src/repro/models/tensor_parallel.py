"""Explicit tensor parallelism over the Fabric (DESIGN.md §12).

The production mesh already carries a "model" axis and the pjit rules in
launch/sharding.py let the SPMD partitioner derive TP collectives
implicitly.  This module is the EXPLICIT counterpart: a Megatron-style
column/row split of the transformer block whose activation combines are
issued by the model code itself, routed through ``core.fabric.Fabric`` so
they get the same bucketing, wire-dtype handling, and
``collective_contract`` accounting as the gradient exchange — which is
what lets the analysis tier prove an HLO collective budget for the TP
axis (``Fabric.collective_contract(..., "tp")``).

Layout (``cfg.tp_degree = T``):

  column-split (output slicing — no communication, bitwise free):
      wq/wk/wv  (D, H, Dh)  → (D, H/T, Dh)     heads
      bq/bk/bv  (H, Dh)     → (H/T, Dh)
      w_gate/w_up  (D, F)   → (D, F/T)         d_ff
  row-split (contraction slicing — one all-reduce per combine):
      wo        (H, Dh, D)  → (H/T, Dh, D)
      w_down    (F, D)      → (F/T, D)
  everything else (norms, embed, lm_head, router, ssm) replicated.

Exactly two contractions change math: the attention out-projection (summed
over heads) and the MLP down-projection (summed over d_ff).  Each TP rank
computes one block of that sum and ``TPContext.all_sum`` combines them.
The unsharded reference path (``tp_degree > 1`` with no active context,
models/layers.py) computes the SAME blocked sum —
``jnp.sum(jnp.stack(partial_blocks), axis=0)`` — so a TP run is
bitwise-equivalent to its blocked reference in f32: both reduce identical
block values with the same stacked-sum op (for T=2 a single add, order-
independent by IEEE commutativity).

Backward contract (f32, verified in tests/test_tp.py): the forward pass,
the loss, and each isolated sub-layer's backward are BITWISE equal to the
blocked reference — ``jnp.sum``'s transpose broadcasts the cotangent to
every block exactly as psum's transpose (psum) delivers it to every rank,
and no f-operator is needed: under a mapped axis JAX's psum transposes to
psum, which already completes the split leaves' gradients.  End-to-end
network gradients agree to ≤1 ulp rather than bitwise: where the residual
stream's cotangent is REUSED across a layer boundary the reference
accumulates ``ct_residual + Σ_r block_rᵀ(ct)`` in a different association
order than the per-rank ``block_rᵀ(ct) + ct_residual/T`` the psum
transpose sums.  Replicated-leaf gradients are per-rank partials by
construction (each rank's copy sees only its own blocks' contribution);
``TPContext.finalize_grads`` all-sums them — Megatron's layernorm-grad
all-reduce — after which they match the reference to the same ≤1 ulp.

The context is a Python-level trace-time switch (installed around tracing,
like a mesh context), NOT traced state: model code asks ``current_tp()``
once per combine while being traced under ``jax.vmap(...,
axis_name="model")`` (the stacked simulator) or a shard_map over a
"model" mesh axis (the HLO-proof rig in tests/analysis).
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass

import jax.numpy as jnp

from repro.core.comm import ShardComm
from repro.core.fabric import DEFAULT_BUCKET_BYTES, Fabric

# leaf name → axis to slice, per parameter-tree key (tensor_parallel
# applies to attention + dense MLP; MoE keeps its own expert parallelism
# over "model" and is never tp-split)
_COLUMN_AXES = {"wq": 1, "wk": 1, "wv": 1, "bq": 0, "bk": 0, "bv": 0,
                "w_gate": 1, "w_up": 1}
_ROW_AXES = {"wo": 0, "w_down": 0}
SPLIT_AXES = {**_COLUMN_AXES, **_ROW_AXES}


@dataclass(frozen=True)
class TPContext:
    """Active tensor-parallel execution: ``degree`` ranks over mesh/vmap
    axis ``axis``, combining row-parallel partials through ``fabric``."""

    degree: int
    axis: str
    fabric: Fabric

    def all_sum(self, x):
        """Combine one row-parallel partial (Megatron's *g* operator):
        one dense all-reduce of the activation — counted by
        ``collective_contract(..., "tp", events=combines)``."""
        return self.fabric.all_sum(x)

    def finalize_grads(self, grads, stacked_marker: str = "stack"):
        """All-sum the REPLICATED leaves' gradients over the TP axis —
        Megatron's layernorm-grad all-reduce.  Under mapped-axis autodiff
        (vmap axis_name / shard_map) the cotangent arriving at each
        rank's copy of a replicated parameter carries only that rank's
        head/column block's contribution (the psum transpose already
        completes the SPLIT leaves' grads); summing across ranks
        completes the replicated ones: sum == the unsharded reference
        gradient.  One bucketed Fabric all-sum for the whole replicated
        subtree.  Split leaves pass through untouched."""
        rep, keep = _partition_replicated(grads, stacked_marker)
        if rep:
            rep = self.fabric.all_sum(rep)
        return _merge_trees(rep, keep)


_STACK: list = []


def current_tp():
    """The innermost active ``tp_context``, or None (unsharded paths)."""
    return _STACK[-1] if _STACK else None


@contextmanager
def tp_context(degree: int, axis: str = "model",
               bucket_bytes: int = DEFAULT_BUCKET_BYTES, wire_dtype=None):
    """Install a TP execution context for code traced within.  The body
    must run under a mapped axis named ``axis`` of size ``degree`` (vmap
    axis_name or shard_map mesh axis)."""
    if degree < 2:
        raise ValueError(f"tp_context needs degree >= 2, got {degree}")
    fab = Fabric(ShardComm(axis, degree), bucket_bytes,
                 wire_dtype=wire_dtype)
    ctx = TPContext(degree, axis, fab)
    _STACK.append(ctx)
    try:
        yield ctx
    finally:
        _STACK.pop()


def tp_split_params(params, degree: int, stacked_marker: str = "stack"):
    """Full param tree → per-rank shards STACKED on a new leading axis of
    size ``degree`` (the layout ``jax.vmap(fn, axis_name="model")`` and
    the LocalComm-style rigs consume; index ``[r]`` for rank r's tree).

    Splits follow ``SPLIT_AXES`` by leaf name; leaves under an ``moe``
    subtree and everything unnamed are replicated.  Leaves under the
    ``stacked_marker`` subtree (the lax.scan layer stacking) carry a
    leading repeat axis, shifting the split axis by one."""

    # walk with names: dict-only trees (the repo's param convention)
    def walk(tree, in_stack=False, in_moe=False):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v, in_stack or k == stacked_marker,
                              in_moe or k == "moe")
            elif in_moe or SPLIT_AXES.get(k) is None:
                out[k] = jnp.stack([v] * degree)
            else:
                ax = SPLIT_AXES[k] + (1 if in_stack else 0)
                n = v.shape[ax]
                if n % degree:
                    raise ValueError(
                        f"tp_split_params: {k} axis {ax} ({n}) not "
                        f"divisible by tp_degree={degree}")
                out[k] = jnp.stack(jnp.split(v, degree, axis=ax))
        return out

    if not isinstance(params, dict):
        raise TypeError("tp_split_params expects the dict param tree")
    return walk(params)


def tp_unsplit_params(shards, stacked_marker: str = "stack"):
    """Inverse of ``tp_split_params``: per-rank stacked shards → the full
    tree (replicated leaves take rank 0's copy)."""

    def walk(tree, in_stack=False, in_moe=False):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v, in_stack or k == stacked_marker,
                              in_moe or k == "moe")
            elif in_moe or SPLIT_AXES.get(k) is None:
                out[k] = v[0]
            else:
                ax = SPLIT_AXES[k] + (1 if in_stack else 0)
                out[k] = jnp.concatenate([v[i] for i in range(v.shape[0])],
                                         axis=ax)
        return out

    return walk(shards)


def _partition_replicated(tree, stacked_marker: str, in_moe: bool = False):
    """Split a dict tree into (replicated-leaf subtree, split-leaf
    subtree) by the ``SPLIT_AXES`` naming convention.  Either side omits
    empty branches so the replicated subtree can be bucketed on its own."""
    rep, keep = {}, {}
    for k, v in tree.items():
        if isinstance(v, dict):
            r, s = _partition_replicated(v, stacked_marker,
                                         in_moe or k == "moe")
            if r:
                rep[k] = r
            if s:
                keep[k] = s
        elif in_moe or k not in SPLIT_AXES:
            rep[k] = v
        else:
            keep[k] = v
    return rep, keep


def _merge_trees(a, b):
    """Recombine the two disjoint subtrees from ``_partition_replicated``."""
    out = dict(a)
    for k, v in b.items():
        out[k] = _merge_trees(out[k], v) if isinstance(v, dict) and \
            isinstance(out.get(k), dict) else v
    return out


def tp_collective_contract(cfg, activation_sds,
                           bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                           wire_dtype=None) -> dict:
    """Expected HLO collective budget for ONE training step of a
    ``tp_degree``-split model: two row-parallel combines per (attn + mlp)
    layer — out-projection and down-projection — each a Fabric all-sum of
    the layer activation, counted forward AND backward (the column-split
    input gradients all-reduce on the transpose)."""
    n_layers = cfg.num_layers
    combines = 2 * n_layers * 2  # (wo + w_down) × (fwd + bwd)
    fab = Fabric(ShardComm("model", cfg.tp_degree), bucket_bytes,
                 wire_dtype=wire_dtype)
    return fab.collective_contract(activation_sds, "tp", events=combines)
