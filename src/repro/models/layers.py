"""Core transformer layers: norms, RoPE, GQA attention (sliding-window /
bias / qk-norm / softcap / cross), SwiGLU MLP, and capacity-based MoE.

All layers are pure functions over nested-dict parameter pytrees.

Precision contract (core/precision.py, DESIGN.md §4): matmuls and
activations run in whatever dtype the inputs carry (``cfg.compute_dtype``
after the forward-boundary cast in models/transformer.py), but every
numerically-sensitive reduction accumulates in f32 regardless —
``rms_norm`` statistics, RoPE angles, attention logits + softmax (all
four sdpa paths), and the MoE router logits/aux loss.  Keeping those
invariants here is what lets the bf16 policy train within tolerance of
f32 (tests/test_precision.py) without any per-layer dtype plumbing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import FULL_ATTENTION, ModelConfig
from repro.core import jax_compat as compat
from repro.launch.sharding import BATCH, MODEL, heads_ax, seq_ax, shard

NEG_INF = -2.0e38


def _dtype(cfg: ModelConfig, kind: str):
    return jnp.dtype(cfg.param_dtype if kind == "param" else cfg.compute_dtype)


# ---------------------------------------------------------------------------
# tensor parallelism (DESIGN.md §12, models/tensor_parallel.py)
#
# TP blocks the WHOLE sub-layer, not individual contractions: rank i's
# subgraph is (q/k/v head-slice → sdpa over its heads → out-projection
# partial) for attention and (gate/up column-slice → act → down-projection
# partial) for the MLP, combined with ONE all-sum per sub-layer (Megatron's
# g operator) plus the f operator's cotangent psum at the input.  The
# unsharded reference with cfg.tp_degree = T > 1 computes the SAME T
# per-block subgraphs and reduces them with jnp.sum(jnp.stack(...)) — the
# identical dataflow graph per block and the identical combine, which is
# what makes a TP run bitwise-equal to its blocked reference in f32
# (blocking per-contraction instead would re-order the input-cotangent
# accumulation across q/k/v and gate/up and break bitwise backward).
# tp_degree == 1 (every config's default) keeps the historical
# single-einsum paths untouched.
# ---------------------------------------------------------------------------
def _current_tp():
    from repro.models.tensor_parallel import current_tp

    return current_tp()


def _attn_slice(p, i: int, t: int):
    """Head-block i of t of an attention param dict — exactly what
    ``tp_split_params`` puts on rank i."""
    h, kv = p["wq"].shape[1], p["wk"].shape[1]
    hb, kb = h // t, kv // t
    out = dict(p)
    out["wq"] = p["wq"][:, i * hb:(i + 1) * hb]
    out["wk"] = p["wk"][:, i * kb:(i + 1) * kb]
    out["wv"] = p["wv"][:, i * kb:(i + 1) * kb]
    out["wo"] = p["wo"][i * hb:(i + 1) * hb]
    if "bq" in p:
        out["bq"] = p["bq"][i * hb:(i + 1) * hb]
        out["bk"] = p["bk"][i * kb:(i + 1) * kb]
        out["bv"] = p["bv"][i * kb:(i + 1) * kb]
    return out


def dense_init(key, shape, dtype, in_axis=0):
    fan_in = shape[in_axis]
    scale = 1.0 / max(1, fan_in) ** 0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rms_norm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x, p, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return out.astype(x.dtype) * p["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, positions, theta):
    """x: (..., L, H, Dh), positions: (..., L) int, theta: scalar."""
    dh = x.shape[-1]
    half = dh // 2
    freq = jnp.arange(half, dtype=jnp.float32) * (2.0 / dh)
    inv = jnp.power(jnp.asarray(theta, jnp.float32), -freq)  # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., L, half)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (..., L, 1, half)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pdt = _dtype(cfg, "param")
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), pdt),
        "wk": dense_init(ks[1], (d, kv, dh), pdt),
        "wv": dense_init(ks[2], (d, kv, dh), pdt),
        "wo": dense_init(ks[3], (h, dh, d), pdt, in_axis=0),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), pdt)
        p["bk"] = jnp.zeros((kv, dh), pdt)
        p["bv"] = jnp.zeros((kv, dh), pdt)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(dh, pdt)
        p["k_norm"] = init_rms_norm(dh, pdt)
    return p


def _qkv(p, cfg, xq, xkv):
    q = jnp.einsum("bld,dhk->blhk", xq, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", xkv, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", xkv, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _softcap(cfg, logits):
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def _sdpa(cfg: ModelConfig, q, k, v, mask):
    """Full-sequence attention.  q: (B,Lq,H,Dh) k/v: (B,Lk,KV,Dh),
    mask: (B,1,Lq,Lk) or (1,1,Lq,Lk).

    GQA KV heads are EXPANDED to H before the einsum: the (H → KV, G)
    reshape of the grouped form is unrepresentable for a head sharding and
    makes the SPMD partitioner all-gather activations across the mesh
    (observed: 1 GiB gathers on qwen2-1.5b).  Expansion keeps the "model"
    head sharding intact end-to-end; the extra KV bytes are activation-
    sized and compute is unchanged."""
    b, lq, h, dh = q.shape
    kvh = k.shape[2]
    if cfg.sharding_mode == "cp":
        # context parallel: q rows stay sequence-sharded; the (small, GQA)
        # KV is all-gathered over "model" (constraining seq to replicated).
        # KV stays UN-expanded (grouped einsum): heads are not sharded in
        # cp mode, and expanding first makes the backward reduce dk/dv at
        # H instead of KV heads (§Perf hillclimb 2 it. 2: 8× extra wire).
        k = shard(k, BATCH, None, None, None)
        v = shard(v, BATCH, None, None, None)
        g = h // kvh
        qg = q.reshape(b, lq, kvh, g, dh)
        logits = jnp.einsum("blkgd,bskd->bkgls", qg, k).astype(jnp.float32)
        logits *= dh ** -0.5
        logits = _softcap(cfg, logits)
        logits = jnp.where(mask[:, :, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgls,bskd->blkgd", probs, v).reshape(b, lq, h, dh)
        return shard(out, BATCH, seq_ax(cfg), None, None)
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    k = shard(k, BATCH, None, MODEL, None)
    v = shard(v, BATCH, None, MODEL, None)
    logits = jnp.einsum("blhd,bshd->bhls", q, k).astype(jnp.float32)
    logits *= dh ** -0.5
    logits = _softcap(cfg, logits)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhls,bshd->blhd", probs, v)
    return shard(out, BATCH, seq_ax(cfg), heads_ax(cfg), None)


def _sdpa_banded(cfg: ModelConfig, q, k, v, window: int):
    """Block-banded sliding-window attention (exact for window ≤ block).

    q,k,v: (B, L, H|KV, Dh); block = window; each q block attends to k
    blocks [prev, self] with in-band masking — (2·w)/L of the dense FLOPs."""
    b, l, h, dh = q.shape
    kvh = k.shape[2]
    if kvh != h and cfg.sharding_mode != "cp":
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
        kvh = h
    w = window
    nb = l // w
    qb = q.reshape(b, nb, w, h, dh)
    kb = k.reshape(b, nb, w, kvh, dh)
    vb = v.reshape(b, nb, w, kvh, dh)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kk = jnp.concatenate([kprev, kb], axis=2)  # (B, nb, 2w, KV, Dh)
    vv = jnp.concatenate([vprev, vb], axis=2)

    g = h // kvh
    qg = qb.reshape(b, nb, w, kvh, g, dh)
    logits = jnp.einsum("bnikgd,bnjkd->bnkgij", qg, kk).astype(jnp.float32)
    logits *= dh ** -0.5
    logits = _softcap(cfg, logits)
    # in-band mask: global i = n·w + ii, global j = n·w − w + jj
    ii = jnp.arange(w)[:, None]
    jj = jnp.arange(2 * w)[None, :]
    rel = ii + w - jj  # = i − j
    first = jnp.arange(nb) == 0  # block 0 has no prev
    valid = (rel >= 0) & (rel < w)  # causal ∧ window
    valid = valid[None, :, :] & ~(first[:, None, None] & (jj < w)[None])
    logits = jnp.where(valid[None, :, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bnkgij,bnjkd->bnikgd", probs, vv)
    out = out.reshape(b, l, h, dh)
    return shard(out, BATCH, seq_ax(cfg), heads_ax(cfg), None)


def _sdpa_decode(cfg: ModelConfig, q, k, v, mask):
    """Single-token decode attention against the (unexpanded) KV cache.
    q: (B,1,H,Dh), k/v: (B,S,KV,Dh) — the grouped einsum is fine here
    because q is tiny and stays replicated over "model" while the cache's
    sequence dim carries the sharding."""
    b, lq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, lq, kvh, g, dh)
    logits = jnp.einsum("blkgd,bskd->bkgls", q, k).astype(jnp.float32)
    logits *= dh ** -0.5
    logits = _softcap(cfg, logits)
    logits = jnp.where(mask[:, :, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgls,bskd->blkgd", probs, v)
    return out.reshape(b, lq, h, dh)


def attention(p, cfg: ModelConfig, x, positions, window, theta,
              cache=None, cache_pos=None, memory=None, causal=True,
              collect_cache=False):
    """One attention sub-layer.

    Training: ``cache is None`` — full-sequence causal (+sliding window) attn;
              with ``collect_cache`` the full-sequence (k, v) are returned as
              a populated decode cache (prefill).
    Decode:   ``cache`` holds (k, v) of length S; x has Lq=1; ``cache_pos`` is
              the write position.  Returns (out, new_cache).
    Cross-attention: ``memory`` is the encoder output; no cache, no causality.
    """
    xkv = memory if memory is not None else x
    b, lq = x.shape[0], x.shape[1]

    if memory is not None:  # cross attention: full visibility
        q, k, v = _qkv(p, cfg, x, xkv)
        lk = memory.shape[1]
        mask = jnp.ones((1, 1, lq, lk), bool)
        out = _sdpa(cfg, q, k, v, mask)
        new_cache = cache
    elif cache is None:  # training / prefill self-attention
        def head_block(p_, xx):
            """One head-block's full attention subgraph: qkv slice → rope
            → sdpa over its heads → out-projection PARTIAL."""
            q, k, v = _qkv(p_, cfg, xx, xx)
            q = rope(q, positions, theta)
            k = rope(k, positions, theta)
            q = shard(q, BATCH, seq_ax(cfg), heads_ax(cfg), None)
            k = shard(k, BATCH, seq_ax(cfg), heads_ax(cfg), None)
            if (isinstance(window, int) and window > 0 and causal
                    and lq % window == 0 and lq // window >= 2):
                # static sliding window ⇒ block-banded attention: each q
                # block attends only to (prev, self) k blocks — compute
                # ∝ L·window, the jnp analogue of the Pallas kernel's
                # block skipping.
                out = _sdpa_banded(cfg, q, k, v, window)
            else:
                i = positions[:, :, None]  # (B, L, 1)
                j = positions[:, None, :]  # (B, 1, L)
                mask = (j <= i) if causal else jnp.ones_like(j <= i)
                w = jnp.where(window == FULL_ATTENTION,
                              jnp.iinfo(jnp.int32).max, window)
                mask = mask & (i - j < w)
                out = _sdpa(cfg, q, k, v, mask[:, None])
            return jnp.einsum("blhk,hkd->bld", out, p_["wo"]), {"k": k,
                                                                "v": v}
        tp = _current_tp()
        t = cfg.tp_degree
        if tp is not None:
            # TP rank: params already hold this rank's head block
            partial, kv_c = head_block(p, x)
            proj = tp.all_sum(partial)
        elif (t > 1 and not collect_cache
              and p["wq"].shape[1] % t == 0 and p["wk"].shape[1] % t == 0):
            # blocked reference: T per-block subgraphs + stacked sum
            parts = [head_block(_attn_slice(p, i, t), x)[0]
                     for i in range(t)]
            proj = jnp.sum(jnp.stack(parts), axis=0)
            kv_c = None
        else:
            proj, kv_c = head_block(p, x)
        new_cache = kv_c if collect_cache else None
        out = shard(proj, BATCH, seq_ax(cfg), None)
        return out, new_cache
    else:  # single-token decode; cache_pos: scalar OR (B,) ragged positions
        q, k, v = _qkv(p, cfg, x, xkv)
        pos = cache_pos
        ragged = hasattr(pos, "ndim") and pos.ndim == 1
        pos_b = pos[:, None] if ragged else jnp.full((b, lq), pos, jnp.int32)
        q = rope(q, pos_b, theta)
        k = rope(k, pos_b, theta)
        if ragged:  # per-row scatter write (continuous batching)
            rows = jnp.arange(b)
            ck = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        s = ck.shape[1]
        j = jnp.arange(s, dtype=jnp.int32)[None, None, :]  # (1,1,S)
        w = jnp.where(window == FULL_ATTENTION, jnp.iinfo(jnp.int32).max, window)
        p_ = pos[:, None, None] if ragged else pos
        mask = (j <= p_) & (p_ - j < w)  # (1,1,S) or ragged (B,1,S)
        out = _sdpa_decode(cfg, q, ck, cv, mask[:, None])  # → (.,1,1,S)
        new_cache = {"k": ck, "v": cv}
    out = jnp.einsum("blhk,hkd->bld", out, p["wo"])
    out = shard(out, BATCH, seq_ax(cfg), None)
    return out, new_cache


def init_attn_cache(cfg: ModelConfig, batch, max_seq, dtype):
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_seq, kv, dh), dtype),
        "v": jnp.zeros((batch, max_seq, kv, dh), dtype),
    }


# ---------------------------------------------------------------------------
# paged attention (serving tier — block KV cache, DESIGN.md §10)
# ---------------------------------------------------------------------------
def init_paged_attn_cache(cfg: ModelConfig, num_pages, page_size, dtype):
    """Block KV cache: ``(num_pages, page_size, KV, Dh)`` k/v page pools.
    Physical page 0 is RESERVED as the trash page (never allocated — idle
    or padded token writes are routed there and no block table ever
    references it for a live position).  ``int8`` pages add per-token-
    per-head f32 scale pools for symmetric quantization."""
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    c = {
        "k_pages": jnp.zeros((num_pages, page_size, kv, dh), dtype),
        "v_pages": jnp.zeros((num_pages, page_size, kv, dh), dtype),
    }
    if jnp.dtype(dtype) == jnp.int8:
        c["k_scale"] = jnp.zeros((num_pages, page_size, kv), jnp.float32)
        c["v_scale"] = jnp.zeros((num_pages, page_size, kv), jnp.float32)
    return c


def _quant_kv_int8(x):
    """Per-token-per-head symmetric int8: x (..., Dh) → (int8, f32 scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _paged_write(cache, block_tables, positions, k, v):
    """Scatter a chunk's KV (B, C, KV, Dh) into the pages.  positions:
    (B, C) int32 with -1 ⇒ pad/idle — those writes land in trash page 0."""
    bs = cache["k_pages"].shape[1]
    rows = jnp.arange(positions.shape[0])[:, None]
    valid = positions >= 0
    pc = jnp.maximum(positions, 0)
    blk = jnp.where(valid, block_tables[rows, pc // bs], 0)
    off = jnp.where(valid, pc % bs, 0)
    new = dict(cache)
    if cache["k_pages"].dtype == jnp.int8:
        kq, ksc = _quant_kv_int8(k)
        vq, vsc = _quant_kv_int8(v)
        new["k_pages"] = cache["k_pages"].at[blk, off].set(kq)
        new["v_pages"] = cache["v_pages"].at[blk, off].set(vq)
        new["k_scale"] = cache["k_scale"].at[blk, off].set(ksc)
        new["v_scale"] = cache["v_scale"].at[blk, off].set(vsc)
    else:
        dt = cache["k_pages"].dtype
        new["k_pages"] = cache["k_pages"].at[blk, off].set(k.astype(dt))
        new["v_pages"] = cache["v_pages"].at[blk, off].set(v.astype(dt))
    return new


def _paged_gather(cache, block_tables, dtype):
    """Dense (B, MB·page_size, KV, Dh) view of each sequence's pages.
    f32/bf16 pages keep their stored dtype (bitwise-identical numerics to
    the dense decode cache); int8 pages dequantize through the scale
    pools into ``dtype``."""
    ks = cache["k_pages"][block_tables]  # (B, MB, bs, KV, Dh)
    vs = cache["v_pages"][block_tables]
    if cache["k_pages"].dtype == jnp.int8:
        ks = (ks.astype(jnp.float32)
              * cache["k_scale"][block_tables][..., None]).astype(dtype)
        vs = (vs.astype(jnp.float32)
              * cache["v_scale"][block_tables][..., None]).astype(dtype)
    b = block_tables.shape[0]
    kv, dh = ks.shape[-2:]
    return ks.reshape(b, -1, kv, dh), vs.reshape(b, -1, kv, dh)


def attention_paged(p, cfg: ModelConfig, x, positions, window, theta,
                    cache, block_tables, use_kernel=False):
    """Attention over a paged KV cache — decode (C=1) and chunked prefill
    (C>1) through ONE code path.

    x: (B, C, D); positions: (B, C) int32 token positions (-1 ⇒ pad/idle:
    the KV write is routed to trash page 0 and the output row is garbage —
    callers mask it); block_tables: (B, pages_per_seq) int32.

    Write-then-attend: the chunk's roped KV is scattered into the pages
    FIRST, then attention reads the updated pages with mask ``j <= pos``,
    so each token sees itself and its whole prefix without a separate
    dense prefill pass.  Decode single tokens take the Pallas kernel when
    ``use_kernel`` (f32/bf16 pages); prefill chunks and int8 pages take
    the jnp gather path (same oracle as kernels/ref.py).
    """
    q, k, v = _qkv(p, cfg, x, x)
    b, c = x.shape[0], x.shape[1]
    pc = jnp.maximum(positions, 0)
    q = rope(q, pc, theta)
    k = rope(k, pc, theta)
    new_cache = _paged_write(cache, block_tables, positions, k, v)

    h, dh = q.shape[2], q.shape[3]
    kvh = cfg.num_kv_heads
    int8 = cache["k_pages"].dtype == jnp.int8
    if use_kernel and c == 1 and not int8:
        from repro.kernels import ops
        qg = q[:, 0].reshape(b, kvh, h // kvh, dh)  # grouped, (kv, g) order
        ctx = pc[:, 0] + 1
        out = ops.paged_attention(
            qg, new_cache["k_pages"], new_cache["v_pages"], block_tables,
            ctx, window=window, softcap=cfg.attn_logit_softcap)
        out = out.reshape(b, 1, h, dh)
    else:
        ks, vs = _paged_gather(new_cache, block_tables, x.dtype)
        s = ks.shape[1]
        i = pc[:, :, None]                                    # (B, C, 1)
        j = jnp.arange(s, dtype=jnp.int32)[None, None, :]     # (1, 1, S)
        w = jnp.where(window == FULL_ATTENTION,
                      jnp.iinfo(jnp.int32).max, window)
        mask = (j <= i) & (i - j < w)                         # (B, C, S)
        out = _sdpa_decode(cfg, q, ks, vs, mask[:, None])
    out = jnp.einsum("blhk,hkd->bld", out, p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pdt = _dtype(cfg, "param")
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f), pdt),
        "w_up": dense_init(k2, (d, f), pdt),
        "w_down": dense_init(k3, (f, d), pdt),
    }


def _act(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp(p, cfg: ModelConfig, x):
    def ffn_block(wg, wu, wd, xx):
        """One d_ff-block's full MLP subgraph: gate/up column slice → act
        → down-projection PARTIAL."""
        h = _act(cfg.act)(xx @ wg) * (xx @ wu)
        h = shard(h, BATCH, seq_ax(cfg), heads_ax(cfg))
        return h @ wd

    tp = _current_tp()
    t = cfg.tp_degree
    if tp is not None:  # TP rank: params already hold this rank's columns
        return tp.all_sum(ffn_block(p["w_gate"], p["w_up"], p["w_down"], x))
    f = p["w_down"].shape[0]
    if t == 1 or f % t:  # shared-expert widths need not divide tp_degree
        return ffn_block(p["w_gate"], p["w_up"], p["w_down"], x)
    blk = f // t
    parts = [ffn_block(p["w_gate"][:, i * blk:(i + 1) * blk],
                       p["w_up"][:, i * blk:(i + 1) * blk],
                       p["w_down"][i * blk:(i + 1) * blk], x)
             for i in range(t)]
    return jnp.sum(jnp.stack(parts), axis=0)


# ---------------------------------------------------------------------------
# Mixture of Experts — capacity-based scatter/gather dispatch (no T×E×C
# one-hot: see DESIGN.md §3).  Experts are sharded over the "model" axis.
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.expert_d_ff
    e = cfg.num_experts_padded  # dummy experts: zero weights, never routed
    pdt = _dtype(cfg, "param")
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, cfg.num_experts), pdt),
        "w_gate": dense_init(ks[1], (e, d, f), pdt, in_axis=1),
        "w_up": dense_init(ks[2], (e, d, f), pdt, in_axis=1),
        "w_down": dense_init(ks[3], (e, f, d), pdt, in_axis=1),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.num_shared_experts * f)
    return p


def _route(p, cfg: ModelConfig, xt, e_pad, cap):
    """Shared routing math.  xt: (T, D) → (flat_idx, slot, keep, gate, aux)."""
    t = xt.shape[0]
    e, k = cfg.num_experts, cfg.top_k
    logits = (xt @ p["router"]).astype(jnp.float32)  # (T, E) active experts
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (T, k), idx < E ≤ E_pad
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(idx, e_pad, dtype=jnp.float32)  # (T, k, E_pad)
    f_e = jnp.mean(jnp.sum(onehot[..., :e], axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e) * cfg.router_aux_coef

    flat_idx = idx.reshape(t * k)
    flat_gate = gate_vals.reshape(t * k)
    oh = onehot.reshape(t * k, e_pad)
    pos_in_e = jnp.cumsum(oh, axis=0) - oh  # position among same-expert rows
    slot = jnp.sum(pos_in_e * oh, axis=-1).astype(jnp.int32)
    keep = slot < cap
    slot = jnp.where(keep, slot, cap)  # overflow → dump slot
    return flat_idx, slot, keep, flat_gate, aux


def _expert_ffn(cfg, buf, w_gate, w_up, w_down):
    h = _act(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_dense(p, cfg: ModelConfig, x):
    """Reference path (no mesh / tiny token counts): capacity dispatch with
    jnp scatter/gather on one device's view."""
    b, l, d = x.shape
    e_pad, k = cfg.num_experts_padded, cfg.top_k
    t = b * l
    xt = x.reshape(t, d)
    cap = int(max(k, round(t * k / e_pad * cfg.capacity_factor)))
    flat_idx, slot, keep, flat_gate, aux = _route(p, cfg, xt, e_pad, cap)

    src = jnp.repeat(xt, k, axis=0) if k > 1 else xt  # (T*k, D)
    buf = jnp.zeros((e_pad, cap + 1, d), x.dtype)
    buf = buf.at[flat_idx, slot].set(src.astype(x.dtype))
    buf = shard(buf, MODEL, None, None)
    out_buf = _expert_ffn(cfg, buf, p["w_gate"], p["w_up"], p["w_down"])

    gathered = out_buf[flat_idx, slot]  # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    combined = jnp.sum((gathered * flat_gate[:, None].astype(gathered.dtype))
                       .reshape(t, k, d), axis=1)
    return combined.reshape(b, l, d), aux


def _moe_ep(p, cfg: ModelConfig, x, mesh):
    """Expert-parallel MoE via shard_map (beyond-paper perf path; see
    EXPERIMENTS.md §Perf hillclimb 1).

    The pjit-auto scatter dispatch makes the SPMD partitioner replicate a
    GLOBAL (E, T·k·cf/E, D) buffer (observed: 80 GiB all-reduces/layer on
    qwen2-moe).  Here dispatch is token-local per data shard, experts are
    exchanged with two tiled ``all_to_all``s over the "model" axis, and
    expert weights are explicitly FSDP-gathered over "data" (ZeRO-3: gather
    the small weights, never the activations)."""
    from jax.sharding import PartitionSpec as P

    b, l, d = x.shape
    e_pad, k = cfg.num_experts_padded, cfg.top_k
    names = mesh.axis_names
    dp = _fit_batch_axes(mesh, b, tuple(a for a in ("pod", "data")
                                        if a in names))
    ep = _axsize(mesh, "model")
    n_dp = 1
    for a in dp:
        n_dp *= _axsize(mesh, a)
    t_loc = (b // n_dp) * l
    if t_loc % ep:
        return _moe_dense(p, cfg, x)  # token slice must divide the EP axis
    t_slice = t_loc // ep  # tokens dispatched by each model-device
    e_loc = e_pad // ep
    cap = int(max(k, round(t_slice * k / e_pad * cfg.capacity_factor)))
    cap = -(-cap // 8) * 8  # tile-align

    def local_fn(xl, router, wg, wu, wd):
        # xl: (b_loc, L, D) — REPLICATED over "model"; each model-device
        # dispatches only its 1/ep token slice (otherwise all ep devices
        # dispatch identical tokens and expert compute + wire blow up ep×:
        # §Perf hillclimb 1 it. 3).
        bl = xl.shape[0]
        xt = xl.reshape(bl * l, d)
        midx = jax.lax.axis_index("model")
        xt = jax.lax.dynamic_slice_in_dim(xt, midx * t_slice, t_slice, 0)
        flat_idx, slot, keep, flat_gate, aux = _route(
            {"router": router}, cfg, xt, e_pad, cap)
        src = jnp.repeat(xt, k, axis=0) if k > 1 else xt
        buf = jnp.zeros((e_pad, cap + 1, d), xl.dtype)
        buf = buf.at[flat_idx, slot].set(src.astype(xl.dtype))
        buf = buf[:, :cap]  # drop dump slot before the wire

        # dispatch a2a: (E_pad, C, D) → (E_loc, ep·C, D).  Named so the
        # opt-in remat policy can SAVE the a2a results (§Perf h1 it. 2).
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                  tiled=True)
        recv = checkpoint_name(recv, "moe_dispatch")
        # ZeRO-3 weight gather over the fsdp tier (grads reduce-scatter via AD)
        if "data" in names:
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        out_loc = _expert_ffn(cfg, recv, wg, wu, wd)  # (E_loc, ep·C, D)
        # combine a2a: back to (E_pad, C, D) for my token slice
        back = jax.lax.all_to_all(out_loc, "model", split_axis=1,
                                  concat_axis=0, tiled=True)
        back = checkpoint_name(back, "moe_combine")
        back = jnp.concatenate(
            [back, jnp.zeros((e_pad, 1, d), back.dtype)], axis=1)  # dump slot
        gathered = back[flat_idx, slot]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        combined = jnp.sum(
            (gathered * flat_gate[:, None].astype(gathered.dtype))
            .reshape(t_slice, k, d), axis=1)
        # reassemble the full local token set (cheap: t_slice·D)
        combined = jax.lax.all_gather(combined, "model", axis=0, tiled=True)
        aux = jax.lax.pmean(aux, "model")
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return combined.reshape(bl, l, d), aux

    batch_spec = P(dp if dp else None, None, None)
    out, aux = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(batch_spec, P(None, None),
                  P("model", "data" if "data" in names else None, None),
                  P("model", "data" if "data" in names else None, None),
                  P("model", None, "data" if "data" in names else None)),
        out_specs=(batch_spec, P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


def _axsize(mesh, name):
    return dict(mesh.shape).get(name, 1)


def _fit_batch_axes(mesh, b, candidates):
    axes = []
    prod = 1
    for a in candidates:
        s = _axsize(mesh, a)
        if s > 1 and b % (prod * s) == 0:
            axes.append(a)
            prod *= s
    return tuple(axes)


def moe(p, cfg: ModelConfig, x):
    """x: (B, L, D) → (out, aux_loss).  Dispatches to the expert-parallel
    shard_map path under a mesh with a "model" axis (and enough tokens),
    else the dense reference path."""
    mesh = compat.get_abstract_mesh()
    use_ep = (mesh is not None and not mesh.empty
              and "model" in mesh.axis_names
              and cfg.num_experts_padded % _axsize(mesh, "model") == 0
              and x.shape[0] * x.shape[1] >= 4096)
    if use_ep:
        out, aux = _moe_ep(p, cfg, x, mesh)
    else:
        out, aux = _moe_dense(p, cfg, x)
    if "shared" in p:
        out = out + mlp(p["shared"], cfg, x)
    return out, aux
