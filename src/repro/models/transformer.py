"""Model assembly: decoder-only LM and encoder-decoder, built from
ModelConfig super-blocks and executed as ``lax.scan`` over stacked layer
parameters (compile-time O(1) in depth).

Public API:
    init_model(key, cfg)                       → params
    forward(params, cfg, tokens/embeds, ...)   → (logits, aux)   [training]
    init_cache(cfg, batch, max_seq, dtype)     → cache pytree
    decode_step(params, cfg, token, pos, cache, memory) → (logits, cache)
    encode(params, cfg, embeds/tokens)         → memory            [enc-dec]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FULL_ATTENTION, LayerSpec, ModelConfig
from repro.launch.sharding import BATCH, MODEL, seq_ax, shard
from repro.models import layers as L
from repro.models import ssm as S


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, cross: bool):
    ks = jax.random.split(key, 8)
    pdt = jnp.dtype(cfg.param_dtype)
    p = {"pre_norm": L.init_rms_norm(cfg.d_model, pdt)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = S.init_mamba(ks[0], cfg)
    elif spec.mixer == "mlstm":
        p["mlstm"] = S.init_mlstm(ks[0], cfg)
    elif spec.mixer == "slstm":
        p["slstm"] = S.init_slstm(ks[0], cfg)
    if cross:
        p["cross_norm"] = L.init_rms_norm(cfg.d_model, pdt)
        p["cross_attn"] = L.init_attention(ks[1], cfg, cross=True)
    if spec.ffn != "none":
        p["ffn_norm"] = L.init_rms_norm(cfg.d_model, pdt)
        if spec.ffn == "moe":
            p["moe"] = L.init_moe(ks[2], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[2], cfg)
    return p


def _apply_layer(p, cfg, spec, h, positions, window, theta, cache, cache_pos,
                 memory, causal=True, collect_cache=False, block_tables=None,
                 paged_kernel=False):
    """One (mixer → [cross] → ffn) layer. Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = L.rms_norm(h, p["pre_norm"], cfg.norm_eps)
    if spec.mixer == "attn":
        if block_tables is not None:
            out, new_cache = L.attention_paged(
                p["attn"], cfg, x, positions, window, theta, cache,
                block_tables, use_kernel=paged_kernel)
        else:
            out, new_cache = L.attention(
                p["attn"], cfg, x, positions, window, theta, cache=cache,
                cache_pos=cache_pos, causal=causal,
                collect_cache=collect_cache)
    elif spec.mixer == "mamba":
        out, new_cache = S.mamba(p["mamba"], cfg, x, cache=cache,
                                 collect_cache=collect_cache)
    elif spec.mixer == "mlstm":
        out, new_cache = S.mlstm(p["mlstm"], cfg, x, cache=cache,
                                 collect_cache=collect_cache)
    elif spec.mixer == "slstm":
        out, new_cache = S.slstm(p["slstm"], cfg, x, cache=cache,
                                 collect_cache=collect_cache)
    else:
        out, new_cache = jnp.zeros_like(h), cache
    h = h + out

    if "cross_attn" in p and memory is not None:
        x = L.rms_norm(h, p["cross_norm"], cfg.norm_eps)
        out, _ = L.attention(p["cross_attn"], cfg, x, positions, window,
                             theta, memory=memory)
        h = h + out

    if spec.ffn != "none":
        x = L.rms_norm(h, p["ffn_norm"], cfg.norm_eps)
        if spec.ffn == "moe":
            out, aux = L.moe(p["moe"], cfg, x)
        else:
            out = L.mlp(p["mlp"], cfg, x)
        h = h + out
    return h, new_cache, aux


def _init_layer_cache(cfg, spec, batch, max_seq, dtype):
    if spec.mixer == "attn":
        return L.init_attn_cache(cfg, batch, max_seq, dtype)
    if spec.mixer == "mamba":
        return S.init_mamba_cache(cfg, batch, dtype)
    if spec.mixer == "mlstm":
        return S.init_mlstm_cache(cfg, batch, dtype)
    if spec.mixer == "slstm":
        return S.init_slstm_cache(cfg, batch, dtype)
    return {}


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------
def init_model(key, cfg: ModelConfig):
    specs, repeat = cfg.superblock()
    pdt = jnp.dtype(cfg.param_dtype)
    k_emb, k_stack, k_enc, k_head = jax.random.split(key, 4)

    def init_superblock(k):
        ks = jax.random.split(k, len(specs))
        return {str(i): _init_layer(ks[i], cfg, spec, cross=cfg.is_encoder_decoder)
                for i, spec in enumerate(specs)}

    params = {
        "embed": L.dense_init(k_emb, (cfg.vocab_size, cfg.d_model), pdt),
        "stack": jax.vmap(init_superblock)(jax.random.split(k_stack, repeat)),
        "final_norm": L.init_rms_norm(cfg.d_model, pdt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size), pdt)
    if cfg.is_encoder_decoder:
        enc_spec = LayerSpec(mixer="attn", ffn="mlp")

        def init_enc_layer(k):
            return _init_layer(k, cfg, enc_spec, cross=False)

        params["encoder"] = {
            "stack": jax.vmap(init_enc_layer)(
                jax.random.split(k_enc, cfg.num_encoder_layers)),
            "final_norm": L.init_rms_norm(cfg.d_model, pdt),
        }
    return params


# ---------------------------------------------------------------------------
# stack traversal (shared by training forward and decode)
# ---------------------------------------------------------------------------
def _run_stack(params, cfg: ModelConfig, h, positions, cache, cache_pos,
               memory, remat=False, collect_cache=False, block_tables=None,
               paged_kernel=False):
    specs, repeat = cfg.superblock()
    np_windows, np_thetas = cfg.layer_windows()  # (repeat, S) numpy arrays
    windows = jnp.asarray(np_windows)
    thetas = jnp.asarray(np_thetas)

    def superblock_body(carry, xs):
        h, aux_acc = carry
        p_sb, win_sb, th_sb, cache_sb = xs
        new_cache_sb = {}
        for i, spec in enumerate(specs):
            c_i = cache_sb[str(i)] if cache_sb is not None else None
            h, nc, aux = _apply_layer(
                p_sb[str(i)], cfg, spec, h, positions, win_sb[i], th_sb[i],
                c_i, cache_pos, memory, collect_cache=collect_cache,
                block_tables=block_tables, paged_kernel=paged_kernel)
            new_cache_sb[str(i)] = nc if nc is not None else {}
        return (h, aux_acc + aux), new_cache_sb

    if remat:
        if cfg.save_moe_a2a:
            # save the named MoE a2a results across the remat boundary:
            # −2 a2a/layer of wire, +~2.7 GB/layer of HBM (see §Perf it. 2)
            policy = jax.checkpoint_policies.save_only_these_names(
                "moe_dispatch", "moe_combine")
            body = jax.checkpoint(superblock_body, policy=policy)
        else:
            body = jax.checkpoint(superblock_body)
    else:
        body = superblock_body

    if cfg.scan_layers:
        (h, aux), new_cache = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)),
            (params["stack"], windows, thetas, cache))
    else:  # unrolled: exact cost_analysis for the dry-run.  Window/theta are
        # STATIC python scalars (closed over, NOT traced) so sliding-window
        # layers take the block-banded attention path (compute ∝ window).
        carry = (h, jnp.zeros((), jnp.float32))
        collected = []
        for r in range(repeat):
            p_r = jax.tree.map(lambda x: x[r], params["stack"])
            c_r = jax.tree.map(lambda x: x[r], cache) if cache is not None else None
            win_r = tuple(int(w) for w in np_windows[r])
            th_r = tuple(float(t) for t in np_thetas[r])

            def body_r(carry, pc, _w=win_r, _t=th_r):
                return superblock_body(carry, (pc[0], _w, _t, pc[1]))

            body_r = jax.checkpoint(body_r) if remat else body_r
            carry, nc = body_r(carry, (p_r, c_r))
            collected.append(nc)
        h, aux = carry
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *collected) \
            if collected and (cache is not None or collect_cache) else None
    if cache is None and not collect_cache:
        new_cache = None
    return h, aux, new_cache


def _logits(params, cfg, h):
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.sharding_mode == "cp":
        # gather the (seq-sharded) stream once at the head so the vocab
        # projection stays TP-sharded — otherwise the (V, D) embed/lm_head
        # gradient is replicated and all-reduced densely (§Perf h2 it. 2)
        h = shard(h, BATCH, None, None)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bld,vd->blv", h, params["embed"])
    else:
        logits = jnp.einsum("bld,dv->blv", h, params["lm_head"])
    return shard(logits, BATCH, None, MODEL).astype(jnp.float32)


def _embed(params, cfg, tokens=None, embeds=None):
    if embeds is not None:
        h = embeds.astype(jnp.dtype(cfg.compute_dtype))
    else:
        h = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype) if cfg.qk_norm else h
    return shard(h, BATCH, seq_ax(cfg), None)


def _cast_compute(params, cfg: ModelConfig):
    """Weights → ``compute_dtype`` at the forward boundary (DESIGN.md §4).

    Matmuls and activations run in the compute dtype; loss, softmax and
    norm statistics still accumulate in f32 inside the layers.  A no-op
    when ``param_dtype == compute_dtype`` (every preset policy), so the
    f32 path is untouched; with f32 storage + bf16 compute this is the
    classic AMP cast, and AD transposes it so gradients flow back in the
    storage dtype."""
    cdt = jnp.dtype(cfg.compute_dtype)
    if jnp.dtype(cfg.param_dtype) == cdt:
        return params
    return jax.tree.map(
        lambda w: w.astype(cdt)
        if jnp.issubdtype(w.dtype, jnp.floating) else w, params)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None,
            memory=None, remat=False):
    """Training/prefill forward pass. Returns (logits, aux_loss)."""
    params = _cast_compute(params, cfg)
    h = _embed(params, cfg, tokens, embeds)
    b, l = h.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))
    if cfg.is_encoder_decoder and memory is None:
        raise ValueError("encoder-decoder model requires encoder `memory`")
    h, aux, _ = _run_stack(params, cfg, h, positions, None, None, memory,
                           remat=remat)
    return _logits(params, cfg, h), aux


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, memory=None,
            last_only=False):
    """Full-sequence forward that also returns a populated decode cache
    (inference prefill).  Returns (logits, cache); ``last_only`` projects
    only the final position (what a real prefill needs — avoids the
    (B, L, V) logits tensor)."""
    params = _cast_compute(params, cfg)
    h = _embed(params, cfg, tokens, embeds)
    b, l = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))
    h, _, cache = _run_stack(params, cfg, h, positions, None, None, memory,
                             collect_cache=True)
    if last_only:
        h = h[:, -1:]
    return _logits(params, cfg, h), cache


def encode(params, cfg: ModelConfig, embeds=None, tokens=None):
    """Encoder pass (enc-dec models): bidirectional self-attention stack."""
    params = _cast_compute(params, cfg)
    enc = params["encoder"]
    h = _embed(params, cfg, tokens, embeds)
    b, l = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32), (b, l))
    spec = LayerSpec(mixer="attn", ffn="mlp")

    def body(carry, p_layer):
        h, _ = carry
        h, _, _ = _apply_layer(p_layer, cfg, spec, h, positions,
                               jnp.int32(FULL_ATTENTION),
                               jnp.float32(cfg.rope_theta),
                               None, None, None, causal=False)
        return (h, 0.0), None

    (h, _), _ = jax.lax.scan(body, (h, 0.0), enc["stack"])
    return L.rms_norm(h, enc["final_norm"], cfg.norm_eps)


def init_cache(cfg: ModelConfig, batch, max_seq, dtype=None):
    """Decode cache, stacked (repeat, ...) to ride the same scan."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    specs, repeat = cfg.superblock()

    def one(spec):
        return _init_layer_cache(cfg, spec, batch, max_seq, dtype)

    sb = {str(i): one(spec) for i, spec in enumerate(specs)}
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (repeat,) + x.shape).copy()
                        if hasattr(x, "shape") else x, sb)


def pad_prefill_cache(cfg: ModelConfig, cache, total):
    """Grow a ``prefill``-collected cache (attention S = prompt length) to
    ``total`` sequence slots.  The pad is keyed off the cache LAYOUT — only
    attention layers' k/v leaves get padded, along their sequence axis
    (axis 2 of the stacked (repeat, B, S, KV, Dh)) — never off shape
    coincidence, so a recurrent leaf whose trailing dim happens to equal
    the prompt length is left alone."""
    specs, _ = cfg.superblock()
    out = dict(cache)
    for i, spec in enumerate(specs):
        if spec.mixer != "attn":
            continue

        def pad(x):
            lp = x.shape[2]
            if lp >= total:
                return x
            w = [(0, 0)] * x.ndim
            w[2] = (0, total - lp)
            return jnp.pad(x, w)

        out[str(i)] = jax.tree.map(pad, cache[str(i)])
    return out


def init_paged_cache(cfg: ModelConfig, num_pages, page_size, dtype=None):
    """Paged decode cache (serving tier): per-layer k/v page pools, stacked
    (repeat, ...) to ride the same layer scan as ``init_cache``.  Physical
    page 0 is the reserved trash page.  Attention-only decoder stacks —
    recurrent mixers keep per-slot dense state and stay on the dense
    engine."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    specs, repeat = cfg.superblock()
    if cfg.is_encoder_decoder:
        raise ValueError("paged cache does not support encoder-decoder models")
    for spec in specs:
        if spec.mixer not in ("attn", "none"):
            raise ValueError(
                f"paged cache supports attention-only stacks; got mixer "
                f"{spec.mixer!r} (use the dense DecodeEngine)")
    sb = {str(i): L.init_paged_attn_cache(cfg, num_pages, page_size, dtype)
          for i in range(len(specs))}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (repeat,) + x.shape).copy(), sb)


def decode_step_paged(params, cfg: ModelConfig, token, pos, cache,
                      block_tables, use_kernel=False):
    """One decode token per slot against the paged cache.  token: (B,)
    int32; pos: (B,) int32 token position per slot, -1 ⇒ idle (the write
    goes to trash page 0, the logits row is garbage — caller masks it);
    block_tables: (B, pages_per_seq) int32.  ``use_kernel`` (static)
    routes attention through the Pallas paged kernel; off, the jnp gather
    path.  Returns (logits (B, V) f32, new_cache)."""
    params = _cast_compute(params, cfg)
    h = _embed(params, cfg, tokens=jnp.maximum(token, 0)[:, None])
    positions = pos[:, None].astype(jnp.int32)
    h, _, new_cache = _run_stack(params, cfg, h, positions, cache, None,
                                 None, block_tables=block_tables,
                                 paged_kernel=use_kernel)
    return _logits(params, cfg, h)[:, 0], new_cache


def prefill_chunk_paged(params, cfg: ModelConfig, tokens, positions, cache,
                        block_tables, last_idx):
    """Chunked batched prefill: consume a whole (B, C) chunk of prompt
    tokens per step, writing KV straight into the pages (write-then-
    attend, so in-chunk causality needs no dense pass).  positions: (B, C)
    int32, -1 ⇒ pad; last_idx: (B,) int32 index of each row's last REAL
    token in the chunk (clamped for idle rows).  Returns (logits (B, V)
    f32 — next-token logits at last_idx, new_cache)."""
    params = _cast_compute(params, cfg)
    h = _embed(params, cfg, tokens=jnp.maximum(tokens, 0))
    h, _, new_cache = _run_stack(params, cfg, h,
                                 positions.astype(jnp.int32), cache, None,
                                 None, block_tables=block_tables)
    b = tokens.shape[0]
    hl = h[jnp.arange(b), jnp.maximum(last_idx, 0)][:, None]
    return _logits(params, cfg, hl)[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, token=None, pos=None, cache=None,
                memory=None, embeds=None):
    """One-token decode against a KV/state cache.  token: (B,) int32;
    pos: scalar int32 write position, or (B,) int32 for ragged slots
    (continuous batching). Returns (logits (B, V), new_cache)."""
    params = _cast_compute(params, cfg)
    if embeds is None:
        h = _embed(params, cfg, tokens=token[:, None])
    else:
        h = embeds
    b = h.shape[0]
    if hasattr(pos, "ndim") and pos.ndim == 1:
        positions = pos[:, None].astype(jnp.int32)
    else:
        positions = jnp.full((b, 1), pos, jnp.int32)
    h, _, new_cache = _run_stack(params, cfg, h, positions, cache, pos, memory)
    return _logits(params, cfg, h)[:, 0], new_cache
