"""State-space & recurrent mixers: Mamba (selective SSM, chunked associative
scan), and xLSTM's mLSTM / sLSTM blocks.

TPU adaptation notes (DESIGN.md §2): the CUDA "hardware-aware" fused scan of
the Mamba paper is realized here as a *chunked* ``lax.associative_scan`` —
time is processed in VMEM-sized chunks (cfg.ssm_chunk) with an O(1) carry
between chunks, which bounds the materialized (B, chunk, d_inner, N) tensor
instead of the full (B, L, d_inner, N).  mLSTM uses the quadratic parallel
form for training (it is attention-shaped, MXU-friendly) and the O(1)
recurrent form for decode.  sLSTM is inherently sequential (recurrent weight
matrix) and uses ``lax.scan`` over time.

Precision contract (core/precision.py, DESIGN.md §4): recurrences are
where low-precision error compounds, so every carried state is f32 by
construction regardless of ``compute_dtype`` — the Mamba discretization
(dA, B·u) and chunked scan, the mLSTM (C, n, m) matrix memory and its
log-space gate stabilizers, and the sLSTM cell state all accumulate in
f32; only the projections in and out run in the compute dtype.  Decode
caches keep their recurrent leaves f32 even when the KV cache is bf16
(``init_*_cache`` takes the narrow dtype for activations only).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import BATCH, MODEL, shard
from repro.models.layers import _dtype, dense_init, init_rms_norm, rms_norm

NEG_INF = -2.0e38


# ===========================================================================
# Mamba (S6) block
# ===========================================================================
def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    kconv = cfg.ssm_conv_dim
    dt_rank = max(1, d // 16)
    pdt = _dtype(cfg, "param")
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), pdt),
        "conv_w": dense_init(ks[1], (kconv, d_in), pdt),
        "conv_b": jnp.zeros((d_in,), pdt),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * n), pdt),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), pdt),
        "dt_bias": jnp.zeros((d_in,), pdt),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                          (d_in, n)).astype(pdt)),
        "D": jnp.ones((d_in,), pdt),
        "out_proj": dense_init(ks[4], (d_in, d), pdt),
    }


def _mamba_bcdt(p, cfg, u):
    """u: (..., d_in) → (delta, B, C) with shapes (..., d_in), (..., N), (..., N)."""
    n = cfg.ssm_state_dim
    dbl = u @ p["x_proj"]  # (..., dt_rank + 2N)
    dt_rank = dbl.shape[-1] - 2 * n
    dt, b, c = dbl[..., :dt_rank], dbl[..., dt_rank:dt_rank + n], dbl[..., dt_rank + n:]
    delta = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])  # (..., d_in)
    return delta, b, c


def _causal_conv(p, u, conv_state=None):
    """Depthwise causal conv over time.  u: (B, L, d_in)."""
    k = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)  # (B, L+k-1, d_in)
    out = sum(full[:, i:i + u.shape[1], :] * p["conv_w"][i] for i in range(k))
    new_state = full[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(out + p["conv_b"]), new_state


def _selective_scan_chunk(a, bu, h0):
    """Within-chunk associative scan.  a, bu: (B, c, d_in, N); h0: (B, d_in, N)."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_, b_ = jax.lax.associative_scan(combine, (a, bu), axis=1)
    h = a_ * h0[:, None] + b_  # (B, c, d_in, N)
    return h, h[:, -1]


def mamba(p, cfg: ModelConfig, x, cache=None, collect_cache=False):
    """x: (B, L, D) → (out, new_cache).  cache = {"conv": (B,k-1,d_in), "ssm": (B,d_in,N)}."""
    b, l, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    xz = x @ p["in_proj"]  # (B, L, 2*d_in)
    u, z = xz[..., :d_in], xz[..., d_in:]
    u = shard(u, BATCH, None, MODEL)

    a_mat = -jnp.exp(p["A_log"].astype(jnp.float32))  # (d_in, N)

    if cache is None:
        u_pre = u  # pre-conv activations (tail feeds the decode conv state)
        u, _ = _causal_conv(p, u)
        delta, bb, cc = _mamba_bcdt(p, cfg, u)
        # discretize: abar = exp(delta * A); bbar*u = delta * u * B
        dA = delta.astype(jnp.float32)[..., None] * a_mat  # (B,L,d_in,N)
        abar = jnp.exp(dA)
        bu = (delta * u).astype(jnp.float32)[..., None] * bb.astype(jnp.float32)[..., None, :]

        chunk = min(cfg.ssm_chunk, l)
        if l % chunk:
            chunk = l  # fall back: single chunk (smoke tests with odd L)
        nchunks = l // chunk
        abar = abar.reshape(b, nchunks, chunk, d_in, n)
        bu = bu.reshape(b, nchunks, chunk, d_in, n)

        def step(h0, xs):
            ac, bc = xs  # (B, chunk, d_in, N)
            hs, hlast = _selective_scan_chunk(ac, bc, h0)
            return hlast, hs

        h0 = jnp.zeros((b, d_in, n), jnp.float32)
        _, hs = jax.lax.scan(step, h0,
                             (abar.swapaxes(0, 1), bu.swapaxes(0, 1)))
        hs = hs.swapaxes(0, 1).reshape(b, l, d_in, n)
        y = jnp.einsum("bldn,bln->bld", hs, cc.astype(jnp.float32))
        y = y + p["D"].astype(jnp.float32) * u.astype(jnp.float32)
        new_cache = None
        if collect_cache:  # prefill: expose final recurrent + conv state
            kconv = cfg.ssm_conv_dim
            new_cache = {"conv": u_pre[:, -(kconv - 1):, :] if kconv > 1 else
                         jnp.zeros((b, 0, d_in), u_pre.dtype),
                         "ssm": hs[:, -1]}
    else:
        # single-token decode: O(1) state update
        u1, conv_state = _causal_conv(p, u, cache["conv"])
        delta, bb, cc = _mamba_bcdt(p, cfg, u1)
        dA = delta.astype(jnp.float32)[..., None] * a_mat  # (B,1,d_in,N)
        abar = jnp.exp(dA)[:, 0]
        bu = (delta * u1).astype(jnp.float32)[..., None] * bb.astype(jnp.float32)[..., None, :]
        h = abar * cache["ssm"] + bu[:, 0]  # (B, d_in, N)
        y = jnp.einsum("bdn,bn->bd", h, cc[:, 0].astype(jnp.float32))[:, None]
        y = y + p["D"].astype(jnp.float32) * u1.astype(jnp.float32)
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}

    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = y @ p["out_proj"]
    return shard(out, BATCH, None, None), new_cache


def init_mamba_cache(cfg: ModelConfig, batch, dtype):
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, cfg.ssm_state_dim), jnp.float32),
    }


# ===========================================================================
# mLSTM block (xLSTM): matrix memory, exponential gating.
# ===========================================================================
def init_mlstm(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    dh = (cfg.ssm_expand * d) // h
    pdt = _dtype(cfg, "param")
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h, dh), pdt),
        "wk": dense_init(ks[1], (d, h, dh), pdt),
        "wv": dense_init(ks[2], (d, h, dh), pdt),
        "w_igate": dense_init(ks[3], (d, h), pdt),
        "w_fgate": dense_init(ks[4], (d, h), pdt),
        "fgate_bias": jnp.full((h,), 3.0, pdt),  # init toward remembering
        "out_norm": init_rms_norm(h * dh, pdt),
        "out_proj": dense_init(ks[5], (h * dh, d), pdt),
    }


def mlstm(p, cfg: ModelConfig, x, cache=None, collect_cache=False):
    """x: (B,L,D).  Training: parallel quadratic form.  Decode: recurrent."""
    b, l, d = x.shape
    h = cfg.num_heads
    dh = (cfg.ssm_expand * d) // h
    q = jnp.einsum("bld,dhk->bhlk", x, p["wq"]) * dh ** -0.5
    k = jnp.einsum("bld,dhk->bhlk", x, p["wk"]) * dh ** -0.5
    v = jnp.einsum("bld,dhk->bhlk", x, p["wv"])
    logi = (x @ p["w_igate"]).swapaxes(1, 2).astype(jnp.float32)  # (B,H,L)
    logf = jax.nn.log_sigmoid(
        (x @ p["w_fgate"]).swapaxes(1, 2).astype(jnp.float32)
        + p["fgate_bias"].astype(jnp.float32)[None, :, None])

    if cache is None:
        # D_ij = sum_{s=j+1..i} logf_s + logi_j  (j <= i)
        cumf = jnp.cumsum(logf, axis=-1)  # (B,H,L)
        dmat = cumf[..., :, None] - cumf[..., None, :] + logi[..., None, :]
        causal = jnp.tril(jnp.ones((l, l), bool))
        dmat = jnp.where(causal, dmat, NEG_INF)
        m = jnp.max(dmat, axis=-1, keepdims=True)  # (B,H,L,1) stabilizer
        dexp = jnp.exp(dmat - m)
        s = jnp.einsum("bhlk,bhsk->bhls", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * dexp
        norm = jnp.maximum(jnp.abs(jnp.sum(s, axis=-1, keepdims=True)),
                           jnp.exp(-m))
        out = jnp.einsum("bhls,bhsk->bhlk", s / norm, v.astype(jnp.float32))
        new_cache = None
        if collect_cache:
            # final recurrent state from the parallel form:
            # d_j = Σ_{s>j} logf_s + logi_j ; C_L = Σ_j e^{d_j − m} v_j k_jᵀ
            dj = cumf[..., -1:] - cumf + logi  # (B,H,L)
            m_fin = jnp.max(dj, axis=-1)  # (B,H)
            w_ = jnp.exp(dj - m_fin[..., None])  # (B,H,L)
            kf = k.astype(jnp.float32)
            vf = v.astype(jnp.float32)
            C_fin = jnp.einsum("bhl,bhlv,bhlk->bhvk", w_, vf, kf)
            n_fin = jnp.einsum("bhl,bhlk->bhk", w_, kf)
            new_cache = {"C": C_fin, "n": n_fin, "m": m_fin}
    else:
        # recurrent: C ← f C + i v kᵀ ; n ← f n + i k ; h = (Cᵀ q)/max(|n·q|, e⁻ᵐ)
        C, nvec, m0 = cache["C"], cache["n"], cache["m"]  # (B,H,dh,dh),(B,H,dh),(B,H)
        logi0, logf0 = logi[..., 0], logf[..., 0]  # (B,H)
        m1 = jnp.maximum(logf0 + m0, logi0)
        fp = jnp.exp(logf0 + m0 - m1)[..., None]
        ip = jnp.exp(logi0 - m1)[..., None]
        k0 = k[:, :, 0].astype(jnp.float32)
        v0 = v[:, :, 0].astype(jnp.float32)
        q0 = q[:, :, 0].astype(jnp.float32)
        C = fp[..., None] * C + ip[..., None] * (v0[..., :, None] * k0[..., None, :])
        nvec = fp * nvec + ip * k0
        num = jnp.einsum("bhvk,bhk->bhv", C, q0)
        den = jnp.maximum(jnp.abs(jnp.sum(nvec * q0, axis=-1)), jnp.exp(-m1))
        out = (num / den[..., None])[:, :, None, :]  # (B,H,1,dh)
        new_cache = {"C": C, "n": nvec, "m": m1}

    out = out.swapaxes(1, 2).reshape(b, -1, h * dh).astype(x.dtype)
    out = rms_norm(out, p["out_norm"], cfg.norm_eps)
    return out @ p["out_proj"], new_cache


def init_mlstm_cache(cfg: ModelConfig, batch, dtype):
    h = cfg.num_heads
    dh = (cfg.ssm_expand * cfg.d_model) // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


# ===========================================================================
# sLSTM block (xLSTM): scalar memory, recurrent weights — sequential scan.
# ===========================================================================
def init_slstm(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    pdt = _dtype(cfg, "param")
    ks = jax.random.split(key, 3)
    return {
        "W": dense_init(ks[0], (d, 4 * d), pdt),  # i, f, z, o from x
        "R": dense_init(ks[1], (h, dh, 4 * dh), pdt),  # block-diag recurrence
        "b": jnp.concatenate([jnp.zeros((d,), pdt),
                              jnp.full((d,), 3.0, pdt),  # forget bias
                              jnp.zeros((2 * d,), pdt)]),
        "out_proj": dense_init(ks[2], (d, d), pdt),
    }


def _slstm_cell(p, cfg, xw, state):
    """xw: (B, 4D) pre-computed x @ W + b; state: dict of (B, D)."""
    b = xw.shape[0]
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    c, n, hid, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhk,hkj->bhj", hid.reshape(b, h, dh).astype(jnp.float32),
                     p["R"].astype(jnp.float32)).reshape(b, 4 * d)
    g = xw.astype(jnp.float32) + rec
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    logf = jax.nn.log_sigmoid(gf)
    m1 = jnp.maximum(logf + m, gi)
    ip = jnp.exp(gi - m1)
    fp = jnp.exp(logf + m - m1)
    c1 = fp * c + ip * jnp.tanh(gz)
    n1 = fp * n + ip
    h1 = jax.nn.sigmoid(go) * c1 / jnp.maximum(n1, 1.0)
    return {"c": c1, "n": n1, "h": h1, "m": m1}


def slstm(p, cfg: ModelConfig, x, cache=None, collect_cache=False):
    """x: (B, L, D) → (out, new_cache)."""
    b, l, d = x.shape
    xw = x @ p["W"] + p["b"]  # (B, L, 4D)

    if cache is None:
        state = init_slstm_cache(cfg, b, jnp.float32)

        def step(st, xt):
            st1 = _slstm_cell(p, cfg, xt, st)
            return st1, st1["h"]

        final, hs = jax.lax.scan(step, state, xw.swapaxes(0, 1))
        out = hs.swapaxes(0, 1).astype(x.dtype)  # (B, L, D)
        new_cache = final if collect_cache else None
    else:
        st1 = _slstm_cell(p, cfg, xw[:, 0], cache)
        out = st1["h"][:, None].astype(x.dtype)
        new_cache = st1
    return out @ p["out_proj"], new_cache


def init_slstm_cache(cfg: ModelConfig, batch, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}
