"""Deterministic synthetic data pipeline.

The paper's data parallelism partitions the dataset among workers
(§2: "distributing partitions of training data among workers").  This
pipeline gives every (worker, step) a *disjoint, reproducible* shard with
no host I/O: batches are generated on device from a folded PRNG key.

The token stream is learnable, not uniform noise: with probability
``structure`` the next token is the affine successor  x' = (a·x + b) mod V,
else uniform.  A model that learns the successor reaches
H ≈ s·log V·(1−s)… well below log V — so convergence benchmarks
(benchmarks/bench_strategies.py) have signal to distinguish strategies,
which is exactly what the paper's §3 experiments need.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_per_worker: int
    structure: float = 0.9  # P(next = successor)
    a: int = 31
    b: int = 7
    seed: int = 0
    # tokens are drawn from [0, active_vocab): a small active set makes the
    # task learnable within a few hundred steps at large model/vocab scale
    # (the embedding table only needs active_vocab live rows)
    active_vocab: int = 0  # 0 ⇒ full vocab

    @property
    def v_act(self) -> int:
        return self.active_vocab or self.vocab_size


def _successor(x, cfg: DataConfig):
    return (cfg.a * x + cfg.b) % cfg.v_act


def _sample_batch(cfg: DataConfig, worker, step):
    """Traceable core of ``sample_batch`` (worker/step may be traced)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), worker), step)
    k0, k1, k2 = jax.random.split(key, 3)
    b, l, v = cfg.batch_per_worker, cfg.seq_len, cfg.v_act
    start = jax.random.randint(k0, (b,), 0, v)
    noise = jax.random.randint(k1, (b, l), 0, v)
    coin = jax.random.bernoulli(k2, cfg.structure, (b, l))

    def step_fn(x, inputs):
        nz, cn = inputs
        nxt = jnp.where(cn, _successor(x, cfg), nz)
        return nxt, nxt

    _, toks = jax.lax.scan(step_fn, start,
                           (noise.swapaxes(0, 1), coin.swapaxes(0, 1)))
    return toks.swapaxes(0, 1).astype(jnp.int32)  # (b, l)


@partial(jax.jit, static_argnames=("cfg",))
def sample_batch(cfg: DataConfig, worker, step):
    """(batch_per_worker, seq_len) int32, deterministic in (seed, worker,
    step).  Jitted ONCE per (hashable, frozen) config: ``worker`` and
    ``step`` are traced operands, so per-step synthesis neither retraces
    nor re-dispatches op-by-op — and its dispatch is async, which is what
    lets ``prefetch_batches`` synthesize batch t+1 while step t runs."""
    return _sample_batch(cfg, worker, step)


@partial(jax.jit, static_argnames=("cfg", "n_workers"))
def worker_batches(cfg: DataConfig, n_workers: int, step):
    """Stacked (W, batch_per_worker, seq_len) — LocalComm layout.  One
    trace per (cfg, W); the per-worker streams are vmapped, not looped."""
    return jax.vmap(lambda w: _sample_batch(cfg, w, step))(
        jnp.arange(n_workers))


@partial(jax.jit, static_argnames=("cfg", "n_workers", "accum_steps"))
def microbatch_stack(cfg: DataConfig, n_workers: int, opt_step,
                     accum_steps: int):
    """(accum_steps, W, batch_per_worker, seq_len): the microbatch input of
    one accumulation boundary (train/loop.py, DESIGN.md §8).

    Microbatch j of optimizer step T draws the data of plain step
    ``T*accum_steps + j`` — the token stream is IDENTICAL to running
    ``accum_steps`` unaccumulated steps, which is what the equivalence
    sweep in tests/test_accum.py relies on."""
    steps = opt_step * accum_steps + jnp.arange(accum_steps)
    return jax.vmap(lambda s: jax.vmap(
        lambda w: _sample_batch(cfg, w, s))(jnp.arange(n_workers)))(steps)


def global_batch(cfg: DataConfig, step: int, global_batch_size: int):
    """One flat global batch (production path); workers' shards concatenated."""
    n = global_batch_size // cfg.batch_per_worker
    ws = worker_batches(cfg, n, step)
    return ws.reshape(global_batch_size, cfg.seq_len)


def prefetch_batches(cfg: DataConfig, n_workers: int, steps: int,
                     accum_steps: int = 1, depth: int = 2):
    """Double-buffered device prefetch: yields ``(t, batch)`` for ``steps``
    optimizer steps, keeping up to ``depth`` batches in flight.

    Batch synthesis is a jitted on-device program whose dispatch is async,
    so enqueueing batch t+1 BEFORE the consumer blocks on step t's result
    overlaps host-side synthesis/dispatch with device compute — the
    classic double buffer at ``depth=2``.  ``jax.device_put`` makes the
    device placement explicit (and covers host-resident arrays if a
    caller swaps in a host pipeline).  ``depth=1`` degrades to the old
    synchronous order."""
    depth = max(1, depth)
    q: deque = deque()

    def synth(t):
        if accum_steps > 1:
            b = microbatch_stack(cfg, n_workers, t, accum_steps)
        else:
            b = worker_batches(cfg, n_workers, t)
        return jax.device_put(b)

    for t in range(steps):
        q.append((t, synth(t)))
        while len(q) >= depth:
            yield q.popleft()
    while q:
        yield q.popleft()


def bayes_entropy(cfg: DataConfig) -> float:
    """Entropy of the generating process (loss floor for a perfect model)."""
    s, v = cfg.structure, cfg.v_act
    # next ~ s·δ(successor) + (1−s)·uniform; the successor bucket gets s+(1−s)/V
    p_succ = s + (1 - s) / v
    p_other = (1 - s) / v
    return float(-(p_succ * np.log(p_succ) + (v - 1) * p_other * np.log(p_other)))
