"""Deterministic synthetic data pipeline.

The paper's data parallelism partitions the dataset among workers
(§2: "distributing partitions of training data among workers").  This
pipeline gives every (worker, step) a *disjoint, reproducible* shard with
no host I/O: batches are generated on device from a folded PRNG key.

The token stream is learnable, not uniform noise: with probability
``structure`` the next token is the affine successor  x' = (a·x + b) mod V,
else uniform.  A model that learns the successor reaches
H ≈ s·log V·(1−s)… well below log V — so convergence benchmarks
(benchmarks/bench_strategies.py) have signal to distinguish strategies,
which is exactly what the paper's §3 experiments need.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_per_worker: int
    structure: float = 0.9  # P(next = successor)
    a: int = 31
    b: int = 7
    seed: int = 0
    # tokens are drawn from [0, active_vocab): a small active set makes the
    # task learnable within a few hundred steps at large model/vocab scale
    # (the embedding table only needs active_vocab live rows)
    active_vocab: int = 0  # 0 ⇒ full vocab

    @property
    def v_act(self) -> int:
        return self.active_vocab or self.vocab_size


def _successor(x, cfg: DataConfig):
    return (cfg.a * x + cfg.b) % cfg.v_act


def sample_batch(cfg: DataConfig, worker: int, step: int):
    """(batch_per_worker, seq_len) int32, deterministic in (seed, worker, step)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), worker), step)
    k0, k1, k2 = jax.random.split(key, 3)
    b, l, v = cfg.batch_per_worker, cfg.seq_len, cfg.v_act
    start = jax.random.randint(k0, (b,), 0, v)
    noise = jax.random.randint(k1, (b, l), 0, v)
    coin = jax.random.bernoulli(k2, cfg.structure, (b, l))

    def step_fn(x, inputs):
        nz, cn = inputs
        nxt = jnp.where(cn, _successor(x, cfg), nz)
        return nxt, nxt

    _, toks = jax.lax.scan(step_fn, start,
                           (noise.swapaxes(0, 1), coin.swapaxes(0, 1)))
    return toks.swapaxes(0, 1).astype(jnp.int32)  # (b, l)


def worker_batches(cfg: DataConfig, n_workers: int, step: int):
    """Stacked (W, batch_per_worker, seq_len) — LocalComm layout."""
    return jnp.stack([sample_batch(cfg, w, step) for w in range(n_workers)])


def global_batch(cfg: DataConfig, step: int, global_batch_size: int):
    """One flat global batch (production path); workers' shards concatenated."""
    n = global_batch_size // cfg.batch_per_worker
    ws = worker_batches(cfg, n, step)
    return ws.reshape(global_batch_size, cfg.seq_len)


def bayes_entropy(cfg: DataConfig) -> float:
    """Entropy of the generating process (loss floor for a perfect model)."""
    s, v = cfg.structure, cfg.v_act
    # next ~ s·δ(successor) + (1−s)·uniform; the successor bucket gets s+(1−s)/V
    p_succ = s + (1 - s) / v
    p_other = (1 - s) / v
    return float(-(p_succ * np.log(p_succ) + (v - 1) * p_other * np.log(p_other)))
