"""Regenerate the EXPERIMENTS.md roofline tables from dry-run JSON.

    PYTHONPATH=src python scripts/gen_tables.py results_singlepod.json
"""

import json
import sys


def table(path):
    rows = json.load(open(path))
    out = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | useful-FLOP | GiB/dev |",
           "|---|---|---:|---:|---:|---|---:|---:|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status'].upper()} | — | — |")
            continue
        ro, mem = r["roofline"], r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']*1e3:.2f} | "
            f"{ro['memory_s']*1e3:.2f} | {ro['collective_s']*1e3:.2f} | "
            f"{ro['dominant']} | {ro['useful_flops_ratio']:.2f} | "
            f"{mem['peak_per_device_gb']:.2f} |")
    ok = sum(1 for r in rows if r["status"] == "ok")
    skip = sum(1 for r in rows if r["status"] == "skip")
    err = sum(1 for r in rows if r["status"] == "error")
    out.append("")
    out.append(f"({ok} ok / {skip} skip / {err} error)")
    return "\n".join(out)


if __name__ == "__main__":
    for p in sys.argv[1:]:
        print(f"### {p}\n")
        print(table(p))
        print()
