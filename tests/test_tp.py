"""Tensor parallelism tests (models/tensor_parallel.py, DESIGN.md §12).

The TP numerical contract, proven on a tiny 2-layer transformer at
TP=2 under the vmap(axis_name="model") harness:

  * forward logits and training loss: BITWISE equal to the unsharded
    blocked reference (``tp_degree`` set, no active context),
  * isolated sub-layer (attention, MLP) forward AND backward: bitwise,
  * end-to-end split-leaf grads: ≤ ~1 ulp (the residual-stream cotangent
    is re-associated across layer boundaries between the two programs),
  * replicated-leaf grads are per-rank partials whose SUM over ranks
    matches the reference (``finalize_grads`` completes them).

Plus the param split/unsplit round-trip, the "tp" collective contract,
and a subprocess HLO proof on a real 2-device "model" mesh linted by
``rules.tp_collective_budget``.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.models.tensor_parallel import (
    SPLIT_AXES,
    _partition_replicated,
    tp_collective_contract,
    tp_context,
    tp_split_params,
    tp_unsplit_params,
)

pytestmark = pytest.mark.tp

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TP = 2


def tiny_cfg(num_layers: int = 2, tp_degree: int = TP):
    return dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        num_layers=num_layers, d_model=32, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, tp_degree=tp_degree)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    targets = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab_size)
    return cfg, params, tokens, targets


def _loss_of(cfg, p, tokens, targets):
    logits, _ = T.forward(p, cfg, tokens)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, targets[..., None], axis=-1))


# ---------------------------------------------------------------------------
# param split / unsplit
# ---------------------------------------------------------------------------
def test_split_unsplit_roundtrip(setup):
    _, params, _, _ = setup
    shards = tp_split_params(params, TP)
    # every leaf gains a leading rank axis
    for leaf in jax.tree.leaves(shards):
        assert leaf.shape[0] == TP
    back = tp_unsplit_params(shards)
    ref = {jax.tree_util.keystr(k): v
           for k, v in jax.tree_util.tree_leaves_with_path(params)}
    got = {jax.tree_util.keystr(k): v
           for k, v in jax.tree_util.tree_leaves_with_path(back)}
    assert set(ref) == set(got)
    for name in ref:
        assert bool(jnp.all(ref[name] == got[name])), name


def test_split_shapes_follow_axes(setup):
    """Column leaves split on their output axis, row leaves on input,
    everything else (norms, embeddings) is replicated whole."""
    _, params, _, _ = setup
    shards = tp_split_params(params, TP)
    blk_ref = jax.tree.map(lambda v: v[0], params["stack"]["0"])
    blk_tp = jax.tree.map(lambda v: v[0, 0], shards["stack"]["0"])

    def walk(ref, tp):
        checked = 0
        for k in ref:
            if isinstance(ref[k], dict):
                checked += walk(ref[k], tp[k])
            elif k in SPLIT_AXES:
                want = list(ref[k].shape)
                want[SPLIT_AXES[k]] //= TP
                assert list(tp[k].shape) == want, k
                checked += 1
        return checked

    assert walk(blk_ref, blk_tp) >= 7  # qkv(+bias), o, gate/up/down
    # embeddings replicated
    assert shards["embed"].shape[1:] == params["embed"].shape


def test_split_indivisible_raises():
    cfg = tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="divisible"):
        tp_split_params(params, 3)


def test_tp_context_rejects_degree_one():
    with pytest.raises(ValueError):
        with tp_context(1):
            pass


# ---------------------------------------------------------------------------
# forward / loss bitwise vs the blocked unsharded reference
# ---------------------------------------------------------------------------
def test_forward_and_loss_bitwise(setup):
    cfg, params, tokens, targets = setup
    ref_logits = jax.jit(lambda p: T.forward(p, cfg, tokens)[0])(params)
    ref_loss = jax.jit(lambda p: _loss_of(cfg, p, tokens, targets))(params)
    shards = tp_split_params(params, TP)

    def tp_fwd(sh):
        with tp_context(TP):
            return jax.vmap(lambda p: T.forward(p, cfg, tokens)[0],
                            axis_name="model")(sh)

    def tp_loss(sh):
        with tp_context(TP):
            return jnp.mean(jax.vmap(
                lambda p: _loss_of(cfg, p, tokens, targets),
                axis_name="model")(sh))

    out = jax.jit(tp_fwd)(shards)
    for r in range(TP):
        assert bool(jnp.all(out[r] == ref_logits)), f"rank {r} not bitwise"
    tl = jax.jit(tp_loss)(shards)
    assert bool(tl == ref_loss)


# ---------------------------------------------------------------------------
# isolated sub-layer backward: bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sublayer", ["mlp", "attn"])
def test_sublayer_backward_bitwise(setup, sublayer):
    """Isolated attention / MLP sub-layers are bitwise in forward AND
    backward at TP=2 — the end-to-end 1-ulp tolerance comes only from
    residual-stream re-association across layer boundaries."""
    from repro.models import layers as L

    cfg, params, _, _ = setup
    blk = jax.tree.map(lambda v: v[0], params["stack"]["0"])
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
    w = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model))

    if sublayer == "mlp":
        sub = blk["mlp"]

        def lossfn(p):
            return jnp.sum(L.mlp(p, cfg, x) * w)
    else:
        sub = blk["attn"]
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))

        def lossfn(p):
            out, _ = L.attention(p, cfg, x, pos, window=0,
                                 theta=cfg.rope_theta, cache=None)
            return jnp.sum(out * w)

    rl, rg = jax.jit(jax.value_and_grad(lossfn))(sub)
    shards = tp_split_params(sub, TP)

    def tp_loss(sh):
        with tp_context(TP):
            return jnp.mean(jax.vmap(lossfn, axis_name="model")(sh))

    tl, tg = jax.jit(jax.value_and_grad(tp_loss))(shards)
    assert bool(tl == rl)
    ref_split = tp_split_params(rg, TP)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         tg, ref_split)
    assert max(jax.tree.leaves(diffs)) == 0.0, diffs


# ---------------------------------------------------------------------------
# end-to-end backward: split ≤1 ulp, replicated sums to the reference
# ---------------------------------------------------------------------------
def test_end_to_end_grads(setup):
    cfg, params, tokens, targets = setup
    _, ref_grads = jax.jit(jax.value_and_grad(
        lambda p: _loss_of(cfg, p, tokens, targets)))(params)
    shards = tp_split_params(params, TP)

    def tp_loss(sh):
        with tp_context(TP):
            return jnp.mean(jax.vmap(
                lambda p: _loss_of(cfg, p, tokens, targets),
                axis_name="model")(sh))

    _, tg = jax.jit(jax.value_and_grad(tp_loss))(shards)
    ref_split = tp_split_params(ref_grads, TP)

    def walk(a, b, in_moe=False):
        for k in a:
            if isinstance(a[k], dict):
                walk(a[k], b[k], in_moe or k == "moe")
            elif not in_moe and k in SPLIT_AXES:
                np.testing.assert_allclose(
                    np.asarray(a[k]), np.asarray(b[k]), atol=1e-7,
                    err_msg=k)

    walk(tg, ref_split)
    # replicated leaves: per-rank partials, SUM over ranks == reference
    rep_t, _ = _partition_replicated(tg, "stack")
    rep_r, _ = _partition_replicated(ref_grads, "stack")
    kt = {jax.tree_util.keystr(k): v for k, v in
          jax.tree_util.tree_leaves_with_path(rep_t)}
    kr = {jax.tree_util.keystr(k): v for k, v in
          jax.tree_util.tree_leaves_with_path(rep_r)}
    assert set(kt) == set(kr)
    for name in kt:
        np.testing.assert_allclose(
            np.asarray(jnp.sum(kt[name], axis=0)), np.asarray(kr[name]),
            atol=2e-7, err_msg=name)


def test_finalize_grads_completes_replicated(setup):
    """finalize_grads = Megatron's layernorm-grad all-reduce: after it,
    EVERY rank holds the completed (summed) replicated-leaf grads while
    split leaves pass through untouched."""
    cfg, params, tokens, targets = setup
    shards = tp_split_params(params, TP)

    def tp_grads(sh):
        from repro.models.tensor_parallel import current_tp

        def per_rank(p):
            g = jax.grad(lambda q: _loss_of(cfg, q, tokens, targets))(p)
            return current_tp().finalize_grads(g)

        with tp_context(TP):
            return jax.vmap(per_rank, axis_name="model")(sh)

    g = jax.jit(tp_grads)(shards)
    rep, _ = _partition_replicated(g, "stack")
    for name, v in ((jax.tree_util.keystr(k), v) for k, v in
                    jax.tree_util.tree_leaves_with_path(rep)):
        np.testing.assert_allclose(np.asarray(v[0]), np.asarray(v[1]),
                                   atol=0, err_msg=name)


# ---------------------------------------------------------------------------
# collective contract + HLO budget on a real 2-device "model" mesh
# ---------------------------------------------------------------------------
def test_tp_collective_contract_counts():
    cfg = tiny_cfg(num_layers=3)
    act = jax.ShapeDtypeStruct((2, 8, cfg.d_model), jnp.float32)
    contract = tp_collective_contract(cfg, act)
    # (wo + w_down) × (fwd + bwd) combines, one bucket each at this size
    assert contract == {"all-reduce": 2 * 3 * 2}


def test_tp_rule_skips_degree_one():
    from repro.analysis import rules

    rr = rules.tp_collective_budget("", {}, tp_degree=1)
    assert rr.status == "skip"


def test_tp_hlo_budget_on_model_mesh():
    """The shard_map TP rig compiles within the "tp" contract budget on a
    real 2-device 'model' mesh — the committed-LINT proof, run here
    directly via rules.tp_collective_budget."""
    out = _run("""
        import os
        from repro.analysis import rigs, rules
        art = rigs.tp_artifacts("f32")
        rr = rules.tp_collective_budget(art["hlo"], art["contract"],
                                        art["tp_degree"])
        assert rr.status == "pass", rr.findings
        assert rr.details["counts"].get("all-reduce", 0) >= 1
        print("OK", rr.details["counts"])
    """)
    assert "OK" in out


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout
