"""Direct unit coverage for core/staleness.py and core/consistency.py.

The property tests (tests/test_consistency_property.py) validate the
paper's Statement 1 end to end; these tests pin the MECHANICS the
properties rely on: delivery timing, drop accounting, the duplicate-
delivery guard, the momentum counterexample arithmetic, and the
staleness-histogram bookkeeping the decentralized measurement tooling
(benchmarks/bench_staleness.py) is built on.
"""

import numpy as np
import pytest

from repro.core.consistency import ConsistencySim, Replica, Update
from repro.core.staleness import (effective_momentum_fit, implicit_momentum,
                                  staleness_histogram)

DIM = 4


# ---------------------------------------------------------------------------
# staleness.py
# ---------------------------------------------------------------------------
def test_implicit_momentum_degenerate_worker_counts():
    assert implicit_momentum(0) == 0.0  # clamped, no division by zero
    assert implicit_momentum(1) == 0.0
    assert implicit_momentum(2) == pytest.approx(0.5)
    assert implicit_momentum(1000) == pytest.approx(0.999)


def test_effective_momentum_fit_short_trajectory_is_zero():
    # fewer than 3 updates: no regression possible, defined as 0.0
    assert effective_momentum_fit(np.zeros((1, DIM))) == 0.0
    assert effective_momentum_fit(np.zeros((2, DIM))) == 0.0
    assert effective_momentum_fit(np.zeros((3, DIM))) == 0.0


def test_effective_momentum_fit_exact_on_noiseless_geometric():
    """u_t = beta * u_{t-1} exactly ⇒ the least-squares fit IS beta."""
    beta = 0.65
    u0 = np.linspace(1.0, 2.0, DIM)
    w = [np.zeros(DIM)]
    u = u0
    for _ in range(50):
        w.append(w[-1] + u)
        u = beta * u
    beta_hat = effective_momentum_fit(np.stack(w))
    assert beta_hat == pytest.approx(beta, abs=1e-12)


def test_staleness_histogram_counts_and_drops():
    """delay = t for dst 1, dropped for dst 2: the histogram records
    exactly the delivered delays and the drop fraction, and src == dst
    pairs are never scheduled."""
    W, H = 3, 4

    def schedule(src, dst, t):
        assert src != dst  # self-delivery must not be queried
        return None if dst == 2 else t

    delays, drop_frac = staleness_histogram(schedule, W, H)
    # per round: 6 ordered pairs, 2 of them into dst=2 (dropped)
    assert drop_frac == pytest.approx(2 / 6)
    assert len(delays) == 4 * H
    assert sorted(set(delays.tolist())) == list(range(H))


def test_staleness_histogram_empty_horizon():
    delays, drop_frac = staleness_histogram(lambda s, d, t: 0, 4, 0)
    assert len(delays) == 0 and drop_frac == 0.0


# ---------------------------------------------------------------------------
# consistency.py — Replica
# ---------------------------------------------------------------------------
def test_replica_sgd_applies_updates():
    r = Replica(np.ones(DIM), lr=0.5)
    r.apply(Update(src=0, seq=0, grad=np.full(DIM, 2.0)))
    np.testing.assert_allclose(r.w, np.zeros(DIM))


def test_replica_rejects_duplicate_delivery():
    r = Replica(np.zeros(DIM), lr=0.1)
    r.apply(Update(src=1, seq=7, grad=np.ones(DIM)))
    with pytest.raises(AssertionError, match="duplicate delivery"):
        r.apply(Update(src=1, seq=7, grad=np.ones(DIM)))
    # a different seq from the same source is fine
    r.apply(Update(src=1, seq=8, grad=np.ones(DIM)))


def test_replica_momentum_arithmetic():
    """m = beta*m + g each apply; w -= lr*m — two applies by hand."""
    r = Replica(np.zeros(DIM), lr=1.0, momentum=0.5)
    g = np.ones(DIM)
    r.apply(Update(0, 0, g))  # m=1, w=-1
    r.apply(Update(0, 1, g))  # m=1.5, w=-2.5
    np.testing.assert_allclose(r.w, np.full(DIM, -2.5))


# ---------------------------------------------------------------------------
# consistency.py — ConsistencySim
# ---------------------------------------------------------------------------
def test_produce_applies_locally_and_enqueues_for_peers():
    sim = ConsistencySim(3, DIM, lr=0.1, seed=0)
    w_before = sim.weights()
    sim.produce(0, np.ones(DIM), seq=0, delays={1: 1, 2: 3})
    w_after = sim.weights()
    # source moved immediately, peers have not
    assert not np.allclose(w_after[0], w_before[0])
    np.testing.assert_allclose(w_after[1], w_before[1])
    np.testing.assert_allclose(w_after[2], w_before[2])
    assert len(sim.queues[(0, 1)]) == 1 and len(sim.queues[(0, 2)]) == 1


def test_delivery_waits_for_the_scheduled_round():
    sim = ConsistencySim(2, DIM, lr=0.1, seed=0)
    sim.produce(0, np.ones(DIM), seq=0, delays={1: 2})
    sim.step()  # round 1 < due round 2: still queued
    assert len(sim.queues[(0, 1)]) == 1
    assert not sim.consistent()
    sim.step()  # round 2: delivered
    assert len(sim.queues[(0, 1)]) == 0
    assert sim.consistent()


def test_none_and_inf_delays_count_as_drops():
    sim = ConsistencySim(3, DIM, lr=0.1, seed=0)
    sim.produce(0, np.ones(DIM), seq=0, delays={1: None, 2: np.inf})
    assert sim.dropped == 2
    assert not sim.queues.get((0, 1)) and not sim.queues.get((0, 2))
    sim.drain()
    assert not sim.consistent()  # dropped updates never arrive


def test_drain_empties_queues_and_restores_consistency():
    sim = ConsistencySim(3, DIM, lr=0.2, seed=1)
    rng = np.random.default_rng(0)
    for seq in range(5):
        for src in range(3):
            sim.produce(src, rng.normal(size=DIM), seq,
                        delays={d: 100 + seq for d in range(3) if d != src})
        sim.step()
    assert not sim.consistent()  # everything still in flight
    sim.drain()
    assert all(len(q) == 0 for q in sim.queues.values())
    assert sim.consistent()


def test_max_divergence_is_max_abs_gap_to_replica0():
    sim = ConsistencySim(2, DIM, lr=1.0, seed=0)
    sim.produce(0, np.full(DIM, 0.25), seq=0, delays={1: None})
    # replica 0 moved by -0.25 everywhere, replica 1 did not
    assert sim.max_divergence() == pytest.approx(0.25)
