"""Strategy-spectrum tests (paper §3) on the LocalComm replica simulator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import strategies as ST
from repro.core.comm import LocalComm, LocalHierComm
from repro.core.compression import get_compressor
from repro.optim import adam, momentum, sgd
from repro.train.loop import init_train_state, make_replica_train_step

W, DIM, NDATA = 4, 12, 64


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    Xs = jax.random.normal(key, (W, NDATA, DIM))
    w_true = jax.random.normal(jax.random.PRNGKey(1), (DIM,))
    Ys = Xs @ w_true + 0.01 * jax.random.normal(jax.random.PRNGKey(2), (W, NDATA))

    def loss_fn(params, batch):
        X, Y = batch
        return jnp.mean((X @ params["w"] - Y) ** 2)

    return Xs, Ys, w_true, loss_fn


def _run(strategy, problem, opt=None, steps=100):
    Xs, Ys, w_true, loss_fn = problem
    comm = LocalComm(W)
    opt = opt or sgd(0.05)
    params = comm.replicate({"w": jnp.zeros(DIM)})
    state = init_train_state(params, opt, strategy, comm)
    step = make_replica_train_step(loss_fn, opt, strategy, comm)
    for _ in range(steps):
        state, m = step(state, (Xs, Ys))
    err = float(jnp.mean((state["params"]["w"] - w_true[None]) ** 2))
    return state, m, err


ALL = [
    ("sync", ST.sync()),
    ("local_sgd", ST.local_sgd(sync_every=4)),
    ("ssp", ST.ssp(staleness=3)),
    ("downpour", ST.downpour(push_every=4)),
    ("gossip", ST.gossip()),
]


@pytest.mark.parametrize("name,strategy", ALL)
def test_strategy_converges(name, strategy, problem):
    _, m, err = _run(strategy, problem)
    assert err < 1e-3, (name, err)
    assert jnp.isfinite(m["loss"])


def test_sync_replicas_exactly_consistent(problem):
    state, m, _ = _run(ST.sync(), problem)
    assert float(m["replica_divergence"]) == 0.0


def test_complete_strategies_bounded_divergence(problem):
    """SSP/downpour (complete communication) keep replicas near-consistent;
    gossip (partial) diverges more — the §3 ordering."""
    _, m_ssp, _ = _run(ST.ssp(staleness=3), problem)
    _, m_dp, _ = _run(ST.downpour(push_every=4), problem)
    _, m_gsp, _ = _run(ST.gossip(), problem)
    assert float(m_ssp["replica_divergence"]) < 1e-2
    assert float(m_dp["replica_divergence"]) < 1e-2
    assert float(m_gsp["replica_divergence"]) >= 0.0  # exists; partial


def test_spectrum_metadata():
    assert ST.sync().spectrum_point == 1 and ST.sync().complete
    assert ST.ssp().spectrum_point == 2 and ST.ssp().complete
    assert ST.downpour().spectrum_point == 3 and ST.downpour().complete
    assert ST.gossip().spectrum_point == 4 and not ST.gossip().complete


def test_ssp_matches_sync_at_staleness_limit(problem):
    """As s→0-equivalent (s=1 with buffers drained each step), SSP tracks
    sync closely on a quadratic problem."""
    _, _, err_sync = _run(ST.sync(), problem)
    _, _, err_ssp = _run(ST.ssp(staleness=1), problem)
    assert abs(err_sync - err_ssp) < 1e-3


@pytest.mark.parametrize("comp", ["onebit", "int8", "topk"])
def test_sync_with_compression_converges(comp, problem):
    c = get_compressor(comp, block=16) if comp != "topk" \
        else get_compressor("topk", ratio=0.25, block=16)
    _, m, err = _run(ST.sync(compressor=c), problem, steps=150)
    assert err < 1e-2, (comp, err)
    assert float(m["wire_bytes"]) < W * DIM * 4  # genuinely fewer bytes


def test_compression_reduces_wire_bytes(problem):
    _, m_none, _ = _run(ST.sync(), problem, steps=3)
    _, m_1bit, _ = _run(ST.sync(compressor=get_compressor("onebit", block=16)),
                        problem, steps=3)
    ratio = float(m_none["wire_bytes"]) / float(m_1bit["wire_bytes"])
    assert ratio > 8  # 32b → ~3b (1 bit + scale overhead at tiny blocks)


def test_gossip_mixing_contracts_divergence(problem):
    """Doubly-stochastic ring mixing must not blow replicas apart."""
    Xs, Ys, w_true, loss_fn = problem
    comm = LocalComm(W)
    opt = sgd(0.05)
    strat = ST.gossip()
    # start replicas DIFFERENT on purpose
    params = {"w": jax.random.normal(jax.random.PRNGKey(5), (W, DIM))}
    state = init_train_state(params, opt, strat, comm)
    step = make_replica_train_step(loss_fn, opt, strat, comm)
    div0 = float(jnp.max(jnp.abs(params["w"] - params["w"][0:1])))
    for _ in range(50):
        state, m = step(state, (Xs, Ys))
    assert float(m["replica_divergence"]) < div0


def test_hierarchical_strategy(problem):
    """Beyond-paper: complete sync inside pods × gossip across pods."""
    Xs, Ys, w_true, loss_fn = problem
    pods, wk = 2, 2
    comm = LocalHierComm(pods, wk)
    strat = ST.hierarchical(ST.sync(), ST.gossip(mix_every=2))
    opt = sgd(0.05)
    params = {"w": jnp.zeros((pods, wk, DIM))}
    state = init_train_state(params, opt, strat, comm)

    def loss2(params, batch):
        X, Y = batch
        return jnp.mean((X @ params["w"] - Y) ** 2)

    grad_fn = jax.vmap(jax.vmap(jax.value_and_grad(loss2)))
    Xs2 = Xs.reshape(pods, wk, NDATA, DIM)
    Ys2 = Ys.reshape(pods, wk, NDATA)

    @jax.jit
    def step(state):
        loss, grads = grad_fn(state["params"], (Xs2, Ys2))
        p, o, c, m = strat.update(state["params"], grads, state["opt_state"],
                                  state["comm_state"], state["step"], opt, comm)
        return {"params": p, "opt_state": o, "comm_state": c,
                "step": state["step"] + 1}, (loss, m)

    for _ in range(120):
        state, (loss, m) = step(state)
    err = float(jnp.mean((state["params"]["w"] - w_true) ** 2))
    assert err < 1e-3
    # intra-pod replicas exactly consistent (sync), cross-pod free to differ
    w = state["params"]["w"]
    assert float(jnp.max(jnp.abs(w[:, 0] - w[:, 1]))) < 1e-6


def test_hier_comm_axis_binding():
    """LocalHierComm (P, W, ...) layout: inner ops act on axis 1, outer on
    axis 0 — the explicit axis parameters that replaced the old
    monkey-patched re-binding."""
    import numpy as np
    pods, wk = 3, 2
    comm = LocalHierComm(pods, wk)
    assert (comm.inner.axis, comm.outer.axis) == (1, 0)
    assert comm.inner.lead_axes == comm.outer.lead_axes == 2
    assert comm.size == pods * wk
    x = {"w": jnp.arange(float(pods * wk * 4)).reshape(pods, wk, 4)}
    got = comm.inner.all_mean(x)["w"]
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(jnp.broadcast_to(jnp.mean(x["w"], 1, keepdims=True),
                                    x["w"].shape)), atol=1e-6)
    got = comm.outer.ppermute(x, shift=1)["w"]
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.roll(x["w"], 1, 0)), atol=1e-6)
    got = comm.inner.ppermute(x, shift=1)["w"]
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.roll(x["w"], 1, 1)), atol=1e-6)


def test_easgd_center_uses_comm_axis():
    """On the outer tier of a hierarchy the easgd center must be the
    CROSS-POD mean (the comm's reduction axis), not a per-pod worker
    mean."""
    import numpy as np
    pods, wk = 4, 2
    comm = LocalHierComm(pods, wk)
    params = {"w": jnp.arange(float(pods * wk * 3)).reshape(pods, wk, 3)}
    center = ST.easgd().init(params, comm.outer)["center"]["w"]
    np.testing.assert_allclose(
        np.asarray(center),
        np.asarray(jnp.broadcast_to(jnp.mean(params["w"], 0, keepdims=True),
                                    params["w"].shape)), atol=1e-6)


def test_hierarchical_inner_complete_outer_partial():
    """One hier(sync × gossip) step with zero grads: workers inside a pod
    stay exactly consistent (complete inner tier) while each pod mixes
    ONLY with its ring neighbors — the opposite pod's value is never
    delivered (partial outer tier)."""
    import numpy as np
    pods, wk, dim = 4, 2, 3
    comm = LocalHierComm(pods, wk)
    strat = ST.hierarchical(ST.sync(), ST.gossip(mix_every=1))
    opt = sgd(0.0)  # isolate the communication
    vals = jnp.arange(1.0, pods + 1)
    params = {"w": jnp.broadcast_to(vals[:, None, None],
                                    (pods, wk, dim)).copy()}
    grads = jax.tree.map(jnp.zeros_like, params)
    state = init_train_state(params, opt, strat, comm)
    p, _, _, m = strat.update(params, grads, state["opt_state"],
                              state["comm_state"], jnp.zeros((), jnp.int32),
                              opt, comm)
    w = np.asarray(p["w"])
    # inner completeness: intra-pod replicas identical
    assert np.max(np.abs(w[:, 0] - w[:, 1])) < 1e-6
    # outer partiality: pod p = mean(p-1, p, p+1); pod p+2 excluded
    expect = np.asarray((vals + jnp.roll(vals, 1) + jnp.roll(vals, -1)) / 3.0)
    np.testing.assert_allclose(w[:, 0, 0], expect, atol=1e-5)
    assert not np.allclose(w[0, 0, 0], np.mean(np.asarray(vals)))


def test_hierarchical_with_fabric_compression(problem):
    """Compressed inner tier through the bucketed fabric on the (P, W)
    stacked layout: hier(sync+onebit × gossip) still converges, and wire
    bytes are genuinely reduced."""
    Xs, Ys, w_true, loss_fn = problem
    pods, wk = 2, 2
    comm = LocalHierComm(pods, wk)
    comp = get_compressor("onebit", block=16)
    strat = ST.hierarchical(ST.sync(compressor=comp), ST.gossip(mix_every=2))
    opt = sgd(0.05)
    params = {"w": jnp.zeros((pods, wk, DIM))}
    state = init_train_state(params, opt, strat, comm)

    def loss2(params, batch):
        X, Y = batch
        return jnp.mean((X @ params["w"] - Y) ** 2)

    grad_fn = jax.vmap(jax.vmap(jax.value_and_grad(loss2)))
    Xs2 = Xs.reshape(pods, wk, NDATA, DIM)
    Ys2 = Ys.reshape(pods, wk, NDATA)

    @jax.jit
    def step(state):
        loss, grads = grad_fn(state["params"], (Xs2, Ys2))
        p, o, c, m = strat.update(state["params"], grads, state["opt_state"],
                                  state["comm_state"], state["step"], opt, comm)
        return {"params": p, "opt_state": o, "comm_state": c,
                "step": state["step"] + 1}, m

    for _ in range(199):  # odd: the last step has no outer mix
        state, m = step(state)
    err = float(jnp.mean((state["params"]["w"] - w_true) ** 2))
    assert err < 1e-2
    # inner tier ships packed 1-bit payloads, not f32
    assert float(m["wire_bytes"]) < pods * wk * DIM * 4


def test_momentum_and_adam_compose_with_sync(problem):
    for opt in (momentum(0.03, 0.9), adam(0.05)):
        _, _, err = _run(ST.sync(), problem, opt=opt, steps=200)
        assert err < 1e-2


def test_easgd_converges(problem):
    _, m, err = _run(ST.easgd(alpha=0.2, sync_every=4), problem, steps=150)
    assert err < 1e-2
    assert ST.easgd().complete


def test_ssp_staleness_aware_lr(problem):
    """Zhang et al. [40]: staleness-aware scaling keeps high-staleness SSP
    stable (final error no worse than plain at s=8)."""
    _, _, err_plain = _run(ST.ssp(staleness=8), problem, steps=150)
    _, _, err_aware = _run(ST.ssp(staleness=8, staleness_aware_lr=True),
                           problem, steps=150)
    assert err_aware < max(err_plain * 3, 1e-2)


def test_sync_dgc_converges(problem):
    from repro.core.compression import get_compressor
    topk = get_compressor("topk", ratio=0.25, block=16)
    _, m, err = _run(ST.sync_dgc(topk), problem, steps=200)
    assert err < 5e-2
    assert float(m["wire_bytes"]) < W * DIM * 4


# ---------------------------------------------------------------------------
# regression: update() must not alias/mutate the caller's comm_state
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_strat", [
    lambda: ST.sync_dgc(get_compressor("topk", ratio=0.25, block=16)),
    lambda: ST.ssp(staleness=3, compressor=get_compressor("int8", block=16)),
    lambda: ST.downpour(push_every=4,
                        compressor=get_compressor("int8", block=16)),
    lambda: ST.hierarchical(ST.sync(), ST.gossip(mix_every=2)),
], ids=["sync_dgc", "ssp", "downpour", "hierarchical"])
def test_update_does_not_mutate_comm_state(make_strat, problem):
    """Stepping twice from the SAME saved state must give identical
    results: strategies used to write into the caller's cstate dict, so a
    resume/re-step from a kept reference silently continued from t+1."""
    Xs, Ys, w_true, loss_fn = problem
    strat = make_strat()
    if strat.name.startswith("hier"):
        comm = LocalHierComm(2, 2)
        params = {"w": jnp.zeros((2, 2, DIM))}
        grads = {"w": jnp.ones((2, 2, DIM))}
    else:
        comm = LocalComm(W)
        params = {"w": jnp.zeros((W, DIM))}
        grads = {"w": jnp.ones((W, DIM))}
    opt = sgd(0.05)
    opt_state = opt.init(params)
    cstate = strat.init(params, comm)
    saved_leaves = jax.tree.leaves(cstate)
    t = jnp.zeros((), jnp.int32)
    # two UNJITTED updates from the same python dict: before the fix the
    # first call rebound cstate["..."] in place and the second diverged
    out1 = strat.update(params, grads, opt_state, cstate, t, opt, comm)
    out2 = strat.update(params, grads, opt_state, cstate, t, opt, comm)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        out1[0], out2[0])
    # the caller's dict still holds the exact original leaves
    for a, b in zip(saved_leaves, jax.tree.leaves(cstate)):
        assert a is b


def test_downpour_events_is_fleet_fraction(problem):
    """comm_events must be the fleet-wide push fraction (1/push_every with
    staggered offsets), not a per-shard 0/1 indicator."""
    strat = ST.downpour(push_every=4)
    comm = LocalComm(W)
    params = {"w": jnp.zeros((W, DIM))}
    grads = {"w": jnp.ones((W, DIM))}
    opt = sgd(0.05)
    cstate = strat.init(params, comm)
    for t in range(4):
        *_, m = strat.update(params, grads, opt.init(params), cstate,
                             jnp.asarray(t, jnp.int32), opt, comm)
        assert float(m["comm_events"]) == pytest.approx(0.25)
