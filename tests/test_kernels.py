"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracle in ref.py (kernels run in interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,l,d", [
    (1, 1, 128, 64), (2, 3, 256, 64), (1, 2, 300, 128), (2, 1, 64, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, h, l, d, dtype, rng):
    q, k, v = (jax.random.normal(jax.random.fold_in(rng, i),
                                 (b, h, l, d), dtype) for i in range(3))
    out = ops.flash_attention(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_sliding_window(window, rng):
    q, k, v = (jax.random.normal(jax.random.fold_in(rng, i),
                                 (1, 2, 256, 64)) for i in range(3))
    out = ops.flash_attention(q, k, v, window=window)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal(rng):
    q, k, v = (jax.random.normal(jax.random.fold_in(rng, i),
                                 (1, 1, 128, 64)) for i in range(3))
    out = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_attention(rng):
    """Kernel agrees with the model's dense-masked attention path."""
    from repro.configs.base import ModelConfig
    from repro.models.layers import _sdpa

    cfg = ModelConfig(num_heads=4, num_kv_heads=4)
    b, h, l, d = 2, 4, 128, 64
    q, k, v = (jax.random.normal(jax.random.fold_in(rng, i),
                                 (b, l, h, d)) for i in range(3))
    i_ = jnp.arange(l)[:, None]
    j_ = jnp.arange(l)[None, :]
    mask = (j_ <= i_)[None, None]
    dense = _sdpa(cfg, q, k, v, mask)  # (B,L,H,D)
    fl = ops.flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2)).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(fl), np.asarray(dense),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# top-k sparsify
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nb,block,k", [(4, 128, 4), (37, 256, 8), (1, 64, 1),
                                        (8, 512, 32)])
def test_topk_sweep(nb, block, k, rng):
    x = jax.random.normal(rng, (nb, block))
    vals, idx, dense = ops.topk_sparsify(x, k)
    rvals, ridx, rdense = ref.topk_sparsify_ref(x, k)
    # sets of |values| must match (tie order may differ)
    np.testing.assert_allclose(np.sort(np.abs(vals), -1),
                               np.sort(np.abs(rvals), -1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(rdense),
                               atol=1e-6)


def test_topk_dense_is_subset(rng):
    x = jax.random.normal(rng, (8, 128))
    _, _, dense = ops.topk_sparsify(x, 4)
    nz = np.asarray(dense) != 0
    assert nz.sum(axis=1).max() <= 4
    np.testing.assert_allclose(np.asarray(dense)[nz], np.asarray(x)[nz])


# ---------------------------------------------------------------------------
# onebit quant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nb,block", [(1, 128), (17, 128), (64, 256)])
def test_onebit_sweep(nb, block, rng):
    g = jax.random.normal(rng, (nb, block))
    r = jax.random.normal(jax.random.fold_in(rng, 1), (nb, block)) * 0.1
    s, sc, nr = ops.onebit_quant(g, r)
    rs, rsc, rnr = ref.onebit_quant_ref(g, r)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(rsc), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(nr), np.asarray(rnr),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_onebit_property_ef_identity(seed):
    """decoded + residual' == input + residual (mass conservation)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (4, 64))
    r = jnp.zeros((4, 64))
    s, sc, nr = ops.onebit_quant(g, r)
    decoded = np.asarray(s, np.float32) * np.asarray(sc)
    np.testing.assert_allclose(decoded + np.asarray(nr), np.asarray(g),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# fused adam
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [100, 4096, 10_000])
@pytest.mark.parametrize("t", [1, 100])
def test_fused_adam_sweep(n, t, rng):
    p, g, m = (jax.random.normal(jax.random.fold_in(rng, i), (n,))
               for i in range(3))
    v = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (n,)))
    p1, m1, v1 = ops.fused_adam(p, g, m, v, 1e-3, t)
    rp, rm, rv = ref.fused_adam_ref(p, g, m, v, 1e-3, t=t)
    # kernel computes bias-correction powers in f32 on device; ref uses
    # python-float (f64) powers — 1e-8-level differences are expected
    np.testing.assert_allclose(np.asarray(p1), np.asarray(rp),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(rm),
                               rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(rv),
                               rtol=1e-4, atol=1e-7)


def test_fused_adam_matches_optimizer(rng):
    """Kernel agrees with the optim/ Adam used by the trainer."""
    from repro.optim import adam

    n = 512
    p = jax.random.normal(rng, (n,))
    g = jax.random.normal(jax.random.fold_in(rng, 1), (n,))
    opt = adam(1e-3)
    st_ = opt.init({"w": p})
    new, st1 = opt.update({"w": g}, st_, {"w": p}, 0)
    p1, m1, v1 = ops.fused_adam(p, g, jnp.zeros(n), jnp.zeros(n), 1e-3, 1)
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(p1),
                               rtol=1e-5, atol=1e-6)


# (The adam(fused=True) Optimizer-API parity tests live in
# tests/test_precision.py, which runs without the hypothesis dependency
# this module is gated on.)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,l,d,n", [(2, 32, 64, 8), (1, 16, 128, 16),
                                     (2, 24, 96, 4)])
def test_mamba_scan_sweep(b, l, d, n, rng):
    u = jax.random.normal(rng, (b, l, d)) * 0.5
    delta = jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 1),
                                              (b, l, d)))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 2), (d, n)))
    bb = jax.random.normal(jax.random.fold_in(rng, 3), (b, l, n)) * 0.5
    cc = jax.random.normal(jax.random.fold_in(rng, 4), (b, l, n)) * 0.5
    ds = jax.random.normal(jax.random.fold_in(rng, 5), (d,))
    y_k, h_k = ops.mamba_scan(u, delta, a, bb, cc, ds, d_block=64)
    y_r, h_r = ref.mamba_scan_ref(u, delta, a, bb, cc, ds)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               atol=1e-4, rtol=1e-4)


def test_mamba_scan_matches_model_layer(rng):
    """Kernel ≡ the chunked associative-scan path in models/ssm.py."""
    from repro.configs.base import ModelConfig
    from repro.models.ssm import (_causal_conv, _mamba_bcdt, init_mamba,
                                  mamba)

    cfg = ModelConfig(d_model=32, ssm_expand=2, ssm_state_dim=8, ssm_chunk=16)
    p = init_mamba(rng, cfg)
    x = jax.random.normal(rng, (2, 32, 32)) * 0.5
    out_model, _ = mamba(p, cfg, x)
    d_in = 64
    xz = x @ p["in_proj"]
    u0, z = xz[..., :d_in], xz[..., d_in:]
    uc, _ = _causal_conv(p, u0)
    delta, bb, cc = _mamba_bcdt(p, cfg, uc)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    yk, _ = ops.mamba_scan(uc, delta, a, bb, cc, p["D"], d_block=64)
    out_kernel = (yk.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               atol=1e-4, rtol=1e-4)
