"""Coverage for launch/mesh.py and launch/diagnose.py.

The production mesh is a FUNCTION parameterised by ``tp_degree`` so the
planner can trade DP against TP at a fixed device count; the diagnose
tool accepts an injected small mesh so its HLO collective accounting
runs on a CPU container.  Plus the unknown-config contract: every
launch CLI exits 2 listing the valid names.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------
def test_production_mesh_rejects_bad_tp_degree():
    # validation fires before any device is touched
    for bad in (0, -1, 3, 5, 512):
        with pytest.raises(ValueError, match="divide 256"):
            make_production_mesh(tp_degree=bad)


def test_roofline_constants_are_v5e():
    assert PEAK_FLOPS_BF16 == 197e12
    assert HBM_BW == 819e9
    assert ICI_BW == 50e9


def test_production_mesh_tp_degree_trades_axes():
    out = _run("""
        from repro.launch.mesh import make_mesh, make_production_mesh
        for tp, dp in ((16, 16), (4, 64), (1, 256)):
            m = make_production_mesh(tp_degree=tp)
            assert dict(m.shape) == {"data": dp, "model": tp}, m.shape
        m = make_production_mesh(multi_pod=True, tp_degree=8)
        assert dict(m.shape) == {"pod": 2, "data": 32, "model": 8}
        # the test/example passthrough keeps arbitrary axes
        assert dict(make_mesh((4,), ("data",)).shape) == {"data": 4}
        print("MESH_OK")
    """, devices=512)
    assert "MESH_OK" in out


# ---------------------------------------------------------------------------
# diagnose with an injected small mesh
# ---------------------------------------------------------------------------
def test_top_collectives_on_injected_mesh():
    """``mesh=`` bypasses the 512-device production env: the collective
    accounting runs on a (2, 2) data×model mesh, and raising the ZeRO
    stage surfaces the reduce-scatter wire in the ranking."""
    out = _run("""
        from repro.launch.diagnose import top_collectives
        from repro.core.jax_compat import make_mesh

        mesh = make_mesh((2, 2), ("data", "model"))
        rows = top_collectives("gemma3-1b", "train_4k", mesh=mesh)
        assert rows, "no collectives found in the lowered step"
        types = {base for _, base, _ in rows}
        assert types & {"all-reduce", "all-gather", "reduce-scatter"}, types
        # ZeRO-3's sharded step partitions over the "pod" axis
        mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rows3 = top_collectives("gemma3-1b", "train_4k", mesh=mesh3,
                                zero_stage=3)
        types3 = {base for _, base, _ in rows3}
        assert "reduce-scatter" in types3, types3
        print("DIAG_OK", sorted(types), sorted(types3))
    """, devices=8)
    assert "DIAG_OK" in out


# ---------------------------------------------------------------------------
# unknown-config contract across the launch CLIs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("module", ["repro.launch.dryrun",
                                    "repro.launch.lint",
                                    "repro.launch.plan"])
def test_unknown_config_exits_2_listing_names(module):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", module, "--arch", "no-such-model"],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 2, (out.returncode, out.stderr[-500:])
    assert "valid names" in out.stderr
    assert "qwen2-1.5b" in out.stderr
