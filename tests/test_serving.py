"""Serving tier: paged KV cache, Pallas paged attention, chunked prefill
(DESIGN.md §10).

Correctness bar: the paged engine must be TOKEN-IDENTICAL to the dense
seed engine under greedy decoding — across prompt-length mixes, cache
dtypes, randomized admission/termination order, and memory-pressure
eviction (recompute-style eviction never changes outputs).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine, PagedDecodeEngine, Request
from repro.serve.kv_cache import BlockAllocator, PagedKVCache

pytestmark = pytest.mark.serving


def _tiny_cfg(arch="qwen2-1.5b", **over):
    cfg = dataclasses.replace(
        get_config(arch).reduced(),
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=64)
    return dataclasses.replace(cfg, **over) if over else cfg


def _requests(rng, n, lo, hi, max_new=(1, 10)):
    return [Request(rid=i,
                    prompt=np.asarray(rng.integers(1, 64, size=int(l)),
                                      np.int32),
                    max_new_tokens=int(m))
            for i, (l, m) in enumerate(zip(
                rng.integers(lo, hi, size=n),
                rng.integers(max_new[0], max_new[1], size=n)))]


def _gens(finished):
    return {r.rid: list(r.generated) for r in finished}


# ---------------------------------------------------------------------------
# kernel vs jnp gather oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(None, None), (7, None),
                                            (None, 30.0), (7, 30.0)])
def test_paged_kernel_matches_ref(window, softcap, dtype):
    key = jax.random.PRNGKey(0)
    b, kv, g, dh, ps, mb = 3, 2, 4, 32, 8, 5
    np_pages = 1 + b * mb
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, kv, g, dh), dtype)
    k_pages = jax.random.normal(ks[1], (np_pages, ps, kv, dh), dtype)
    v_pages = jax.random.normal(ks[2], (np_pages, ps, kv, dh), dtype)
    # scrambled disjoint block tables, never the trash page 0
    rng = np.random.default_rng(1)
    bt = jnp.asarray(rng.permutation(np.arange(1, np_pages))
                     .reshape(b, mb).astype(np.int32))
    ctx = jnp.asarray([1, 17, mb * ps], jnp.int32)  # ragged live lengths
    out = paged_attention(q, k_pages, v_pages, bt, ctx,
                          window=window, softcap=softcap)
    ref = paged_attention_ref(q, k_pages, v_pages, bt, ctx,
                              window=window, softcap=softcap)
    assert out.dtype == q.dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# block allocator / paged cache invariants
# ---------------------------------------------------------------------------
def test_block_allocator_invariants():
    a = BlockAllocator(num_pages=9, page_size=4)
    assert a.num_free == 8  # page 0 reserved
    got = a.alloc(3)
    assert len(got) == 3 and 0 not in got
    a.check()
    assert a.alloc(6) is None          # all-or-nothing: 5 free < 6
    assert a.num_free == 5             # failed alloc allocated nothing
    a.free(got)
    a.check()
    with pytest.raises(ValueError):    # double-free
        a.free(got)
    a.check()
    assert a.blocks_for(0) == 0
    assert a.blocks_for(1) == 1
    assert a.blocks_for(4) == 1
    assert a.blocks_for(5) == 2


def test_paged_kv_cache_admit_grow_release():
    kv = PagedKVCache(num_slots=2, pages_per_seq=4,
                      allocator=BlockAllocator(num_pages=8, page_size=4))
    assert kv.admit(0, 6)              # 2 pages
    assert kv.tables[0, 0] != 0 and kv.tables[0, 1] != 0
    assert kv.tables[0, 2] == 0        # unallocated → trash
    assert kv.ensure(0, 6)             # covered: no-op
    assert kv.ensure(0, 9)             # grow to 3 pages
    assert kv.admit(1, 16)             # 4 pages
    assert not kv.ensure(0, 16)        # pool exhausted (7 of 7 used)
    kv.release(1)
    kv.allocator.check()
    assert kv.ensure(0, 16)
    kv.release(0)
    kv.allocator.check()
    assert kv.allocator.num_allocated == 0
    assert (kv.tables == 0).all()


# ---------------------------------------------------------------------------
# paged engine ≡ dense engine (greedy token parity)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cache_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("lo,hi", [(1, 12), (16, 40)])  # short + long mixes
def test_paged_engine_matches_dense(lo, hi, cache_dtype):
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    dense = DecodeEngine(params, cfg, batch_slots=3, max_seq=48,
                         cache_dtype=cache_dtype)
    paged = PagedDecodeEngine(params, cfg, batch_slots=3, max_seq=48,
                              page_size=4, chunk_size=8,
                              cache_dtype=cache_dtype, use_kernel=False)
    for r in _requests(np.random.default_rng(3), 7, lo, hi):
        dense.submit(r)
    for r in _requests(np.random.default_rng(3), 7, lo, hi):
        paged.submit(r)
    assert _gens(dense.run()) == _gens(paged.run())
    paged.kv.allocator.check()
    assert paged.kv.allocator.num_allocated == 0


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma3-1b"])
def test_paged_engine_kernel_path_matches_dense(arch):
    """Pallas kernel decode path (interpret on CPU) — includes gemma3's
    sliding-window + softcap-free qk-norm layout, where the window rides
    the scalar-prefetch operand."""
    over = {} if arch == "qwen2-1.5b" else dict(num_kv_heads=1)
    cfg = _tiny_cfg(arch, **over)
    params = T.init_model(jax.random.PRNGKey(1), cfg)
    dense = DecodeEngine(params, cfg, batch_slots=2, max_seq=32)
    paged = PagedDecodeEngine(params, cfg, batch_slots=2, max_seq=32,
                              page_size=4, chunk_size=8, use_kernel=True)
    assert paged.use_kernel
    for r in _requests(np.random.default_rng(5), 3, 2, 24, max_new=(2, 6)):
        dense.submit(r)
    for r in _requests(np.random.default_rng(5), 3, 2, 24, max_new=(2, 6)):
        paged.submit(r)
    assert _gens(dense.run()) == _gens(paged.run())


def test_paged_engine_randomized_stream_matches_dense():
    """Randomized admission/termination order: requests arrive in bursts
    between engine steps, with wildly mixed lengths and budgets."""
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(2), cfg)
    dense = DecodeEngine(params, cfg, batch_slots=3, max_seq=48)
    paged = PagedDecodeEngine(params, cfg, batch_slots=3, max_seq=48,
                              page_size=8, chunk_size=4, use_kernel=False)

    def stream(eng):
        rng = np.random.default_rng(11)
        reqs = _requests(rng, 10, 1, 30, max_new=(1, 8))
        it = iter(reqs)
        pending = len(reqs)
        while pending or eng.queue or any(p != "idle" for p in eng.phase):
            for _ in range(int(rng.integers(0, 3))):  # burst of 0-2 arrivals
                r = next(it, None)
                if r is not None:
                    eng.submit(r)
                    pending -= 1
            eng.step()
        return eng.finished

    assert _gens(stream(dense)) == _gens(stream(paged))


def test_eviction_completes_identically_and_no_leak():
    """A page-starved pool forces head-of-line blocking + recompute
    eviction; outputs must not change, and the allocator must end clean."""
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    ample = PagedDecodeEngine(params, cfg, batch_slots=3, max_seq=48,
                              page_size=4, chunk_size=8, use_kernel=False)
    tiny = PagedDecodeEngine(params, cfg, batch_slots=3, max_seq=48,
                             page_size=4, chunk_size=8, num_pages=1 + 12,
                             use_kernel=False)
    for r in _requests(np.random.default_rng(7), 8, 1, 20):
        ample.submit(r)
    for r in _requests(np.random.default_rng(7), 8, 1, 20):
        tiny.submit(r)
    ga, gt = _gens(ample.run()), _gens(tiny.run())
    assert ga == gt
    assert sum(r.evictions for r in tiny.finished) >= 0  # may or may not fire
    tiny.kv.allocator.check()
    assert tiny.kv.allocator.num_allocated == 0


def test_preemption_drain_releases_all_pages():
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = PagedDecodeEngine(params, cfg, batch_slots=2, max_seq=48,
                            page_size=4, chunk_size=4, use_kernel=False)
    for r in _requests(np.random.default_rng(9), 5, 8, 30, max_new=(20, 30)):
        eng.submit(r)
    done = eng.run(max_steps=3)  # force a mid-flight drain
    assert any(r.preempted for r in done)
    eng.kv.allocator.check()
    assert eng.kv.allocator.num_allocated == 0
    assert (eng.kv.tables == 0).all()


def test_int8_cache_dtype_decodes():
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = PagedDecodeEngine(params, cfg, batch_slots=2, max_seq=32,
                            page_size=4, chunk_size=8, cache_dtype="int8")
    assert not eng.use_kernel  # int8 pages force the gather/dequant path
    assert eng.cache["0"]["k_pages"].dtype == jnp.int8
    assert "k_scale" in eng.cache["0"]
    for r in _requests(np.random.default_rng(4), 3, 2, 16, max_new=(3, 6)):
        eng.submit(r)
    done = eng.run()
    assert all(r.done and len(r.generated) == min(
        r.max_new_tokens, 32 - len(r.prompt)) for r in done)


def test_paged_cache_rejects_recurrent_stacks():
    cfg = get_config("xlstm-125m").reduced()
    with pytest.raises(ValueError, match="attention-only"):
        T.init_paged_cache(cfg, num_pages=4, page_size=4)


# ---------------------------------------------------------------------------
# greedy_generate prefill-cache pad (satellite: layout-keyed, not
# shape-coincidence-keyed)
# ---------------------------------------------------------------------------
def test_greedy_generate_adversarial_prompt_length():
    """xlstm's mlstm cache leaf C is (repeat, B, H, dh, dh): with a prompt
    of length H the old ``x.shape[2] == lp`` heuristic padded the HEAD
    axis of recurrent state, corrupting decode.  The layout-keyed pad
    must leave recurrent leaves alone and still match teacher-forced
    forward argmax."""
    from repro.serve.engine import greedy_generate

    cfg = dataclasses.replace(get_config("xlstm-125m").reduced(),
                              num_layers=2, d_model=64, vocab_size=64)
    params = T.init_model(jax.random.PRNGKey(3), cfg)
    lp = cfg.num_heads  # adversarial: prompt length == head count
    prompt = np.arange(1, lp + 1, dtype=np.int32)
    gen = greedy_generate(params, cfg, prompt, max_new_tokens=4)
    seq = list(prompt)
    for _ in range(4):
        logits, _ = T.forward(params, cfg, tokens=jnp.asarray(seq)[None])
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert gen == seq[lp:]
