"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(≤2 super-blocks, d_model ≤ 512, ≤4 experts) runs one forward pass and one
train step on CPU; output shapes asserted, no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs
from repro.models import transformer as T
from repro.optim import adam
from repro.train.loop import make_loss_fn

ALL_ARCHS = [
    "gemma3-1b", "deepseek-67b", "seamless-m4t-medium", "xlstm-125m",
    "qwen2.5-14b", "qwen2-moe-a2.7b", "granite-moe-1b-a400m", "pixtral-12b",
    "jamba-1.5-large-398b", "qwen2-1.5b",
]

B, L = 2, 16


def _batch(cfg, key):
    batch = {"labels": jax.random.randint(key, (B, L), 0, cfg.vocab_size)}
    if cfg.modality == "vision":
        batch["embeds"] = jax.random.normal(key, (B, L, cfg.d_model),
                                            jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(key, (B, L), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        batch["source_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32) * 0.02
    return batch


def test_all_archs_registered():
    assert set(ALL_ARCHS) <= set(list_configs())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_limits(arch):
    cfg = get_config(arch).reduced()
    specs, repeat = cfg.superblock()
    assert repeat <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch, rng):
    cfg = get_config(arch).reduced()
    params = T.init_model(rng, cfg)
    batch = _batch(cfg, rng)
    memory = None
    if cfg.is_encoder_decoder:
        memory = T.encode(params, cfg, embeds=batch["source_embeds"])
        assert memory.shape == (B, cfg.encoder_seq_len, cfg.d_model)
    logits, aux = T.forward(params, cfg, tokens=batch.get("tokens"),
                            embeds=batch.get("embeds"), memory=memory)
    assert logits.shape == (B, L, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = T.init_model(rng, cfg)
    opt = adam(1e-3)
    loss_fn = make_loss_fn(cfg, remat=False)
    batch = _batch(cfg, rng)

    @jax.jit
    def step(params, opt_state):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, 0)
        return params, opt_state, loss

    p1, _, loss1 = step(params, opt.init(params))
    assert jnp.isfinite(loss1)
    # loss roughly log(V) at init for uniform predictions
    assert float(loss1) < jnp.log(cfg.vocab_size) * 2 + 1
    moved = jax.tree.map(lambda a, b: jnp.any(a != b), params, p1)
    assert any(bool(x) for x in jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = T.init_model(rng, cfg)
    memory = None
    if cfg.is_encoder_decoder:
        memory = T.encode(params, cfg, embeds=jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model)) * 0.02)
    cache = T.init_cache(cfg, B, 32)
    tok = jax.random.randint(rng, (B,), 0, cfg.vocab_size)
    for pos in range(3):
        logits, cache = T.decode_step(params, cfg, token=tok,
                                      pos=jnp.int32(pos), cache=cache,
                                      memory=memory)
        assert logits.shape == (B, cfg.vocab_size)
        assert not jnp.isnan(logits).any()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["gemma3-1b", "qwen2-moe-a2.7b",
                                  "xlstm-125m", "jamba-1.5-large-398b",
                                  "seamless-m4t-medium", "pixtral-12b"])
def test_prefill_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = T.init_model(rng, cfg)
    toks = jax.random.randint(rng, (B, L), 0, cfg.vocab_size)
    embeds = None
    memory = None
    if cfg.modality == "vision":
        embeds = jax.random.normal(rng, (B, L, cfg.d_model)) * 0.02
    if cfg.is_encoder_decoder:
        memory = T.encode(params, cfg, embeds=jax.random.normal(
            rng, (B, 8, cfg.d_model)) * 0.02)
    ref, _ = T.forward(params, cfg, tokens=None if embeds is not None else toks,
                       embeds=embeds, memory=memory)
    pf, cache = T.prefill(params, cfg,
                          tokens=None if embeds is not None else toks[:, :L - 1],
                          embeds=embeds[:, :L - 1] if embeds is not None else None,
                          memory=memory)
    assert jnp.allclose(pf, ref[:, :L - 1], rtol=5e-4, atol=5e-4)

    def pad(x):
        if x.ndim >= 3 and x.shape[2] == L - 1:
            w = [(0, 0)] * x.ndim
            w[2] = (0, 1)
            return jnp.pad(x, w)
        return x

    cache = jax.tree.map(pad, cache)
    lg, _ = T.decode_step(
        params, cfg,
        token=toks[:, L - 1] if embeds is None else None,
        embeds=embeds[:, L - 1:L] if embeds is not None else None,
        pos=jnp.int32(L - 1), cache=cache, memory=memory)
    assert float(jnp.max(jnp.abs(lg - ref[:, L - 1]))) < 5e-3


def test_param_counts_match_published():
    """Analytic N must land on the published model sizes."""
    expected = {
        "gemma3-1b": (0.9e9, 1.1e9),
        "deepseek-67b": (66e9, 69e9),
        "qwen2.5-14b": (14e9, 15.5e9),
        "qwen2-1.5b": (1.4e9, 1.7e9),
        "pixtral-12b": (12e9, 12.6e9),
        "jamba-1.5-large-398b": (390e9, 405e9),
        "qwen2-moe-a2.7b": (14e9, 14.6e9),
        "granite-moe-1b-a400m": (1.2e9, 1.45e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_active_params():
    assert 2.4e9 <= get_config("qwen2-moe-a2.7b").active_param_count() <= 3.0e9
    assert 0.35e9 <= get_config("granite-moe-1b-a400m").active_param_count() <= 0.5e9
    assert 90e9 <= get_config("jamba-1.5-large-398b").active_param_count() <= 96e9
