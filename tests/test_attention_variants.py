"""Attention-path equivalence tests: banded vs dense-masked, cp vs tp,
decode grouped vs full."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.layers import _sdpa, _sdpa_banded, _sdpa_decode


@pytest.mark.parametrize("l,w", [(256, 64), (512, 128), (256, 32)])
@pytest.mark.parametrize("kv", [1, 2, 4])
def test_banded_equals_dense_masked(l, w, kv, rng):
    cfg = ModelConfig(num_heads=4, num_kv_heads=kv)
    b, h, dh = 2, 4, 32
    q = jax.random.normal(rng, (b, l, h, dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, l, kv, dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, l, kv, dh))
    i = jnp.arange(l)[:, None]
    j = jnp.arange(l)[None, :]
    mask = ((j <= i) & (i - j < w))[None, None]
    dense = _sdpa(cfg, q, k, v, mask)
    banded = _sdpa_banded(cfg, q, k, v, w)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


def test_banded_with_softcap(rng):
    cfg = ModelConfig(num_heads=2, num_kv_heads=2, attn_logit_softcap=30.0)
    b, l, h, dh, w = 1, 256, 2, 16, 64
    q, k, v = (jax.random.normal(jax.random.fold_in(rng, i), (b, l, h, dh))
               for i in range(3))
    i_ = jnp.arange(l)[:, None]
    j_ = jnp.arange(l)[None, :]
    mask = ((j_ <= i_) & (i_ - j_ < w))[None, None]
    np.testing.assert_allclose(
        np.asarray(_sdpa_banded(cfg, q, k, v, w)),
        np.asarray(_sdpa(cfg, q, k, v, mask)), atol=2e-5, rtol=2e-5)


def test_decode_grouped_equals_expanded(rng):
    """The grouped decode einsum ≡ expanded full attention on one row."""
    cfg = ModelConfig(num_heads=4, num_kv_heads=2)
    b, s, h, kv, dh = 2, 64, 4, 2, 16
    q = jax.random.normal(rng, (b, 1, h, dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, kv, dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, s, kv, dh))
    pos = 40
    j = jnp.arange(s)[None, None, :]
    mask = j <= pos
    got = _sdpa_decode(cfg, q, k, v, mask[:, None])
    want = _sdpa(cfg, q, k, v, mask[:, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_gemma_window_pattern():
    """gemma3's 5:1 local:global layout survives the config machinery."""
    from repro.configs import get_config

    cfg = get_config("gemma3-1b")
    windows, thetas = cfg.layer_windows()
    assert windows.shape == (26, 1)
    globals_ = [i for i in range(26) if windows[i, 0] == -1]
    assert globals_ == [5, 11, 17, 23]
    assert all(windows[i, 0] == 512 for i in range(26) if i not in globals_)
    assert thetas[5, 0] == 1_000_000.0 and thetas[0, 0] == 10_000.0
