"""Benchmark harness: run.py name filtering / import resilience, and the
committed measured-timing artifact (DESIGN.md §9 schema)."""

import json
import os
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)  # the benchmarks/ package lives at repo root

from benchmarks import run as bench_run  # noqa: E402
from benchmarks.bench_timing import validate  # noqa: E402


def test_run_unknown_name_exits_2_listing_valid_names(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv", ["run.py", "bogus"])
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "unknown benchmark" in err
    for name in bench_run.MODULES:
        assert name in err


def test_run_import_failure_emits_error_row(monkeypatch, capsys):
    """A module that fails at IMPORT still yields its ERROR CSV row and the
    sweep exits 1 — the harness never dies mid-table."""
    monkeypatch.setattr(bench_run, "MODULES", ("zzz_missing",))
    monkeypatch.setattr(sys, "argv", ["run.py"])
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 1
    out = capsys.readouterr().out
    assert "zzz_missing/ERROR,0,failed" in out
    assert out.startswith("name,us_per_call,derived")


def test_committed_timing_artifact_validates():
    """The BENCH_timing.json checked into the repo satisfies the §9
    schema: ≥3 strategies x both precisions, every kernel vs its
    reference, and the compression breakeven table."""
    validate()


def test_timing_validate_rejects_malformed(tmp_path):
    missing = tmp_path / "nope.json"
    with pytest.raises(ValueError):
        validate(str(missing))
    bad = tmp_path / "BENCH_timing.json"
    bad.write_text(json.dumps({"meta": {"backend": "cpu"}}))
    with pytest.raises(ValueError):
        validate(str(bad))
    bad.write_text("not json{")
    with pytest.raises(ValueError):
        validate(str(bad))
