"""Static-analysis linter tier (repro.analysis, DESIGN.md §11) — ISSUE 8
acceptance:

  (a) every HLO/jaxpr rule has a deliberately-BROKEN negative twin
      (collective-budget, promotion-proof, donation-aliasing,
      cond-gating, fused-dispatch, retrace-detector, state-aliasing):
      the lint must catch the regression it encodes, not just bless the
      current code,
  (b) the Report schema round-trips and the validator rejects every
      tampering mode CI relies on it to catch,
  (c) a real sweep cell (the production exchange/loop rigs on
      gemma3-1b) passes end to end, and the committed ``LINT.json``
      validates,
  (d) the ``repro.launch.lint`` CLI exits 2 on unknown config names and
      0 on ``--validate`` of the committed artifact.

All tests carry the ``lint`` marker; CI runs them as their own tier-1
matrix entry (``pytest -m lint``) alongside the bf16/accum/serving jobs.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (CELL_RULES, RULES, Cell, build_report,
                            collective_budget,
                            cond_gating, donation_aliasing, fused_dispatch,
                            gating_ratio, promotion_proof, result, retrace,
                            state_aliasing, tree_snapshot, validate,
                            validate_file, violations)
from repro.analysis import rigs
from repro.train.loop import jit_cache_size

pytestmark = pytest.mark.lint

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# hand-crafted HLO lines (the textual shape every rule parses)
# ---------------------------------------------------------------------------
def _hlo(*instrs):
    body = "\n".join(f"  %{op}.{i} = {shape} {op}({operand}), channel_id=1"
                     for i, (op, shape, operand) in enumerate(instrs))
    return f"ENTRY %main () -> f32[] {{\n{body}\n}}\n"


WIRE_AR = ("all-reduce", "f32[4096]{0}", "f32[4096]{0} %p0")
WIRE_RS = ("reduce-scatter", "f32[1024]{0}", "f32[4096]{0} %p0")
WIRE_AG = ("all-gather", "f32[4096]{0}", "f32[1024]{0} %p0")
SCALAR_AR = ("all-reduce", "f32[]", "f32[] %loss")
BF16_AG = ("all-gather", "u16[4,2048]{1,0}", "u16[1,2048]{1,0} %p0")
F32_AG = ("all-gather", "f32[4,2048]{1,0}", "f32[1,2048]{1,0} %p0")
TUPLE_A2A = ("all-to-all",
             "(f32[1,1024]{1,0}, f32[1,1024]{1,0})",
             "f32[1,1024]{1,0} %c0, f32[1,1024]{1,0} %c1")


# ---------------------------------------------------------------------------
# collective-budget
# ---------------------------------------------------------------------------
def test_collective_budget_accepts_contract_and_scalars():
    txt = _hlo(WIRE_RS, WIRE_RS, WIRE_AG, SCALAR_AR)
    res = collective_budget(txt, {"reduce-scatter": 2, "all-gather": 2})
    assert res.status == "pass", res.findings
    assert res.details["scalar"] == 1


def test_collective_budget_flags_per_leaf_collectives():
    """The bug class the fabric exists to prevent: one collective PER
    LEAF (12 here) instead of per bucket (budget 3)."""
    txt = _hlo(*([WIRE_AR] * 12))
    res = collective_budget(txt, {"all-reduce": 3})
    assert res.status == "fail"
    assert "12 wire instruction(s) exceed budget 3" in res.findings[0]


def test_collective_budget_flags_stray_allreduce_on_zero1():
    """ZeRO-1 contract has NO all-reduce: a full-gradient all-reduce
    sneaking in next to the reduce-scatters must fail the budget."""
    txt = _hlo(WIRE_RS, WIRE_AG, WIRE_AR)
    res = collective_budget(txt, {"reduce-scatter": 2, "all-gather": 2})
    assert res.status == "fail"
    assert any("all-reduce" in f for f in res.findings)


def test_collective_budget_flags_scalar_flood_and_empty_wire():
    # more scalar collectives than the allowance
    res = collective_budget(_hlo(*([SCALAR_AR] * 5)), {})
    assert res.status == "fail"
    assert "scalar collectives exceed allowance" in res.findings[0]
    # a non-empty contract with zero wire collectives: exchange traced away
    res = collective_budget(_hlo(SCALAR_AR), {"all-reduce": 3})
    assert res.status == "fail"
    assert "no wire collective compiled" in res.findings[0]


# ---------------------------------------------------------------------------
# promotion-proof
# ---------------------------------------------------------------------------
def test_promotion_proof_skips_wide_wire_and_accepts_narrow():
    assert promotion_proof(_hlo(F32_AG), narrow_wire=False).status == "skip"
    res = promotion_proof(_hlo(BF16_AG, TUPLE_A2A), narrow_wire=True)
    # u16 gathers + tuple-materialized a2a (the XLA:CPU shape of a bf16
    # all-to-all) are the proven-good narrow wire
    assert res.status == "pass", res.findings


def test_promotion_proof_flags_f32_payload_on_narrow_wire():
    res = promotion_proof(_hlo(BF16_AG, F32_AG), narrow_wire=True)
    assert res.status == "fail"
    assert "f32 payload" in res.findings[0]


# ---------------------------------------------------------------------------
# donation-aliasing (real compiled modules: donate vs not)
# ---------------------------------------------------------------------------
def _compiled_alias_bytes(donate: bool):
    state = {"w": jnp.ones((64, 64), jnp.float32)}

    def fn(s, x):
        return {"w": s["w"] * 0.9 + x}

    jfn = jax.jit(fn, donate_argnums=(0,) if donate else ())
    mem = jfn.lower(state, 1.0).compile().memory_analysis()
    return int(getattr(mem, "alias_size_in_bytes", 0) or 0), 64 * 64 * 4


def test_donation_aliasing_passes_on_donated_step():
    alias, donated = _compiled_alias_bytes(donate=True)
    res = donation_aliasing(alias, donated)
    assert res.status == "pass", res.findings
    assert res.details["frac"] >= 0.5


def test_donation_aliasing_flags_undonated_step():
    alias, donated = _compiled_alias_bytes(donate=False)
    res = donation_aliasing(alias, donated)
    assert res.status == "fail"
    assert "donation had no effect" in res.findings[0]


def test_donation_aliasing_flags_partial_aliasing():
    res = donation_aliasing(alias_bytes=100, donated_bytes=1000)
    assert res.status == "fail"
    assert "10.0%" in res.findings[0]


# ---------------------------------------------------------------------------
# cond-gating (real jaxprs: lax.cond gate vs jnp.where gate)
# ---------------------------------------------------------------------------
def _gated_jaxpr(gate: str):
    def sync(v):
        return jax.lax.psum(v, "i") / 4.0

    def good(x, t):
        return jax.lax.cond(t % 4 == 0, sync, lambda v: v, x)

    def bad(x, t):
        # the regression this rule encodes: a jnp.where gate COMPUTES the
        # psum every step and discards it — sync_every× the wire bytes
        return jnp.where(t % 4 == 0, sync(x), x)

    fn = good if gate == "cond" else bad
    return jax.make_jaxpr(fn, axis_env=[("i", 4)])(
        jnp.ones(8, jnp.float32), jnp.zeros((), jnp.int32))


def test_cond_gating_passes_on_lax_cond_gate():
    res = cond_gating(_gated_jaxpr("cond"), gated=True)
    assert res.status == "pass", res.findings
    assert res.details["under_cond"] == res.details["collectives"] > 0


def test_cond_gating_flags_where_gate():
    res = cond_gating(_gated_jaxpr("where"), gated=True)
    assert res.status == "fail"
    assert "outside any lax.cond branch" in res.findings[0]


def test_cond_gating_flags_traced_away_exchange():
    jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones(4))
    res = cond_gating(jaxpr, gated=True)
    assert res.status == "fail"
    assert "traced away" in res.findings[0]
    assert cond_gating(jaxpr, gated=False).status == "skip"


def test_gating_ratio_bounds():
    assert gating_ratio(800.0, 100.0, sync_every=8).status == "pass"
    res = gating_ratio(800.0, 700.0, sync_every=8)  # where-gate byte shape
    assert res.status == "fail"
    assert gating_ratio(0.0, 0.0, sync_every=8).status == "fail"


# ---------------------------------------------------------------------------
# fused-dispatch (real traced exchange_dgc, fused on vs off)
# ---------------------------------------------------------------------------
FUSED_PARAMS = {"w": jax.ShapeDtypeStruct((4096,), jnp.float32)}


def test_fused_dispatch_passes_on_fused_path():
    art = rigs.fused_artifacts(FUSED_PARAMS, "f32", fused=True)
    res = fused_dispatch(art["jaxpr_text"], art["codec_calls"])
    assert res.status == "pass", res.findings


def test_fused_dispatch_flags_jnp_fallback():
    art = rigs.fused_artifacts(FUSED_PARAMS, "f32", fused=False)
    res = fused_dispatch(art["jaxpr_text"], art["codec_calls"])
    assert res.status == "fail"
    msgs = " | ".join(res.findings)
    assert "no pallas_call" in msgs and "jnp codec invoked" in msgs
    assert fused_dispatch(art["jaxpr_text"], art["codec_calls"],
                          expect_fused=False).status == "skip"


# ---------------------------------------------------------------------------
# retrace-detector (real jit cache growth)
# ---------------------------------------------------------------------------
def test_retrace_passes_on_stable_shapes():
    f = jax.jit(lambda x: x * 2.0)
    sizes = []
    for _ in range(3):
        f(jnp.ones(4))
        sizes.append(jit_cache_size(f))
    res = retrace(sizes)
    assert res.status == "pass", res.findings


def test_retrace_flags_shape_driven_recompilation():
    f = jax.jit(lambda x: x * 2.0)
    sizes = []
    for n in (4, 8, 16):  # shape change every call: silent retraces
        f(jnp.ones(n))
        sizes.append(jit_cache_size(f))
    res = retrace(sizes)
    assert res.status == "fail"
    assert any("retrace at step" in f_ for f_ in res.findings)
    assert retrace([]).status == "fail"
    assert retrace([2]).status == "fail"  # two variants after first call


# ---------------------------------------------------------------------------
# state-aliasing (pytree mutation detector)
# ---------------------------------------------------------------------------
def test_state_aliasing_clean_update_passes():
    state = {"velocity": [jnp.ones(4)], "t": jnp.zeros(())}
    before = tree_snapshot(state)
    _ = {"velocity": [state["velocity"][0] + 1], "t": state["t"] + 1}
    res = state_aliasing(before, tree_snapshot(state))
    assert res.status == "pass", res.findings


def test_state_aliasing_flags_inplace_mutation():
    state = {"velocity": [jnp.ones(4)], "t": jnp.zeros(())}
    before = tree_snapshot(state)
    state["velocity"][0] = state["velocity"][0] + 1  # the PR-2 bug class
    state["extra"] = 1
    res = state_aliasing(before, tree_snapshot(state))
    assert res.status == "fail"
    msgs = " | ".join(res.findings)
    assert "replaced in place" in msgs and "inserted into the argument" in msgs


# ---------------------------------------------------------------------------
# report schema + validator tampering modes
# ---------------------------------------------------------------------------
def _mini_report():
    cells = [Cell("gemma3-1b", "sync", "f32", 1,
                  [result(r, []) for r in CELL_RULES])]
    return build_report(cells, {"backend": "cpu", "jax": jax.__version__,
                                "smoke": True, "workers": 4})


def test_report_roundtrip_validates(tmp_path):
    rep = _mini_report()
    validate(rep)
    p = tmp_path / "LINT.json"
    p.write_text(json.dumps(rep))
    assert validate_file(str(p))["summary"]["pass"] == len(CELL_RULES)


def test_result_constructor_guards():
    with pytest.raises(ValueError, match="unknown rule"):
        result("no-such-rule", [])
    with pytest.raises(ValueError, match="fail with no findings"):
        from repro.analysis import RuleResult
        RuleResult("retrace-detector", "fail", [])
    assert result("retrace-detector", [], skip="why").status == "skip"
    assert result("retrace-detector", ["boom"]).status == "fail"


@pytest.mark.parametrize("tamper,msg", [
    (lambda r: r.pop("summary"), "missing section"),
    (lambda r: r["meta"].pop("workers"), "meta missing"),
    (lambda r: r["meta"].update(schema=2), "unsupported schema"),
    (lambda r: r.update(cells=[]), "empty cell list"),
    (lambda r: r.update(cells=r["cells"] * 2), "duplicate cell"),
    (lambda r: r["cells"][0]["rules"].pop(), "missing rules"),
    (lambda r: r["cells"][0]["rules"][0].update(status="bogus"),
     "bad status"),
    (lambda r: r["summary"].update(cells=99), "cell count mismatch"),
])
def test_validate_rejects_tampering(tamper, msg):
    rep = _mini_report()
    tamper(rep)
    with pytest.raises(ValueError, match=msg):
        validate(rep)


def test_validate_rejects_failing_report():
    rep = _mini_report()
    rep["cells"][0]["rules"][0].update(status="fail",
                                       findings=["stray all-reduce"])
    assert violations(rep) == \
        ["gemma3-1b/sync/f32/accum1: collective-budget: stray all-reduce"]
    with pytest.raises(ValueError, match="rule violation"):
        validate(rep)


def test_validate_file_missing(tmp_path):
    with pytest.raises(ValueError, match="missing"):
        validate_file(str(tmp_path / "nope.json"))


# ---------------------------------------------------------------------------
# end-to-end: a real sweep cell + the committed artifact + CLI exits
# ---------------------------------------------------------------------------
def test_sweep_cell_passes_on_production_rigs():
    """One real matrix cell (exchange + loop + eager rigs) through
    evaluate_cell: all seven rules report, none fail."""
    out = _run("""
        import jax
        from repro.analysis import report as R
        from repro.analysis import sweep as SW

        cells, stats = SW.sweep(configs=("gemma3-1b",),
                                strategies=("sync", "local_sgd"),
                                precisions=("f32",), accums=(1,))
        rep = R.build_report(cells, {"backend": jax.default_backend(),
                                     "jax": jax.__version__,
                                     "smoke": True, "workers": 4})
        R.validate(rep)
        assert stats["rigs_built"] > 0
        print("LINT_CELL_OK", rep["summary"])
    """)
    assert "LINT_CELL_OK" in out


def test_committed_artifact_validates():
    """CI contract: the committed LINT.json is schema-valid with zero
    violations (the lint job re-checks after a smoke rerun)."""
    validate_file(os.path.join(ROOT, "LINT.json"))


def test_lint_cli_exit_codes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    bad = subprocess.run([sys.executable, "-m", "repro.launch.lint",
                          "--arch", "bogus"], capture_output=True,
                         text=True, env=env, timeout=120)
    assert bad.returncode == 2
    assert "unknown config 'bogus'" in bad.stderr.splitlines()[0]
    ok = subprocess.run([sys.executable, "-m", "repro.launch.lint",
                         "--validate"], capture_output=True, text=True,
                        env=env, cwd=ROOT, timeout=120)
    assert ok.returncode == 0, ok.stderr[-2000:]
    assert "OK" in ok.stdout
