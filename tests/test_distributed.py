"""Distribution tests that need >1 device: run in a subprocess with
xla_force_host_platform_device_count (the main test process must keep the
single real device — see conftest)."""

import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_ep_matches_dense():
    """Expert-parallel shard_map MoE ≡ dense reference (fwd + grads)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.core.jax_compat import make_mesh, set_mesh
        from repro.models import layers as L

        cfg = ModelConfig(d_model=64, num_experts=8, top_k=2, moe_d_ff=128,
                          expert_pad_to=4, capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = L.init_moe(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 2048, 64)) * 0.5

        def loss(p):
            o, a = L.moe(p, cfg, x)
            return jnp.sum(o ** 2) + a

        d_out, _ = L._moe_dense(p, cfg, x)
        g_d = jax.grad(loss)(p)
        mesh = make_mesh((2, 4), ("data", "model"))
        with set_mesh(mesh):
            e_out, _ = jax.jit(lambda p, x: L.moe(p, cfg, x))(p, x)
            g_e = jax.jit(jax.grad(loss))(p)
        assert float(jnp.max(jnp.abs(d_out - e_out))) < 1e-4
        for k in ("router", "w_gate", "w_up", "w_down"):
            rel = float(jnp.max(jnp.abs(g_e[k] - g_d[k]))
                        / (jnp.max(jnp.abs(g_d[k])) + 1e-9))
            assert rel < 1e-3, (k, rel)
        print("EP_OK")
    """)
    assert "EP_OK" in out


def test_sharded_forward_matches_single_device():
    """Mesh-sharded forward (tp and cp modes) ≡ unsharded numerics."""
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core.jax_compat import make_mesh, set_mesh
        from repro.models import transformer as T

        cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(),
                                  num_layers=2)
        params = T.init_model(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                  cfg.vocab_size)
        ref, _ = T.forward(params, cfg, tokens=toks)
        mesh = make_mesh((2, 4), ("data", "model"))
        for mode in ("tp", "cp"):
            mcfg = dataclasses.replace(cfg, sharding_mode=mode)
            with set_mesh(mesh):
                got, _ = jax.jit(lambda p, t: T.forward(p, mcfg, tokens=t))(
                    params, toks)
            err = float(jnp.max(jnp.abs(got - ref)))
            assert err < 5e-4, (mode, err)
            print(f"{mode}_OK err={err:.1e}")
    """)
    assert "tp_OK" in out and "cp_OK" in out


def test_dryrun_lower_compile_small_mesh():
    """End-to-end dry-run machinery on a small (2,2,2) pod mesh: lower +
    compile + memory/cost analysis for a truncated arch (train + decode)."""
    out = _run("""
        import jax
        from repro.core.jax_compat import cost_analysis, make_mesh, set_mesh
        from repro.launch.specs import build_step, resolve_config, truncate

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        for arch, shape in (("gemma3-1b", "train_4k"),
                            ("qwen2-moe-a2.7b", "decode_32k"),
                            ("xlstm-125m", "long_500k")):
            cfg = truncate(resolve_config(arch, shape), 1)
            step, sds, sh, don = build_step(cfg, shape, mesh)
            with set_mesh(mesh):
                comp = jax.jit(step, in_shardings=sh,
                               donate_argnums=don).lower(*sds).compile()
            assert cost_analysis(comp).get("flops", 0) > 0
            assert comp.memory_analysis().argument_size_in_bytes > 0
            print(f"{arch}/{shape}_OK")
    """, devices=8)
    for tag in ("gemma3-1b/train_4k_OK", "qwen2-moe-a2.7b/decode_32k_OK",
                "xlstm-125m/long_500k_OK"):
        assert tag in out


def test_production_mesh_construction():
    out = _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("MESH_OK")
    """, devices=512)
    assert "MESH_OK" in out


def test_compressed_pod_exchange_lowers_and_reduces_wire():
    """The paper's §2.2.4 compression on the cross-pod tier: lowering
    succeeds and the compiled HLO moves ~10× fewer bytes with the packed
    1-bit wire format than the f32 psum baseline."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.compression import get_compressor
        from repro.core.jax_compat import make_mesh, set_mesh, shard_map
        from repro.launch.exchange import build_exchange
        from repro.roofline.analysis import parse_collectives

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"w": jax.ShapeDtypeStruct((2, 4096, 256), jnp.float32)}
        sh = {"w": NamedSharding(mesh, P("pod", "data", "model"))}
        totals = {}
        for name in ("none", "onebit"):
            comp = None if name == "none" else get_compressor(name)
            fn = shard_map(build_exchange(comp), mesh=mesh,
                           axis_names={"pod"},
                           in_specs=(P("pod"), P("pod")),
                           out_specs=(P("pod"), P("pod")),
                           check_vma=False)
            with set_mesh(mesh):
                c = jax.jit(fn).lower(g, g).compile()
            totals[name] = sum(parse_collectives(c.as_text())["bytes"].values())
        ratio = totals["none"] / max(totals["onebit"], 1)
        assert ratio > 5, totals
        print(f"EXCHANGE_OK ratio={ratio:.1f}")
    """)
    assert "EXCHANGE_OK" in out
