"""Compression tests (paper §2.2.4): correctness, error feedback,
wire-size accounting, and hypothesis invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.compression import (ef_compress_tree, ef_init, get_compressor,
                                    pack_signs, unpack_signs, wire_bytes)


@pytest.mark.parametrize("name,kw", [
    ("none", {}), ("onebit", {"block": 64}), ("int8", {"block": 64}),
    ("topk", {"ratio": 0.1, "block": 64}),
])
def test_roundtrip_shapes(name, kw, rng):
    comp = get_compressor(name, **kw)
    x = jax.random.normal(rng, (7, 33))
    wire, meta = comp.compress(x)
    y = comp.decompress(wire, meta, x.shape, x.dtype)
    assert y.shape == x.shape and y.dtype == x.dtype


def test_int8_accuracy(rng):
    comp = get_compressor("int8", block=128)
    x = jax.random.normal(rng, (1024,))
    wire, meta = comp.compress(x)
    y = comp.decompress(wire, meta, x.shape, x.dtype)
    assert float(jnp.max(jnp.abs(x - y))) < float(jnp.max(jnp.abs(x))) / 100


def test_topk_keeps_largest(rng):
    comp = get_compressor("topk", ratio=0.25, block=16)
    # distinct magnitudes (no ties): |x| largest at indices 3, 7, 11, 15
    x = jnp.asarray([0.1, -0.2, 0.3, -9.0, 0.4, -0.5, 0.6, 8.0,
                     -0.7, 0.8, -0.9, 7.0, 1.0, -1.1, 1.2, -6.0])
    wire, meta = comp.compress(x)
    y = comp.decompress(wire, meta, x.shape, x.dtype)
    kept = jnp.nonzero(y)[0]
    assert set(int(i) for i in np.array(kept)) == {3, 7, 11, 15}


def test_error_feedback_preserves_signal(rng):
    """EF invariant: residual + decoded == accumulated gradient mass —
    nothing is silently lost (the reason 1-bit SGD converges)."""
    comp = get_compressor("onebit", block=32)
    g = {"a": jax.random.normal(rng, (64,)),
         "b": jax.random.normal(jax.random.fold_in(rng, 1), (8, 16))}
    r = ef_init(g)
    g_hat, r1 = ef_compress_tree(comp, g, r)
    for k in g:
        np.testing.assert_allclose(
            np.asarray(g_hat[k] + r1[k]), np.asarray(g[k]), atol=1e-5)


def test_error_feedback_unbiased_over_time(rng):
    """Feeding the SAME gradient repeatedly, the mean decoded output
    converges to the true gradient (EF removes the quantization bias)."""
    comp = get_compressor("onebit", block=16)
    g = {"w": jax.random.normal(rng, (64,))}
    r = ef_init(g)
    acc = jnp.zeros_like(g["w"])
    n = 200
    for _ in range(n):
        g_hat, r = ef_compress_tree(comp, g, r)
        acc = acc + g_hat["w"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               atol=0.15)  # EF cycles can orbit; bias → 0 slowly


def test_wire_bytes_accounting():
    g = {"w": jnp.zeros((1000,))}
    full = wire_bytes(get_compressor("none"), g)
    onebit = wire_bytes(get_compressor("onebit", block=256), g)
    topk = wire_bytes(get_compressor("topk", ratio=0.01, block=1000), g)
    assert full == 4000
    assert onebit < full / 25  # ~32× minus scale overhead
    assert topk < full / 15  # 1% of (32+16)-bit entries


def test_pack_unpack_signs(rng):
    sign = jnp.where(jax.random.normal(rng, (128,)) > 0, 1, -1).astype(jnp.int8)
    packed = pack_signs(sign)
    assert packed.size == 16  # true 1-bit wire format
    np.testing.assert_array_equal(np.asarray(unpack_signs(packed, 128)),
                                  np.asarray(sign))


@given(st.integers(0, 2**31 - 1), st.sampled_from(["onebit", "int8"]))
@settings(max_examples=25, deadline=None)
def test_property_decode_magnitude_bounded(seed, name):
    """Decoded output magnitude never exceeds the block max (quantizers
    are non-expansive on the block max-norm)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64))
    comp = get_compressor(name, block=64)
    wire, meta = comp.compress(x)
    y = comp.decompress(wire, meta, x.shape, x.dtype)
    assert float(jnp.max(jnp.abs(y))) <= float(jnp.max(jnp.abs(x))) + 1e-6


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_property_topk_sparsity(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    comp = get_compressor("topk", ratio=0.0625, block=64)
    wire, meta = comp.compress(x)
    y = comp.decompress(wire, meta, x.shape, x.dtype)
    nnz = int(jnp.sum(y != 0))
    assert nnz <= 4 * 4  # k per block × nblocks
