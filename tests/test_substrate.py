"""Substrate tests: optimizers, data pipeline, checkpointing, losses,
staleness tooling."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.staleness import effective_momentum_fit, implicit_momentum
from repro.data.pipeline import (DataConfig, bayes_entropy, global_batch,
                                 sample_batch, worker_batches)
from repro.optim import (adam, constant_schedule, cosine_schedule,
                         delay_compensated_sgd, momentum, sgd, warmup_cosine)
from repro.train.losses import cross_entropy, lm_loss


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def _quadratic(opt, steps=200):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for t in range(steps):
        g = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(g, state, params, jnp.int32(t))
    return float(jnp.max(jnp.abs(params["w"] - target)))


@pytest.mark.parametrize("opt", [
    sgd(0.1), momentum(0.05, 0.9), momentum(0.05, 0.9, nesterov=True),
    adam(0.1), delay_compensated_sgd(0.1),
])
def test_optimizers_converge(opt):
    assert _quadratic(opt) < 1e-2


def test_schedules():
    s = warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(s(0)) < 0.2
    assert abs(float(s(10)) - 1.0) < 1e-5
    assert float(s(109)) < 0.2
    c = cosine_schedule(1.0, 100)
    assert float(c(0)) == pytest.approx(1.0)
    assert float(constant_schedule(0.5)(123)) == 0.5


def test_weight_decay_shrinks():
    opt = sgd(0.1, weight_decay=0.1)
    params = {"w": jnp.ones(3)}
    p1, _ = opt.update({"w": jnp.zeros(3)}, opt.init(params), params, 0)
    assert float(p1["w"][0]) < 1.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
CFG = DataConfig(vocab_size=97, seq_len=32, batch_per_worker=4, seed=3)


def test_data_deterministic():
    a = sample_batch(CFG, worker=1, step=5)
    b = sample_batch(CFG, worker=1, step=5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_disjoint_across_workers_and_steps():
    a = sample_batch(CFG, 0, 0)
    b = sample_batch(CFG, 1, 0)
    c = sample_batch(CFG, 0, 1)
    assert not np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_data_has_structure():
    """Most transitions follow the affine successor — learnable signal."""
    toks = np.asarray(sample_batch(CFG, 0, 0))
    succ = (CFG.a * toks[:, :-1] + CFG.b) % CFG.vocab_size
    frac = float((toks[:, 1:] == succ).mean())
    assert 0.75 < frac < 1.0


def test_data_shapes_and_range():
    ws = worker_batches(CFG, 3, 0)
    assert ws.shape == (3, 4, 32)
    gb = global_batch(CFG, 0, 12)
    assert gb.shape == (12, 32)
    assert int(gb.min()) >= 0 and int(gb.max()) < CFG.vocab_size


def test_bayes_entropy_below_uniform():
    assert 0 < bayes_entropy(CFG) < np.log(CFG.vocab_size)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def test_cross_entropy_perfect_prediction():
    logits = jnp.full((2, 4, 8), -20.0)
    labels = jnp.array([[1, 2, 3, 4], [5, 6, 7, 0]])
    logits = logits.at[jnp.arange(2)[:, None], jnp.arange(4)[None], labels].set(20.0)
    assert float(cross_entropy(logits, labels)) < 1e-3


def test_lm_loss_shift():
    v = 16
    logits = jnp.zeros((1, 5, v))
    toks = jnp.array([[1, 2, 3, 4, 5]])
    assert float(lm_loss(logits, toks)) == pytest.approx(np.log(v), rel=1e-5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"params": {"w": jax.random.normal(rng, (4, 3)),
                       "layers": [jnp.ones(2), jnp.zeros(3)]},
            "step": jnp.int32(7)}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    assert latest_step(d) == 7
    back = restore_checkpoint(d, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_multiple_steps(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 5, 3):
        save_checkpoint(d, s, {"x": jnp.ones(1) * s})
    assert latest_step(d) == 5


# ---------------------------------------------------------------------------
# staleness / implicit momentum
# ---------------------------------------------------------------------------
def test_implicit_momentum_prediction():
    assert implicit_momentum(1) == 0.0
    assert implicit_momentum(4) == pytest.approx(0.75)


def test_effective_momentum_fit_recovers_beta():
    """Synthesize momentum-SGD trajectory; fit must recover β."""
    rng = np.random.default_rng(0)
    beta, lr, dim, T = 0.8, 0.01, 20, 400
    w = np.zeros(dim)
    m = np.zeros(dim)
    traj = [w.copy()]
    for _ in range(T):
        g = 2 * w - 1.0 + 0.01 * rng.normal(size=dim)
        m = beta * m + g
        w = w - lr * m
        traj.append(w.copy())
    beta_hat = effective_momentum_fit(np.stack(traj))
    # the AR(1) fit is biased by loss curvature; accept the right ballpark
    assert abs(beta_hat - beta) < 0.25
