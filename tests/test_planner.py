"""Auto-parallelism planner tests (launch/planner.py, DESIGN.md §12).

Covers the candidate model (valid TP degrees, launchability, baseline
membership), the committed ``PLAN.json`` artifact (schema, exact
re-derivation, large-config margins, LINT cross-check), tamper
detection, and the CLI exit codes.
"""

import copy
import json
import os
import subprocess
import sys

import pytest

from repro.configs.base import get_config
from repro.launch import planner as PL
from repro.launch.specs import SHAPES

pytestmark = pytest.mark.tp

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PLAN = os.path.join(ROOT, "PLAN.json")
SHAPE = SHAPES["train_4k"]


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.plan", *args],
        capture_output=True, text=True, env=env, timeout=300)


# ---------------------------------------------------------------------------
# candidate space
# ---------------------------------------------------------------------------
def test_tp_valid_degrees_divide_all_split_axes():
    cfg = get_config("deepseek-67b")
    degs = PL.tp_valid_degrees(cfg)
    assert degs[0] == 1 and len(degs) > 1
    for t in degs[1:]:
        assert cfg.num_heads % t == 0
        assert cfg.num_kv_heads % t == 0
        assert cfg.d_ff % t == 0


def test_tp_valid_degrees_edge_cases():
    # gemma3-1b has a single KV head: nothing above 1 divides it
    assert PL.tp_valid_degrees(get_config("gemma3-1b")) == (1,)
    # SSM stacks have no row-parallel contraction to split
    ssm = [n for n in PL.plan_configs()
           if get_config(n).family == "ssm"]
    for n in ssm:
        assert PL.tp_valid_degrees(get_config(n)) == (1,)


def test_candidate_cost_rejects_unlaunchable():
    cfg = get_config("gemma3-1b")
    # tp=2 is not a valid degree for kv=1
    assert PL.candidate_cost(cfg, SHAPE, 2, 0, 1, "bf16") is None
    # batch indivisible by dp*accum
    cfg2 = get_config("qwen2-1.5b")
    bad = SHAPES["train_4k"].__class__("odd", 128, 257, "train")
    assert PL.candidate_cost(cfg2, bad, 1, 0, 4, "bf16") is None


def test_candidate_cost_fields_and_monotone_state():
    cfg = get_config("deepseek-67b")
    by_stage = {z: PL.candidate_cost(cfg, SHAPE, 1, z, 1, "bf16")
                for z in PL.ZERO_STAGES}
    for z, c in by_stage.items():
        assert c is not None
        assert c["strategy"] == PL.ZERO_STRATEGY[z]
        assert c["step_s"] > 0 and c["dp"] == PL.DEVICES
    # each ZeRO stage strictly shrinks resident train state
    states = [by_stage[z]["state_bytes"] for z in (0, 1, 3)]
    assert states[0] > states[1] > states[2]
    # and stage 3's parameter shrink is the W× roofline claim
    assert by_stage[3]["state_bytes"] < by_stage[0]["state_bytes"] / 10


def test_plan_is_deterministic_and_beats_baseline():
    a = PL.plan_config("qwen2-moe-a2.7b")
    b = PL.plan_config("qwen2-moe-a2.7b")
    assert a == b
    # baseline is IN the candidate set, so chosen can never lose to it
    assert a["speedup_vs_dp"] >= 1.0


# ---------------------------------------------------------------------------
# committed artifact
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def committed():
    with open(PLAN) as f:
        return json.load(f)


def test_committed_plan_validates_with_lint_crosscheck(committed):
    assert os.path.exists(os.path.join(ROOT, "LINT.json"))
    rep = PL.validate_file(PLAN)  # auto-loads LINT.json alongside
    assert rep["summary"]["configs"] == len(PL.plan_configs())
    assert not rep["meta"]["smoke"]


def test_committed_large_configs_clear_margin(committed):
    by_name = {p["config"]: p for p in committed["plans"]}
    for name in PL.LARGE_CONFIGS:
        assert by_name[name]["speedup_vs_dp"] >= PL.LARGE_MARGIN, name


def test_validate_rejects_tampered_cost(committed):
    rep = copy.deepcopy(committed)
    rep["plans"][0]["chosen"]["step_s"] *= 0.5
    with pytest.raises(ValueError, match="re-derived"):
        PL.validate(rep, "PLAN.json")


def test_validate_rejects_bad_strategy_mapping(committed):
    rep = copy.deepcopy(committed)
    rep["plans"][0]["chosen"]["strategy"] = "gossip"
    with pytest.raises(ValueError, match="strategy"):
        PL.validate(rep, "PLAN.json")


def test_validate_rejects_missing_config(committed):
    rep = copy.deepcopy(committed)
    dropped = rep["plans"].pop()
    rep["summary"]["configs"] -= 1
    with pytest.raises(ValueError, match="missing"):
        PL.validate(rep, "PLAN.json")
    assert dropped["config"]  # sanity: we really removed a plan


def test_validate_rejects_failing_lint_cell(committed):
    p0 = committed["plans"][0]
    key = (p0["config"], p0["chosen"]["strategy"],
           p0["chosen"]["precision"], p0["chosen"]["accum_steps"])
    lint = {"cells": [{"config": key[0], "strategy": key[1],
                       "precision": key[2], "accum": key[3],
                       "rules": [{"status": "fail"}]}]}
    with pytest.raises(ValueError, match="lint"):
        PL.validate(copy.deepcopy(committed), "PLAN.json",
                    lint_report=lint)


def test_smoke_report_builds_and_validates(tmp_path):
    rep = PL.build_report(smoke=True,
                          timing_path=os.path.join(ROOT,
                                                   "BENCH_timing.json"))
    assert [p["config"] for p in rep["plans"]] == list(PL.SMOKE_CONFIGS)
    PL.validate(rep, "PLAN.json")  # smoke skips the full-roster checks


def test_compression_advisory_from_measured_bench():
    adv = PL.compression_advisory(os.path.join(ROOT, "BENCH_timing.json"))
    assert adv["source"] == "BENCH_timing.json"
    # measured encode overhead puts breakeven far below the modeled ICI
    # link, so the planner refuses to add a codec
    assert 0 < adv["best_breakeven_gbps"] < adv["link_gbps"]
    assert adv["compression_pays"] is False
    # missing file degrades to "no evidence, no codec"
    none = PL.compression_advisory("/nonexistent/timing.json")
    assert none["source"] is None and none["compression_pays"] is False


# ---------------------------------------------------------------------------
# CLI exit codes
# ---------------------------------------------------------------------------
def test_cli_unknown_config_exits_2():
    out = _cli("--arch", "nope-7b")
    assert out.returncode == 2
    assert "valid names" in out.stderr
    assert "deepseek-67b" in out.stderr


def test_cli_validate_committed_artifact():
    out = _cli("--validate")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_cli_single_arch_plans():
    out = _cli("--arch", "gemma3-1b")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "gemma3-1b:" in out.stdout
