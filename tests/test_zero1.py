"""Partitioned (ZeRO-1) exchange tests — ISSUE 3 acceptance:

  (a) ``sync_zero1`` is numerically equivalent to ``sync`` + full
      optimizer state on a multi-layer model,
  (b) the lowered HLO of the partitioned path contains reduce-scatter +
      all-gather (≤ n_buckets each) and NO full gradient all-reduce,
  (c) per-worker optimizer-state leaves are ~1/W of the dense path,
  (d) ``local_sgd(sync_every=8)`` ships ~1/8 the collective bytes after
      the ``lax.cond`` gating fix,

plus the partitioned checkpoint round-trip (save sharded at W → restore
re-sharded at W′) and the atomic-write guarantee.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, read_meta, restore_checkpoint,
                              save_checkpoint)
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.core.fabric import Fabric
from repro.optim import adam, momentum, sgd
from repro.train.loop import init_train_state, make_replica_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
W = 4


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# fabric: partitioned exchange ≡ fused all-mean
# ---------------------------------------------------------------------------
def test_partitioned_exchange_matches_all_mean(rng):
    """reduce-scatter(mean) + all-gather over awkward (padded) bucket sizes
    reproduces the dense fused all-mean exactly."""
    tree = {"a": jax.random.normal(rng, (W, 13)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (W, 7, 9)),
            "c": jax.random.normal(jax.random.fold_in(rng, 2), (W, 301))}
    fab = Fabric(LocalComm(W), bucket_bytes=4 * 100)
    play = fab.partitioned_layout(tree)
    assert play.n_parts == W
    assert all(p % W == 0 for p in play.padded_sizes)
    shards, m = fab.exchange_partitioned(tree, play)
    got = fab.unpartition(shards, play)
    ref = fab.all_mean(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-6)
    assert float(m["wire_bytes"]) == fab.flat_bytes(tree)


def test_shard_params_roundtrip(rng):
    """Slicing a replicated tree into per-worker shards and gathering back
    is the identity (padding dropped, dtypes restored)."""
    base = {"w": jax.random.normal(rng, (5, 11)),
            "b": jax.random.normal(jax.random.fold_in(rng, 3), (17,))}
    comm = LocalComm(W)
    rep = comm.replicate(base)
    fab = Fabric(comm, bucket_bytes=4 * 64)
    play = fab.partitioned_layout(rep)
    back = fab.unpartition(fab.shard_params(rep, play), play)
    for k in rep:
        np.testing.assert_allclose(np.asarray(back[k]), np.asarray(rep[k]),
                                   atol=0)


# ---------------------------------------------------------------------------
# (a) + (c): sync_zero1 ≡ sync, with 1/W optimizer state
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mlp_problem():
    key = jax.random.PRNGKey(0)
    dims = (12, 16, 8, 1)  # multi-layer MLP
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (a, b)) * 0.3
              for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))}
    X = jax.random.normal(jax.random.fold_in(key, 9), (W, 32, dims[0]))
    Y = jnp.sum(X, axis=-1, keepdims=True)

    def loss_fn(p, batch):
        x, y = batch
        h = x
        for i in range(len(dims) - 1):
            h = h @ p[f"w{i}"]
            if i < len(dims) - 2:
                h = jnp.tanh(h)
        return jnp.mean((h - y) ** 2)

    return params, (X, Y), loss_fn


@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adam"])
def test_zero1_matches_sync_dense(opt_name, mlp_problem):
    base, batches, loss_fn = mlp_problem
    make_opt = {"sgd": lambda: sgd(0.05),
                "momentum": lambda: momentum(0.03, 0.9),
                "adam": lambda: adam(0.02)}[opt_name]
    finals = {}
    for name, strat in [("sync", ST.sync()),
                        ("zero1", ST.sync_zero1(bucket_bytes=4 * 50))]:
        comm = LocalComm(W)
        opt = make_opt()
        params = comm.replicate(base)
        state = init_train_state(params, opt, strat, comm)
        step = make_replica_train_step(loss_fn, opt, strat, comm)
        for _ in range(25):
            state, m = step(state, batches)
        finals[name] = state
        assert float(m["replica_divergence"]) == 0.0
    for k in base:
        np.testing.assert_allclose(
            np.asarray(finals["zero1"]["params"][k]),
            np.asarray(finals["sync"]["params"][k]), atol=1e-5)


def test_zero1_opt_state_is_one_over_w(mlp_problem):
    """(c): every shard-state leaf holds ~1/W of the dense elements; the
    per-worker footprint shrink is exactly W up to bucket padding."""
    base, _, _ = mlp_problem
    comm = LocalComm(W)
    opt = adam(0.02)
    params = comm.replicate(base)
    dense = init_train_state(params, opt, ST.sync(), comm)["opt_state"]
    zero1 = init_train_state(params, opt, ST.sync_zero1(bucket_bytes=4 * 50),
                             comm)["opt_state"]
    n_dense = sum(x.size for x in jax.tree.leaves(dense))
    n_shard = sum(x.size for x in jax.tree.leaves(zero1))
    assert n_dense / n_shard == pytest.approx(W, rel=0.05)
    # stacked layout: every leaf is a (W, padded_bucket/W) shard bucket
    play = Fabric(comm, 4 * 50).partitioned_layout(params)
    shard_sizes = set(play.shard_sizes)
    for x in jax.tree.leaves(zero1):
        assert x.shape[0] == W  # stacked per-worker shards
        assert x.shape[-1] in shard_sizes


def test_zero1_matches_sync_on_transformer():
    """(a) on a real multi-layer LM: identical trained params to 1e-5."""
    import dataclasses
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, worker_batches
    from repro.models import transformer as T
    from repro.train.loop import make_loss_fn

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
        d_ff=64, vocab_size=32)
    w = 2
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      batch_per_worker=2, seed=0)
    lf = make_loss_fn(cfg, remat=False)

    def loss_fn(p, toks):
        return lf(p, {"tokens": toks, "labels": toks})

    finals = {}
    for name, strat in [("sync", ST.sync()),
                        ("zero1", ST.sync_zero1(bucket_bytes=4 * 2000))]:
        comm = LocalComm(w)
        opt = adam(3e-3)
        params = comm.replicate(T.init_model(jax.random.PRNGKey(0), cfg))
        state = init_train_state(params, opt, strat, comm)
        step = make_replica_train_step(loss_fn, opt, strat, comm)
        for t in range(8):
            state, _ = step(state, worker_batches(dcfg, w, t))
        finals[name] = state["params"]
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), atol=1e-5),
        finals["sync"], finals["zero1"])


# ---------------------------------------------------------------------------
# (b): lowering proof — reduce-scatter + all-gather, no grad all-reduce
# ---------------------------------------------------------------------------
def test_zero1_lowering_is_partitioned():
    out = _run("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.analysis import collective_budget
        from repro.core import strategies as ST
        from repro.core.comm import ShardComm
        from repro.core.fabric import BucketLayout, Fabric
        from repro.core.jax_compat import make_mesh, set_mesh, shard_map
        from repro.optim import adam
        from repro.train.loop import zero1_opt_template

        PODS, LAYERS = 4, 6
        mesh = make_mesh((PODS,), ("pod",))
        params = {f"l{i}": {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
                            "b": jax.ShapeDtypeStruct((32,), jnp.float32)}
                  for i in range(LAYERS)}
        bucket_bytes = 4 * 8000
        lay = BucketLayout.build(params, bucket_bytes, lead_axes=0)
        assert 1 < lay.n_buckets < 2 * LAYERS
        opt = adam(1e-3)
        opt_state = zero1_opt_template(params, opt, PODS, bucket_bytes)
        strat = ST.sync_zero1(bucket_bytes=bucket_bytes)
        comm = ShardComm("pod", PODS)

        def body(p, g, s):
            p, s, _, _ = strat.update(p, g, s, {}, jnp.zeros((), jnp.int32),
                                      opt, comm)
            return p, s

        rep = jax.tree.map(lambda _: P(), params)
        ssp = jax.tree.map(lambda _: P("pod"), opt_state)
        fn = shard_map(body, mesh=mesh, axis_names={"pod"},
                       in_specs=(rep, rep, ssp), out_specs=(rep, ssp),
                       check_vma=False)
        with set_mesh(mesh):
            c = jax.jit(fn).lower(params, params, opt_state).compile()
        # the rule API is the single proof implementation: RS/AG bounded
        # by n_buckets, anything else (stray all-reduce) capped at 0
        contract = Fabric(comm, bucket_bytes).collective_contract(
            lay, strat.wire_profile)
        res = collective_budget(c.as_text(), contract)
        assert res.status == "pass", res.findings
        print("ZERO1_HLO_OK", json.dumps(res.details))
    """)
    assert "ZERO1_HLO_OK" in out


def test_zero1_production_step_lowers():
    """The partition_grads=True sharded train step compiles on a 3-axis
    mesh: reduce-scatters bounded by the bucket count, and the only
    all-reduce left is the scalar loss mean."""
    out = _run("""
        import jax
        from repro.analysis import collective_budget
        from repro.core.fabric import BucketLayout
        from repro.core.jax_compat import make_mesh, set_mesh
        from repro.launch.specs import build_step, model_sds, resolve_config, truncate
        from repro.roofline.analysis import parse_collectives

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = truncate(resolve_config("gemma3-1b", "train_4k"), 1)
        step, sds, sh, don = build_step(cfg, "train_4k", mesh,
                                        partition_grads=True)
        with set_mesh(mesh):
            c = jax.jit(step, in_shardings=sh,
                        donate_argnums=don).lower(*sds).compile()
        counts = parse_collectives(c.as_text())["counts"]
        lay = BucketLayout.build(model_sds(cfg))
        # grad-path proof: RS bounded by buckets, zero wire all-reduce
        # (the loss pmean rides the scalar allowance).  all-gathers are
        # NOT bounded here — the 3-axis mesh adds model/data-axis
        # activation gathers beyond the ZeRO-1 param gathers.
        res = collective_budget(
            c.as_text(),
            {"reduce-scatter": lay.n_buckets, "all-gather": 10 ** 9})
        assert res.status == "pass", res.findings
        assert 0 < counts["reduce-scatter"], counts
        print("ZERO1_STEP_OK", res.details)
    """, devices=8)
    assert "ZERO1_STEP_OK" in out


# ---------------------------------------------------------------------------
# (d): lax.cond gating — sync_every=8 ships ~1/8 the bytes
# ---------------------------------------------------------------------------
def test_local_sgd_gating_drops_collective_bytes():
    out = _run("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.analysis import gating_ratio
        from repro.core import strategies as ST
        from repro.core.comm import ShardComm
        from repro.core.jax_compat import make_mesh, set_mesh, shard_map
        from repro.optim import sgd
        from repro.roofline.analysis import parse_collectives

        PODS = 4
        mesh = make_mesh((PODS,), ("pod",))
        params = {f"l{i}": jax.ShapeDtypeStruct((64, 32), jnp.float32)
                  for i in range(4)}
        opt = sgd(0.1)
        comm = ShardComm("pod", PODS)

        def bytes_over_8_steps(sync_every):
            strat = ST.local_sgd(sync_every=sync_every)
            total = 0
            for t in range(8):
                def body(p, g, _t=t):
                    p2, _, _, _ = strat.update(p, g, {}, {}, _t, opt, comm)
                    return p2
                rep = jax.tree.map(lambda _: P(), params)
                fn = shard_map(body, mesh=mesh, axis_names={"pod"},
                               in_specs=(rep, rep), out_specs=rep,
                               check_vma=False)
                with set_mesh(mesh):
                    c = jax.jit(fn).lower(params, params).compile()
                total += sum(parse_collectives(c.as_text())["bytes"].values())
            return total

        b1 = bytes_over_8_steps(1)
        b8 = bytes_over_8_steps(8)
        res = gating_ratio(b1, b8, sync_every=8)
        assert res.status == "pass", res.findings
        print("GATED_OK", json.dumps(res.details))
    """)
    assert "GATED_OK" in out


def test_gating_static_and_traced_agree(mlp_problem):
    """The two _gate paths (static python bool at trace time vs traced
    lax.cond) produce identical training trajectories."""
    base, batches, loss_fn = mlp_problem
    for strat_fn in (lambda: ST.local_sgd(sync_every=3),
                     lambda: ST.easgd(alpha=0.2, sync_every=3),
                     lambda: ST.gossip(mix_every=2)):
        comm = LocalComm(W)
        opt = sgd(0.05)
        params = comm.replicate(base)
        strat = strat_fn()
        # traced t (jitted step: lax.cond path).  donate=False: this test
        # re-uses ``params`` to seed the eager run below, so the jitted
        # step must not consume it (DESIGN.md §8 donation rules).
        state = init_train_state(params, opt, strat, comm)
        step = make_replica_train_step(loss_fn, opt, strat, comm,
                                       donate=False)
        for _ in range(6):
            state, _ = step(state, batches)
        # static t (eager update: pruned-branch path)
        state2 = init_train_state(params, opt, strat, comm)
        grad_fn = jax.vmap(jax.value_and_grad(loss_fn))
        p, o, c = state2["params"], state2["opt_state"], state2["comm_state"]
        for t in range(6):
            _, g = grad_fn(p, batches)
            p, o, c, _ = strat.update(p, g, o, c, t, opt, comm)
        for k in base:
            np.testing.assert_allclose(np.asarray(state["params"][k]),
                                       np.asarray(p[k]), atol=1e-5,
                                       err_msg=strat.name)


# ---------------------------------------------------------------------------
# checkpoints: atomic writes + partitioned save/restore across W
# ---------------------------------------------------------------------------
def test_checkpoint_atomic_write(tmp_path, monkeypatch):
    d = str(tmp_path)
    tree = {"w": jnp.arange(6.0)}
    save_checkpoint(d, 1, tree)
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]

    def boom(fobj, **kw):  # crash mid-save: partial bytes, then death
        fobj.write(b"partial garbage")
        raise RuntimeError("disk full")

    monkeypatch.setattr(np, "savez_compressed", boom)
    with pytest.raises(RuntimeError):
        save_checkpoint(d, 2, {"w": jnp.arange(6.0) * 2})
    # the crash left no ckpt_00000002.npz and the latest is still intact
    assert latest_step(d) == 1
    assert read_meta(d)["latest"] == 1
    got = restore_checkpoint(d, 1, tree)
    np.testing.assert_allclose(np.asarray(got["w"]), np.arange(6.0))


def test_partitioned_ckpt_restores_resharded(tmp_path, rng):
    """Save ZeRO-1 opt state sharded at W=4, restore re-sharded at W=2:
    the reassembled full state is identical."""
    d = str(tmp_path)
    base = {"w": jax.random.normal(rng, (9, 7)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (23,))}
    grads = jax.tree.map(lambda x: x * 0.1, base)
    opt = momentum(0.1, 0.9)
    bb = 4 * 40

    def build_state(w):
        comm = LocalComm(w)
        fab = Fabric(comm, bb)
        rep = comm.replicate(base)
        play = fab.partitioned_layout(rep)
        state = opt.init(fab.shard_params(rep, play))
        g_sh, _ = fab.exchange_partitioned(comm.replicate(grads), play)
        _, state = opt.update(g_sh, state, fab.shard_params(rep, play), 0)
        return comm, fab, play, state

    _, fab4, play4, state4 = build_state(4)
    save_checkpoint(d, 0, {"opt_state": state4}, partition=play4.spec())
    assert read_meta(d)["partitions"]["0"]["n_parts"] == 4

    comm2, fab2, play2, template2 = build_state(2)
    # wipe the template's values so a silent non-restore would be caught
    template2 = jax.tree.map(jnp.zeros_like, template2)
    restored = restore_checkpoint(d, 0, {"opt_state": template2},
                                  repartition=True)["opt_state"]
    full4 = fab4.unpartition(state4["m"], play4)
    full2 = fab2.unpartition(
        jax.tree.map(jnp.asarray, restored["m"]), play2)
    for k in base:
        np.testing.assert_allclose(np.asarray(full2[k][0]),
                                   np.asarray(full4[k][0]), atol=1e-6)


def test_partition_spec_survives_later_saves_and_bad_layouts_rejected(
        tmp_path, rng):
    """The per-step partition spec outlives later partition-less saves in
    the same dir, and a restore template built with a different bucket
    layout is rejected instead of silently zero-filling state."""
    d = str(tmp_path)
    base = {"w": jax.random.normal(rng, (9, 7)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (23,))}
    opt = momentum(0.1, 0.9)
    comm = LocalComm(4)
    fab = Fabric(comm, 4 * 40)
    rep = comm.replicate(base)
    play = fab.partitioned_layout(rep)
    state = opt.init(fab.shard_params(rep, play))
    save_checkpoint(d, 5, {"opt_state": state}, partition=play.spec())
    # a later params-only save must not orphan the partitioned checkpoint
    save_checkpoint(d, 9, {"params": base})
    assert read_meta(d)["latest"] == 9
    template = jax.tree.map(jnp.zeros_like, state)
    restored = restore_checkpoint(d, 5, {"opt_state": template},
                                  repartition=True)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), restored["opt_state"], state)
    # template with a different bucket layout (one big bucket) → reject
    fab_big = Fabric(LocalComm(2), 1 << 20)
    bad = opt.init(fab_big.shard_params(LocalComm(2).replicate(base)))
    with pytest.raises(ValueError, match="bucket"):
        restore_checkpoint(d, 5, {"opt_state": bad}, repartition=True)


def test_zero1_wire_and_state_accounting():
    """ZeRO-1 ships the same ring bytes as the dense all-reduce while the
    per-worker optimizer-state footprint drops by W."""
    from repro.roofline.analysis import exchange_wire_bytes, opt_state_bytes
    n, w = 1_000_000, 8
    assert exchange_wire_bytes(4 * n, w, partitioned=True) \
        == exchange_wire_bytes(4 * n, w)
    dense = opt_state_bytes(n, state_floats=2, w=w)
    part = opt_state_bytes(n, state_floats=2, w=w, partitioned=True)
    assert dense / part == pytest.approx(w)


def test_exchange_import_has_no_env_side_effect():
    """Importing build_exchange must not reconfigure XLA for the process."""
    import importlib
    before = os.environ.get("XLA_FLAGS")
    sys.modules.pop("repro.launch.exchange", None)
    importlib.import_module("repro.launch.exchange")
    assert os.environ.get("XLA_FLAGS") == before
