"""Elastic fault-tolerance tier (DESIGN.md §13): fleet-view membership,
bitwise in-memory ZeRO re-partitioning vs the checkpoint round-trip,
straggler demotion, the chaos controller, and the `--resume auto` CLI."""

import io
import os
import sys
import warnings
import zipfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, latest_valid_step,
                              restore_checkpoint, save_checkpoint,
                              stray_tmp_files, verify_checkpoint)
from repro.core.chaos import (ChaosEvent, ChaosSchedule, ExchangeFailure,
                              FleetClock)
from repro.core.comm import LocalComm, LocalHierComm
from repro.core.fabric import Fabric
from repro.core.staleness import StragglerDetector, StragglerPolicy
from repro.core.strategies import get_strategy, hierarchical
from repro.launch.elastic import (ElasticFleet, FleetView,
                                  demoted_resync, make_elastic_replica_step,
                                  masked_exchange, resize_dense_tree,
                                  resize_state)
from repro.optim import adam, sgd
from repro.train.loop import (init_train_state, jit_cache_size,
                              make_replica_train_step)

pytestmark = pytest.mark.chaos

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)  # the benchmarks/ package lives at repo root

BB = 4 * 40  # small buckets → several unevenly padded buckets per tree


def tiny_params(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((7, 9)), jnp.float32),
            "b": jnp.zeros((9,), jnp.float32),
            "v": jnp.asarray(rng.standard_normal((13,)), jnp.float32)}


def tiny_loss(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w"] + p["b"])
    return jnp.mean((h @ p["v"][:9] - y) ** 2)


def tiny_batches(w, t, seed=0):
    rng = np.random.default_rng(seed * 1000 + t)
    x = rng.standard_normal((w, 4, 7)).astype(np.float32)
    y = rng.standard_normal((w, 4)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


def batch_fn(view, t):
    # keyed by stable worker id, so a resize regenerates the right rows
    rng = np.random.default_rng(t)
    x = rng.standard_normal((8, 4, 7)).astype(np.float32)
    y = rng.standard_normal((8, 4)).astype(np.float32)
    idx = np.array(view.members)
    return jnp.asarray(x[idx]), jnp.asarray(y[idx])


def assert_trees_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# FleetView
# ---------------------------------------------------------------------------
def test_fleet_view_ranks_are_deterministic():
    v = FleetView(0, (3, 1, 7, 1))
    assert v.members == (1, 3, 7) and v.size == 3
    assert [v.rank_of(w) for w in v.members] == [0, 1, 2]
    # two controllers building the same view agree without coordination
    assert FleetView(0, (7, 3, 1)).members == v.members


def test_fleet_view_transitions_bump_epoch():
    v = FleetView(0, (0, 1, 2, 3))
    v2 = v.without(2)
    assert v2.epoch == 1 and v2.members == (0, 1, 3)
    v3 = v2.with_joined(5)
    assert v3.epoch == 2 and v3.members == (0, 1, 3, 5)
    v4 = v3.with_demoted((1,))
    assert v4.epoch == 3 and v4.demoted == (1,)
    np.testing.assert_array_equal(v4.mask(), [1.0, 0.0, 1.0, 1.0])
    # demoted members that leave the fleet drop out of the demoted set
    assert v4.without(1).demoted == ()


def test_resize_with_no_survivor_raises():
    with pytest.raises(ValueError, match="no surviving member"):
        resize_dense_tree({"x": jnp.zeros((2, 3))},
                          FleetView(0, (0, 1)), FleetView(1, (5, 6)))


# ---------------------------------------------------------------------------
# re-partition plumbing
# ---------------------------------------------------------------------------
def test_with_parts_keeps_bucket_sizes():
    comm = LocalComm(4)
    play = Fabric(comm, BB).partitioned_layout(comm.replicate(tiny_params()))
    play2 = play.with_parts(2)
    assert play.spec()["bucket_sizes"] == play2.spec()["bucket_sizes"]
    assert play2.spec()["n_parts"] == 2


def test_reshard_bucket_is_the_shared_implementation():
    from repro.checkpoint import reshard_bucket as ckpt_impl
    from repro.core.resharding import reshard_bucket as core_impl
    assert ckpt_impl is core_impl


@pytest.mark.parametrize("direction", [(4, 2), (2, 4)],
                         ids=["shrink", "grow"])
@pytest.mark.parametrize("opt_name", ["sgd", "adam"])
@pytest.mark.parametrize("stage", [1, 2, 3])
def test_resize_bitwise_vs_checkpoint_roundtrip(tmp_path, stage, opt_name,
                                                direction):
    """The tentpole contract: the in-memory resize IS the checkpoint
    save → restore(repartition=True) round-trip, bitwise, with no disk."""
    wf, wt = direction
    opt = sgd(0.05) if opt_name == "sgd" else adam(1e-2)
    comm = LocalComm(wf)
    strat = get_strategy(f"sync_zero{stage}", bucket_bytes=BB)
    state = init_train_state(comm.replicate(tiny_params()), opt, strat, comm)
    step = make_replica_train_step(tiny_loss, opt, strat, comm,
                                   donate=False, bucket_bytes=BB)
    for t in range(2):  # make the optimizer state non-trivial
        state, _ = step(state, tiny_batches(wf, t))

    owns = bool(getattr(strat, "owns_params", False))
    # checkpoint path FIRST: resize_state re-primes the ZeRO-3 layout to
    # the new width, after which gather_params at the old width is gone
    full = strat.gather_params(state["params"], comm) if owns \
        else state["params"]
    play = Fabric(comm, BB).partitioned_layout(full)
    tree = {"opt_state": state["opt_state"]}
    if owns:
        tree["param_shards"] = state["params"]
    save_checkpoint(str(tmp_path), 0, tree, partition=play.spec())

    vf, vt = FleetView(0, tuple(range(wf))), FleetView(1, tuple(range(wt)))
    live = resize_state(state, vf, vt, strategy=strat, bucket_bytes=BB)

    comm2 = LocalComm(wt)
    fresh = init_train_state(comm2.replicate(tiny_params()), opt,
                             get_strategy(f"sync_zero{stage}",
                                          bucket_bytes=BB), comm2)
    template = {"opt_state": jax.tree.map(jnp.zeros_like,
                                          fresh["opt_state"])}
    if owns:
        template["param_shards"] = jax.tree.map(jnp.zeros_like,
                                                fresh["params"])
    restored = restore_checkpoint(str(tmp_path), 0, template,
                                  repartition=True)
    assert_trees_bitwise(live["opt_state"], restored["opt_state"])
    if owns:
        assert_trees_bitwise(live["params"], restored["param_shards"])
        # the re-primed layout must keep gather_params working at W'
        regathered = strat.gather_params(live["params"], comm2)
        assert_trees_bitwise(comm2.replica(regathered, 0),
                             comm.replica(full, 0))


def test_resize_roundtrip_is_identity():
    opt = adam(1e-2)
    comm = LocalComm(4)
    strat = get_strategy("sync_zero2", bucket_bytes=BB)
    state = init_train_state(comm.replicate(tiny_params()), opt, strat, comm)
    step = make_replica_train_step(tiny_loss, opt, strat, comm,
                                   donate=False, bucket_bytes=BB)
    state, _ = step(state, tiny_batches(4, 0))
    v4, v2 = FleetView(0, (0, 1, 2, 3)), FleetView(1, (0, 1))
    down = resize_state(state, v4, v2, strategy=strat, bucket_bytes=BB)
    back = resize_state(down, v2, FleetView(2, (0, 1, 2, 3)),
                        strategy=strat, bucket_bytes=BB)
    assert_trees_bitwise(back["opt_state"], state["opt_state"])
    # dense params: survivors keep their rows, joiners copy consensus —
    # under sync training every row is identical, so this is the original
    assert_trees_bitwise(back["params"], state["params"])


def test_ssp_delivery_buffers_fail_loudly():
    opt = sgd(0.05)
    comm = LocalComm(3)
    strat = get_strategy("ssp", staleness=5)
    state = init_train_state(comm.replicate(tiny_params()), opt, strat, comm)
    with pytest.raises(ValueError, match="not elastically resizable"):
        resize_state(state, FleetView(0, (0, 1, 2)), FleetView(1, (0, 1)),
                     strategy=strat, bucket_bytes=BB)


# ---------------------------------------------------------------------------
# masked boundary step
# ---------------------------------------------------------------------------
def test_all_ones_mask_is_bitwise_sync():
    """Masked elastic stepping with everyone in the sync tier is BITWISE
    the plain sync strategy (power-of-two W), across a resync boundary."""
    opt = adam(1e-2)
    comm = LocalComm(4)
    strat = get_strategy("sync")
    ref = init_train_state(comm.replicate(tiny_params()), opt, strat, comm)
    ref_step = make_replica_train_step(tiny_loss, opt, strat, comm,
                                       donate=False, bucket_bytes=BB)
    ela = {"params": comm.replicate(tiny_params()),
           "opt_state": opt.init(comm.replicate(tiny_params())),
           "comm_state": {}, "step": jnp.zeros((), jnp.int32)}
    ela_step = make_elastic_replica_step(tiny_loss, opt, comm,
                                         resync_every=2, bucket_bytes=BB,
                                         donate=False)
    mask = jnp.ones((4,), jnp.float32)
    resyncs = 0
    for t in range(4):
        b = tiny_batches(4, t)
        ref, _ = ref_step(ref, b)
        ela, m = ela_step(ela, b, mask)
        resyncs += int(m["resync"])
    assert resyncs == 2  # the gated pull DID fire and stayed bitwise
    assert_trees_bitwise(ela["params"], ref["params"])
    assert_trees_bitwise(ela["opt_state"], ref["opt_state"])


def test_masked_exchange_keeps_local_gradients_for_demoted():
    comm = LocalComm(4)
    fab = Fabric(comm, BB)
    rng = np.random.default_rng(3)
    grads = {"g": jnp.asarray(rng.standard_normal((4, 5)), jnp.float32)}
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    g_eff, m = masked_exchange(fab, grads, mask)
    g = np.asarray(grads["g"])
    want_sync = (g[0] + g[2] + g[3]) / 3.0
    out = np.asarray(g_eff["g"])
    np.testing.assert_allclose(out[0], want_sync, rtol=1e-6)
    np.testing.assert_array_equal(out[1], g[1])  # local tier: untouched
    np.testing.assert_allclose(out[3], want_sync, rtol=1e-6)
    assert m["wire_bytes"] > 0


def test_demoted_resync_pulls_to_consensus_only_at_boundary():
    comm = LocalComm(4)
    fab = Fabric(comm, BB)
    params = {"p": jnp.asarray([[1.0], [9.0], [1.0], [1.0]], jnp.float32)}
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    out, did = demoted_resync(fab, params, mask,
                              jnp.asarray(2, jnp.int32), 4)
    assert not bool(did)
    np.testing.assert_array_equal(np.asarray(out["p"]),
                                  np.asarray(params["p"]))
    out, did = demoted_resync(fab, params, mask,
                              jnp.asarray(3, jnp.int32), 4)
    assert bool(did)
    got = np.asarray(out["p"])
    np.testing.assert_allclose(got[1], [1.0], rtol=1e-6)  # pulled back
    np.testing.assert_array_equal(got[0], [1.0])  # sync rows untouched


def test_elastic_demotion_gated_rule():
    from repro.analysis import elastic_demotion_gated
    from repro.analysis.rigs import elastic_artifacts
    res = elastic_demotion_gated(elastic_artifacts()["jaxpr"])
    assert res.status == "pass", res.findings
    assert res.details["under_cond"] == res.details["collectives"] > 0


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------
def test_straggler_detector_hysteresis():
    det = StragglerDetector(range(4), StragglerPolicy(patience=2,
                                                      recovery=2))
    slow = {0: 1.0, 1: 4.0, 2: 1.0, 3: 1.0}
    det.observe(slow)
    assert det.to_demote() == []  # patience not yet reached
    det.observe(slow)
    assert det.to_demote() == [1]
    det.demote(1)
    fast = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    # EWMA + recovery hysteresis: re-promotion takes several clean rounds
    for _ in range(6):
        det.observe(fast)
        for w in det.to_promote():
            det.promote(w)
    assert det.demoted == set()


def test_fleet_clock_slowdown_and_restore():
    clock = FleetClock(4, base_s=1.0, jitter=0.0, seed=0)
    clock.apply([ChaosEvent(0, "slowdown", 2, 3.0)])
    times = clock.boundary_times((0, 1, 2, 3))
    assert times[2] == pytest.approx(3.0) and times[0] == pytest.approx(1.0)
    clock.apply([ChaosEvent(1, "restore", 2)])
    assert clock.boundary_times((2,))[2] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# chaos schedule
# ---------------------------------------------------------------------------
def test_chaos_schedule_is_seeded_and_validated():
    a = ChaosSchedule.from_seed(7, horizon=50, n_workers=4)
    b = ChaosSchedule.from_seed(7, horizon=50, n_workers=4)
    assert a.spec() == b.spec()
    assert a.spec() != ChaosSchedule.from_seed(8, 50, 4).spec()
    with pytest.raises(ValueError, match="kind"):
        ChaosEvent(0, "meteor", 1)


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------
def test_fleet_survives_kill_within_one_boundary():
    sched = ChaosSchedule((ChaosEvent(5, "kill", 2),))
    fleet = ElasticFleet(tiny_params(), tiny_loss, adam(1e-2), workers=4,
                         chaos=sched, retries=2, backoff_s=0.0,
                         bucket_bytes=BB)
    logs = fleet.run(8, batch_fn)
    assert len(logs) == 8  # every boundary committed
    k = logs[5]
    assert k["size"] == 4 and k["size_after"] == 3  # degraded IN-boundary
    assert k["attempts"] == 3 and k["dropped"] == [2]
    assert fleet.view.members == (0, 1, 3)
    assert fleet.view.epoch == 1
    assert all(lg["size_after"] == 3 for lg in logs[5:])


def test_flake_is_retried_without_resize():
    sched = ChaosSchedule((ChaosEvent(3, "flake", 1),))
    fleet = ElasticFleet(tiny_params(), tiny_loss, sgd(0.05), workers=4,
                         chaos=sched, retries=2, backoff_s=1e-4,
                         bucket_bytes=BB)
    logs = fleet.run(5, batch_fn)
    f = logs[3]
    assert f["attempts"] == 1 and len(f["backoffs"]) == 1
    assert f["size_after"] == 4 and fleet.view.epoch == 0  # no transition


def test_transient_failure_exhausting_retries_is_surfaced():
    # with ZERO retries a flake exhausts the budget on its first attempt;
    # transient failures are surfaced (no resize), not silently degraded
    sched = ChaosSchedule((ChaosEvent(0, "flake", 1),))
    fleet = ElasticFleet(tiny_params(), tiny_loss, sgd(0.05), workers=2,
                         chaos=sched, retries=0, backoff_s=0.0,
                         bucket_bytes=BB)
    with pytest.raises(ExchangeFailure) as e:
        fleet.run_boundary(batch_fn)
    assert e.value.transient and e.value.workers == frozenset({1})
    assert fleet.view.size == 2  # nobody was dropped for a transient fault


def test_preempt_and_rejoin_roundtrip():
    sched = ChaosSchedule((ChaosEvent(2, "preempt", 1),
                           ChaosEvent(5, "rejoin", 1)))
    fleet = ElasticFleet(tiny_params(), tiny_loss, adam(1e-2), workers=4,
                         chaos=sched, backoff_s=0.0, bucket_bytes=BB)
    logs = fleet.run(7, batch_fn)
    assert logs[2]["size_after"] == 3 and logs[5]["size_after"] == 4
    assert fleet.view.epoch == 2
    # the joiner copied the sync consensus row: all rows identical again
    p = np.asarray(fleet.state["params"]["w"])
    np.testing.assert_array_equal(p[1], p[0])


def test_straggler_demotion_promotes_back_and_never_retraces():
    sched = ChaosSchedule((ChaosEvent(1, "slowdown", 3, 6.0),
                           ChaosEvent(6, "restore", 3)))
    fleet = ElasticFleet(tiny_params(), tiny_loss, adam(1e-2), workers=4,
                         straggler_policy=StragglerPolicy(patience=2,
                                                          recovery=2),
                         resync_every=4, chaos=sched,
                         clock=FleetClock(4, jitter=0.0, seed=1),
                         backoff_s=0.0, bucket_bytes=BB)
    logs = fleet.run(16, batch_fn)
    demoted = [lg["t"] for lg in logs if 3 in lg.get("demoted", ())]
    promoted = [lg["t"] for lg in logs if 3 in lg.get("promoted", ())]
    assert demoted and promoted and demoted[0] < promoted[0]
    assert fleet.view.demoted == ()  # recovered by the end
    # tier flips are mask VALUES: one compile for the whole 16-boundary
    # run (membership never changed, so one width)
    assert list(fleet._steps) == [4]
    assert jit_cache_size(fleet._steps[4]) in (1, -1)


# ---------------------------------------------------------------------------
# checkpoint integrity (satellites 1–2)
# ---------------------------------------------------------------------------
def _flip_member(npz_path, member):
    """Bit-flip one array member inside the .npz zip (re-zips, so the
    container stays readable and only the leaf payload is corrupt)."""
    with zipfile.ZipFile(npz_path) as z:
        blobs = {n: z.read(n) for n in z.namelist()}
    raw = bytearray(blobs[member])
    raw[-1] ^= 0xFF  # flip data bytes at the tail, not the npy header
    blobs[member] = bytes(raw)
    buf = io.BytesIO()
    with zipfile.ZipFile(buf, "w", zipfile.ZIP_STORED) as z:
        for n, b in blobs.items():
            z.writestr(n, b)
    with open(npz_path, "wb") as f:
        f.write(buf.getvalue())


def test_checksum_catches_bitflip_and_names_the_leaf(tmp_path):
    tree = {"a": jnp.arange(6.0), "b": {"c": jnp.ones((3, 3))}}
    save_checkpoint(str(tmp_path), 0, tree)
    assert verify_checkpoint(str(tmp_path), 0) is None
    _flip_member(str(tmp_path / "ckpt_00000000.npz"), "b.c.npy")
    reason = verify_checkpoint(str(tmp_path), 0)
    assert reason is not None and "b.c" in reason and "crc32" in reason
    with pytest.raises(ValueError, match=r"leaf 'b\.c' is corrupt"):
        restore_checkpoint(str(tmp_path), 0,
                           jax.tree.map(jnp.zeros_like, tree))


def test_latest_valid_step_skips_corrupt_steps(tmp_path):
    tree = {"a": jnp.arange(6.0)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    _flip_member(str(tmp_path / "ckpt_00000002.npz"), "a.npy")
    assert latest_step(str(tmp_path)) == 2  # newest on disk...
    with pytest.warns(UserWarning, match="skipping step 2"):
        assert latest_valid_step(str(tmp_path)) == 1  # ...newest VALID
    _flip_member(str(tmp_path / "ckpt_00000001.npz"), "a.npy")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert latest_valid_step(str(tmp_path)) is None


def test_stray_tmp_files_are_ignored_and_reported(tmp_path):
    save_checkpoint(str(tmp_path), 3, {"a": jnp.arange(4.0)})
    (tmp_path / "ckpt_00000009.npz.tmp").write_bytes(b"partial write")
    assert stray_tmp_files(str(tmp_path)) == ["ckpt_00000009.npz.tmp"]
    with pytest.warns(UserWarning, match="stray tmp file"):
        assert latest_step(str(tmp_path)) == 3  # tmp never counts
    with pytest.warns(UserWarning, match="stray tmp file"):
        restore_checkpoint(str(tmp_path), 3, {"a": jnp.zeros((4,))})


# ---------------------------------------------------------------------------
# --resume auto CLI (satellite 3)
# ---------------------------------------------------------------------------
def _cli(tmp_path, steps, extra=()):
    from repro.launch import train
    return train.main([
        "--arch", "qwen2-1.5b", "--reduced", "--workers", "2",
        "--zero-stage", "1", "--steps", str(steps), "--seq-len", "16",
        "--batch-per-worker", "2", "--log-every", "1",
        "--ckpt-dir", str(tmp_path / "ck"), *extra])


def test_resume_auto_continues_from_latest_valid(tmp_path, capsys):
    h1 = _cli(tmp_path, 2)
    h2 = _cli(tmp_path, 4, extra=("--resume", "auto"))
    out = capsys.readouterr().out
    assert "resumed from step 2" in out
    assert [r["step"] for r in h2] == [2, 3]  # restored steps skipped
    assert h1[-1]["step"] == 1


def test_resume_auto_exits_2_when_no_valid_step(tmp_path, capsys):
    (tmp_path / "empty").mkdir()
    from repro.launch import train
    with pytest.raises(SystemExit) as e:
        train.main(["--arch", "qwen2-1.5b", "--reduced", "--workers", "2",
                    "--steps", "2", "--seq-len", "16",
                    "--batch-per-worker", "2",
                    "--ckpt-dir", str(tmp_path / "empty"),
                    "--resume", "auto"])
    assert e.value.code == 2
    assert "no valid checkpoint step" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# hierarchical determinism (satellite 4)
# ---------------------------------------------------------------------------
def test_hierarchical_runs_are_bitwise_deterministic():
    def one_run():
        comm = LocalHierComm(2, 2)
        strat = hierarchical(get_strategy("sync"),
                             get_strategy("gossip", mix_every=2))
        opt = adam(1e-2)
        base = tiny_params(seed=5)
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (2, 2) + x.shape).copy(), base)
        state = {"params": params, "opt_state": opt.init(params),
                 "comm_state": strat.init(params, comm),
                 "step": jnp.zeros((), jnp.int32)}

        @jax.jit
        def step(state, batches):
            _, grads = jax.vmap(jax.vmap(jax.value_and_grad(tiny_loss)))(
                state["params"], batches)
            p, o, c, _ = strat.update(state["params"], grads,
                                      state["opt_state"],
                                      state["comm_state"], state["step"],
                                      opt, comm)
            return {"params": p, "opt_state": o, "comm_state": c,
                    "step": state["step"] + 1}

        for t in range(6):
            x, y = tiny_batches(4, t, seed=9)
            state = step(state, (x.reshape(2, 2, 4, 7),
                                 y.reshape(2, 2, 4)))
        return state

    a, b = one_run(), one_run()
    assert_trees_bitwise(a["params"], b["params"])
    assert_trees_bitwise(a["opt_state"], b["opt_state"])


# ---------------------------------------------------------------------------
# roofline + launch accounting
# ---------------------------------------------------------------------------
def test_resize_moved_bytes_matches_bruteforce():
    from repro.roofline.analysis import (checkpoint_roundtrip_bytes,
                                         resize_moved_bytes)
    for n, wo, wn in [(100, 4, 2), (100, 2, 4), (97, 4, 3), (5, 4, 2),
                      (64, 8, 8)]:
        c_old, c_new = -(-n // wo), -(-n // wn)
        brute = sum(1 for i in range(n) if i // c_old != i // c_new)
        got = resize_moved_bytes([n], wo, wn, state_floats=1, itemsize=1)
        assert got == brute, (n, wo, wn)
    assert resize_moved_bytes([10], 4, 4) == 0  # same width: nothing moves
    assert checkpoint_roundtrip_bytes([10, 7], state_floats=2,
                                      itemsize=4) == 2 * 17 * 4 * 2


def test_elastic_partition_spec_is_width_invariant():
    from repro.configs import get_config
    from repro.launch.specs import elastic_partition_spec
    cfg = get_config("qwen2-1.5b").reduced()
    s4 = elastic_partition_spec(cfg, 4, BB)
    s2 = elastic_partition_spec(cfg, 2, BB)
    assert s4["n_parts"] == 4 and s2["n_parts"] == 2
    assert s4["bucket_sizes"] == s2["bucket_sizes"]  # THE invariant


def test_elastic_state_shardings_partition_buckets():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.launch.sharding import elastic_state_shardings
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    template = {"m": [jnp.zeros((8,)), jnp.zeros((12,))],
                "t": jnp.zeros(())}
    sh = elastic_state_shardings(template, mesh)
    assert sh["m"][0].spec == P("pod")
    assert sh["t"].spec == P()


def test_bench_elastic_artifact_is_committed_and_valid():
    import benchmarks.bench_elastic as be
    report = be.validate()
    assert report["meta"]["smoke"] is False  # commit the FULL artifact
    assert len(report["resize"]) == 12
