"""End-to-end mixed-precision subsystem tests (ISSUE 4 acceptance):

  (a) the f32 policy is a strict no-op: sync and sync_zero1 training is
      BITWISE identical to the policy-less pre-precision path,
  (b) loss-scaled bf16 training of the tiny transformer reaches a loss
      within 5% of f32 on the LocalComm rig,
  (c) the bf16 wire halves exchange bytes (Fabric accounting) and the
      lowered ZeRO-1 HLO ships bf16 reduce-scatters — no f32 ones,
  (d) the loss-scale skip-step leaves params, optimizer state and comm
      state untouched on overflow (and the dynamic scale backs off /
      regrows),
  (e) checkpoint round-trip preserves the policy record and the f32
      master dtype across worker counts (save at W=4 → restore at W=2),
  (f) every spectrum strategy stays green under the bf16 policy
      (the ``bf16`` marker sweep — CI runs it as its own job).
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (read_meta, read_precision, restore_checkpoint,
                              save_checkpoint)
from repro.configs.base import ModelConfig
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.core.fabric import Fabric
from repro.core.precision import (PrecisionPolicy, apply_policy, get_policy,
                                  policy_from_spec)
from repro.optim import adam, momentum, sgd
from repro.train.loop import init_train_state, make_replica_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
W = 4


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# policy object + config validation
# ---------------------------------------------------------------------------
def test_policy_presets_and_spec_roundtrip():
    bf = get_policy("bf16")
    assert bf.param_dt == jnp.bfloat16 and bf.master_dt == jnp.float32
    assert bf.wire_dt == jnp.bfloat16 and bf.keeps_master and bf.uses_scaling
    assert get_policy(None).is_noop and get_policy("f32").is_noop
    assert not get_policy("bf16-pure").keeps_master
    assert policy_from_spec(bf.spec()) == bf
    assert get_policy(bf) is bf
    with pytest.raises(KeyError, match="unknown precision"):
        get_policy("fp8")
    with pytest.raises(ValueError, match="wire_dtype"):
        PrecisionPolicy("bad", wire_dtype="float64")


def test_config_dtype_validated_at_construction():
    """A bad dtype fails at ModelConfig construction, not inside model
    init (satellite: configs/base.py validation)."""
    with pytest.raises(ValueError, match="param_dtype"):
        ModelConfig(name="bad", param_dtype="float8")
    with pytest.raises(ValueError, match="compute_dtype"):
        dataclasses.replace(ModelConfig(), compute_dtype="tf32")
    cfg = apply_policy(ModelConfig(), get_policy("bf16"))
    assert cfg.param_dtype == "bfloat16" and cfg.compute_dtype == "bfloat16"


# ---------------------------------------------------------------------------
# shared problems
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mlp_problem():
    key = jax.random.PRNGKey(0)
    dims = (12, 16, 8, 1)
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (a, b)) * 0.3
              for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))}
    X = jax.random.normal(jax.random.fold_in(key, 9), (W, 32, dims[0]))
    Y = jnp.sum(X, axis=-1, keepdims=True)

    def loss_fn(p, batch):
        x, y = batch
        h = x
        for i in range(len(dims) - 1):
            h = (h @ p[f"w{i}"].astype(h.dtype))
            if i < len(dims) - 2:
                h = jnp.tanh(h)
        return jnp.mean((h.astype(jnp.float32) - y.astype(jnp.float32)) ** 2)

    return params, (X, Y), loss_fn


def _train(strategy, problem, policy, steps=20, opt=None, seed_params=None):
    base, batches, loss_fn = problem
    comm = LocalComm(W)
    opt = opt or sgd(0.05)
    pol = None if policy is None else get_policy(policy)
    params = comm.replicate(seed_params if seed_params is not None else base)
    if pol is not None:
        params = pol.cast_to_param(params)
        batches = jax.tree.map(
            lambda x: x.astype(pol.compute_dt), batches)
    state = init_train_state(params, opt, strategy, comm, policy=pol)
    step = make_replica_train_step(loss_fn, opt, strategy, comm, policy=pol)
    m = {}
    for _ in range(steps):
        state, m = step(state, batches)
    return state, m


# ---------------------------------------------------------------------------
# (a) f32 policy is bitwise the pre-precision path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strat_fn", [
    lambda pol: ST.sync(policy=pol),
    lambda pol: ST.sync_zero1(bucket_bytes=4 * 50, policy=pol),
], ids=["sync", "sync_zero1"])
def test_f32_policy_bitwise_identical(strat_fn, mlp_problem):
    s_none, _ = _train(strat_fn(None), mlp_problem, None, steps=10,
                       opt=adam(0.02))
    s_f32, _ = _train(strat_fn(get_policy("f32")), mlp_problem, "f32",
                      steps=10, opt=adam(0.02))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        s_none["params"], s_f32["params"])
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        s_none["opt_state"], s_f32["opt_state"])


# ---------------------------------------------------------------------------
# (c) wire accounting: bf16 halves exchange bytes
# ---------------------------------------------------------------------------
def test_bf16_wire_halves_exchange_bytes(rng):
    tree = {"a": jax.random.normal(rng, (W, 301)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (W, 13, 7))}
    f32 = Fabric(LocalComm(W), bucket_bytes=4 * 100)
    bf16 = Fabric(LocalComm(W), bucket_bytes=4 * 100,
                  wire_dtype=jnp.bfloat16)
    assert f32.flat_bytes(tree) == 2 * bf16.flat_bytes(tree)
    _, _, m32 = f32.exchange(tree)
    g16, _, m16 = bf16.exchange(tree)
    assert float(m32["wire_bytes"]) == 2 * float(m16["wire_bytes"])
    # bf16-rounded mean stays close to the f32 mean
    ref = f32.all_mean(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(g16[k]), np.asarray(ref[k]),
                                   rtol=2e-2, atol=2e-2)
    # partitioned path reports the same (halved) bytes
    play = bf16.partitioned_layout(tree)
    shards, mp = bf16.exchange_partitioned(tree, play)
    assert float(mp["wire_bytes"]) == float(m16["wire_bytes"])
    assert all(s.dtype == jnp.float32 for s in shards)  # f32 shard math


# ---------------------------------------------------------------------------
# (b) loss-scaled bf16 training of the tiny transformer: within 5% of f32
# ---------------------------------------------------------------------------
def test_bf16_transformer_loss_within_5pct_of_f32():
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, worker_batches
    from repro.models import transformer as T
    from repro.train.loop import make_loss_fn

    w, steps = 2, 12
    results = {}
    for pname in ("f32", "bf16"):
        pol = get_policy(pname)
        cfg = dataclasses.replace(
            apply_policy(get_config("qwen2-1.5b").reduced(), pol),
            num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
            head_dim=16, d_ff=64, vocab_size=32)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          batch_per_worker=2, seed=0)
        lf = make_loss_fn(cfg, remat=False)

        def loss_fn(p, toks):
            return lf(p, {"tokens": toks, "labels": toks})

        comm = LocalComm(w)
        opt = adam(3e-3)
        strat = ST.sync(policy=None if pol.is_noop else pol)
        params = comm.replicate(T.init_model(jax.random.PRNGKey(0), cfg))
        state = init_train_state(params, opt, strat, comm,
                                 policy=None if pol.is_noop else pol)
        step = make_replica_train_step(loss_fn, opt, strat, comm,
                                       policy=None if pol.is_noop else pol)
        for t in range(steps):
            state, m = step(state, worker_batches(dcfg, w, t))
        results[pname] = float(m["loss"])
        if pname == "bf16":
            assert float(m.get("overflow", 0.0)) == 0.0
            assert state["params"]["embed"].dtype == jnp.bfloat16
            assert state["master"]["embed"].dtype == jnp.float32
    assert np.isfinite(results["bf16"])
    rel = abs(results["bf16"] - results["f32"]) / results["f32"]
    assert rel < 0.05, results


@pytest.mark.bf16
def test_bf16_zero1_matches_bf16_sync(mlp_problem):
    """The bf16 ZeRO-1 path (f32 master in the opt-state shard) tracks the
    dense bf16 path (f32 master in the train state) to f32-master
    tolerance, and keeps the 1/W master layout."""
    base, _, _ = mlp_problem
    s_sync, _ = _train(ST.sync(policy=get_policy("bf16")), mlp_problem,
                       "bf16", steps=15, opt=adam(0.02))
    s_z1, _ = _train(
        ST.sync_zero1(bucket_bytes=4 * 50, policy=get_policy("bf16")),
        mlp_problem, "bf16", steps=15, opt=adam(0.02))
    assert "master" in s_sync and "master" not in s_z1
    assert "master" in s_z1["opt_state"]
    for x in jax.tree.leaves(s_z1["opt_state"]):
        assert x.dtype == jnp.float32 and x.shape[0] == W
    for k in base:
        np.testing.assert_allclose(
            np.asarray(s_z1["params"][k], np.float32),
            np.asarray(s_sync["params"][k], np.float32), atol=1e-2)


# ---------------------------------------------------------------------------
# (d) skip-step on overflow
# ---------------------------------------------------------------------------
def test_loss_scale_skip_step_leaves_state_untouched():
    pol = dataclasses.replace(get_policy("bf16"), growth_interval=3)
    comm = LocalComm(W)
    opt = adam(0.05)
    strat = ST.sync(policy=pol)
    base = {"w": jnp.ones((6, 2))}
    X = jnp.ones((W, 4, 6))

    def loss_fn(p, batch):
        x, boom = batch
        # boom=1 drives the loss to inf -> non-finite gradients
        return jnp.mean((x @ p["w"].astype(x.dtype)).astype(jnp.float32) ** 2
                        ) * jnp.where(boom > 0, jnp.inf, 1.0)

    params = pol.cast_to_param(comm.replicate(base))
    state = init_train_state(params, opt, strat, comm, policy=pol)
    step = make_replica_train_step(loss_fn, opt, strat, comm, policy=pol)
    ok_batch = (X.astype(jnp.bfloat16), jnp.zeros((W,)))
    bad_batch = (X.astype(jnp.bfloat16), jnp.ones((W,)))

    state, m = step(state, ok_batch)  # one good step to move off init
    scale0 = float(state["loss_scale"]["scale"])
    # np.array, not np.asarray: the step donates its input state
    # (DESIGN.md §8), and np.asarray of a CPU jax array is a zero-copy
    # VIEW — a donated-and-reused buffer would silently mutate the
    # snapshot and make the untouched-state assertion tautological
    snap = jax.tree.map(lambda x: np.array(x),
                        {k: state[k] for k in
                         ("params", "master", "opt_state")})
    state, m = step(state, bad_batch)  # overflow: must be a no-op + backoff
    assert float(m["overflow"]) == 1.0
    for k in snap:
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), state[k], snap[k])
    assert float(state["loss_scale"]["scale"]) == scale0 / 2
    assert int(state["loss_scale"]["good_steps"]) == 0
    # growth: growth_interval consecutive finite steps double the scale
    for _ in range(pol.growth_interval):
        state, m = step(state, ok_batch)
    assert float(state["loss_scale"]["scale"]) == scale0
    # and the good steps actually moved the params
    assert not np.array_equal(np.asarray(state["master"]["w"], np.float32),
                              np.asarray(snap["master"]["w"], np.float32))


# ---------------------------------------------------------------------------
# (e) checkpoint: policy + master dtype survive a W=4 -> W=2 round trip
# ---------------------------------------------------------------------------
def test_checkpoint_preserves_policy_and_master_across_workers(tmp_path):
    pol = get_policy("bf16")
    d = str(tmp_path)
    key = jax.random.PRNGKey(3)
    base = {"w": jax.random.normal(key, (9, 7)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (23,))}
    grads = jax.tree.map(lambda x: (x * 0.1).astype(jnp.bfloat16), base)
    opt = momentum(0.1, 0.9)
    bb = 4 * 40

    def build_state(w):
        comm = LocalComm(w)
        strat = ST.sync_zero1(bucket_bytes=bb, policy=pol)
        fab = Fabric(comm, bb, wire_dtype=pol.wire_dt)
        rep = pol.cast_to_param(comm.replicate(base))
        play = fab.partitioned_layout(rep)
        state = strat.init_opt(rep, opt, comm)
        _, state, _, _ = strat.update(rep, comm.replicate(grads), state, {},
                                      jnp.zeros((), jnp.int32), opt, comm)
        return comm, fab, play, rep, state

    _, fab4, play4, rep4, state4 = build_state(4)
    save_checkpoint(d, 0, {"params": rep4, "opt_state": state4},
                    partition=play4.spec(), precision=pol.spec())
    # the recorded policy round-trips
    assert read_precision(d, 0) == pol.spec()
    assert policy_from_spec(read_precision(d, 0)) == pol
    assert read_meta(d)["partitions"]["0"]["n_parts"] == 4

    comm2, fab2, play2, rep2, template2 = build_state(2)
    template2 = jax.tree.map(jnp.zeros_like, template2)
    restored = restore_checkpoint(
        d, 0, {"params": jax.tree.map(jnp.zeros_like, rep2),
               "opt_state": template2}, repartition=True)
    # master dtype preserved (f32 on disk AND in the restored shard)
    for x in jax.tree.leaves(restored["opt_state"]["master"]):
        assert np.asarray(x).dtype == np.float32
    # params restored CASTED to the working dtype
    assert np.asarray(restored["params"]["w"]).dtype == \
        jnp.dtype(jnp.bfloat16)
    # reassembled master agrees across worker counts
    full4 = fab4.unpartition(state4["master"], play4)
    full2 = fab2.unpartition(
        jax.tree.map(jnp.asarray, restored["opt_state"]["master"]), play2)
    for k in base:
        np.testing.assert_allclose(np.asarray(full2[k][0], np.float32),
                                   np.asarray(full4[k][0], np.float32),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# (c) HLO proof: the bf16-wire ZeRO-1 path emits no f32 reduce-scatter
# ---------------------------------------------------------------------------
def test_zero1_bf16_hlo_has_no_f32_reduce_scatter():
    """The bf16-wire ZeRO-1 lowering ships ONLY bf16 on the wire: the
    gradient reduction is one bf16 all-to-all per bucket + local f32
    accumulate (a bf16 reduce-scatter would be convert-promoted back to
    an f32 wire by XLA), and the param all-gather is bf16.  No f32
    reduce-scatter, no gradient all-reduce."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.analysis import collective_budget, promotion_proof
        from repro.core import strategies as ST
        from repro.core.comm import ShardComm
        from repro.core.fabric import BucketLayout, Fabric
        from repro.core.jax_compat import make_mesh, set_mesh, shard_map
        from repro.core.precision import get_policy
        from repro.optim import adam
        from repro.train.loop import zero1_opt_template

        PODS, LAYERS = 4, 6
        pol = get_policy("bf16")
        mesh = make_mesh((PODS,), ("pod",))
        params = {f"l{i}": {"w": jax.ShapeDtypeStruct((64, 32), jnp.bfloat16),
                            "b": jax.ShapeDtypeStruct((32,), jnp.bfloat16)}
                  for i in range(LAYERS)}
        bucket_bytes = 4 * 8000
        lay = BucketLayout.build(params, bucket_bytes, lead_axes=0)
        opt = adam(1e-3)
        opt_state = zero1_opt_template(params, opt, PODS, bucket_bytes,
                                       policy=pol)
        assert "master" in opt_state
        strat = ST.sync_zero1(bucket_bytes=bucket_bytes, policy=pol)
        comm = ShardComm("pod", PODS)

        def body(p, g, s):
            p, s, _, _ = strat.update(p, g, s, {}, jnp.zeros((), jnp.int32),
                                      opt, comm)
            return p, s

        rep = jax.tree.map(lambda _: P(), params)
        ssp = jax.tree.map(lambda _: P("pod"), opt_state)
        fn = shard_map(body, mesh=mesh, axis_names={"pod"},
                       in_specs=(rep, rep, ssp), out_specs=(rep, ssp),
                       check_vma=False)
        with set_mesh(mesh):
            c = jax.jit(fn).lower(params, params, opt_state).compile()
        txt = c.as_text()
        # rule API: the narrow partitioned contract is a2a+AG per bucket
        # (NO reduce-scatter — it would be convert-promoted), and the
        # promotion proof rejects any non-tuple f32 wire payload
        contract = Fabric(comm, bucket_bytes,
                          wire_dtype=pol.wire_dt).collective_contract(
            lay, strat.wire_profile)
        assert set(contract) == {"all-to-all", "all-gather"}, contract
        res = collective_budget(txt, contract)
        assert res.status == "pass", res.findings
        promo = promotion_proof(txt, pol.narrow_wire)
        assert promo.status == "pass", promo.findings
        print("BF16_HLO_OK", json.dumps(res.details))
    """)
    assert "BF16_HLO_OK" in out


# ---------------------------------------------------------------------------
# (f) strategy-spectrum sweep under the bf16 policy (CI marker job)
# ---------------------------------------------------------------------------
BF16_STRATEGIES = [
    ("sync", lambda pol: ST.sync(policy=pol)),
    ("sync_zero1", lambda pol: ST.sync_zero1(bucket_bytes=4 * 50,
                                             policy=pol)),
    ("local_sgd", lambda pol: ST.local_sgd(sync_every=4, policy=pol)),
    ("easgd", lambda pol: ST.easgd(alpha=0.2, sync_every=3, policy=pol)),
    ("ssp", lambda pol: ST.ssp(staleness=3, policy=pol)),
    ("downpour", lambda pol: ST.downpour(push_every=4, policy=pol)),
    ("gossip", lambda pol: ST.gossip(policy=pol)),
]


@pytest.mark.bf16
@pytest.mark.parametrize("name,strat_fn", BF16_STRATEGIES,
                         ids=[n for n, _ in BF16_STRATEGIES])
def test_strategy_trains_under_bf16(name, strat_fn, mlp_problem):
    """Every spectrum strategy converges under --precision bf16: finite
    loss, big reduction vs. init, bf16 working params, halved wire."""
    pol = get_policy("bf16")
    state, m = _train(strat_fn(pol), mlp_problem, pol, steps=60,
                      opt=adam(0.02))
    base, batches, loss_fn = mlp_problem
    init_loss = float(loss_fn(base, jax.tree.map(lambda x: x[0], batches)))
    final = float(m["loss"])
    assert np.isfinite(final) and final < 0.5 * init_loss, (name, final)
    assert state["params"]["w0"].dtype == jnp.bfloat16
    # the uncompressed gradient exchanges report a 2-byte wire
    if name in ("sync", "sync_zero1"):
        n = sum(x.size for x in jax.tree.leaves(base))
        assert float(m["wire_bytes"]) <= 2 * n * W + 64, name
    # complete strategies keep replicas consistent under the bf16 wire
    if name in ("sync", "sync_zero1"):
        assert float(m["replica_divergence"]) == 0.0, name


def test_dense_sync_bf16_hlo_has_no_f32_all_reduce():
    """The UNCOMPRESSED bf16-wire sync exchange is also promotion-proof:
    XLA convert-promotes a bf16 all-reduce back to an f32 wire, so the
    fabric expresses it as bf16 all-to-all + local f32 accumulate + u16
    all-gather (ring bytes of the all-reduce it replaces).  Without this,
    wire_bytes would claim 2 bytes/elem while the wire ships 4."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.analysis import collective_budget, promotion_proof
        from repro.core import strategies as ST
        from repro.core.comm import ShardComm
        from repro.core.fabric import BucketLayout, Fabric
        from repro.core.jax_compat import make_mesh, set_mesh, shard_map
        from repro.core.precision import get_policy
        from repro.optim import sgd

        PODS, LAYERS = 4, 6
        pol = get_policy("bf16")
        mesh = make_mesh((PODS,), ("pod",))
        params = {f"l{i}": jax.ShapeDtypeStruct((64, 32), jnp.bfloat16)
                  for i in range(LAYERS)}
        bucket_bytes = 4 * 8000
        lay = BucketLayout.build(params, bucket_bytes, lead_axes=0)
        strat = ST.sync(bucket_bytes=bucket_bytes, policy=pol)
        comm = ShardComm("pod", PODS)

        def body(p, g):
            p, _, _, _ = strat.update(p, g, {}, {}, jnp.zeros((), jnp.int32),
                                      sgd(0.1), comm)
            return p

        rep = jax.tree.map(lambda _: P(), params)
        fn = shard_map(body, mesh=mesh, axis_names={"pod"},
                       in_specs=(rep, rep), out_specs=rep, check_vma=False)
        with set_mesh(mesh):
            c = jax.jit(fn).lower(params, params).compile()
        txt = c.as_text()
        # rule API: the narrow DENSE contract replaces the all-reduce
        # with a2a+AG per bucket; no all-reduce may survive, and no
        # non-tuple f32 payload may ride the wire
        contract = Fabric(comm, bucket_bytes,
                          wire_dtype=pol.wire_dt).collective_contract(
            lay, strat.wire_profile)
        assert set(contract) == {"all-to-all", "all-gather"}, contract
        res = collective_budget(txt, contract)
        assert res.status == "pass", res.findings
        promo = promotion_proof(txt, pol.narrow_wire)
        assert promo.status == "pass", promo.findings
        print("DENSE_BF16_HLO_OK", json.dumps(res.details))
    """)
    assert "DENSE_BF16_HLO_OK" in out


def test_production_zero1_step_lowers_with_bf16_policy():
    """build_step(precision="bf16") compiles the partition_grads path on a
    3-axis mesh: f32 master buckets in the sharded opt state, loss-scale
    state threaded, and still no gradient all-reduce."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.core.jax_compat import make_mesh, set_mesh
        from repro.launch.specs import build_step, resolve_config, truncate
        from repro.roofline.analysis import parse_collectives

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = truncate(resolve_config("gemma3-1b", "train_4k"), 1)
        step, sds, sh, don = build_step(cfg, "train_4k", mesh,
                                        partition_grads=True,
                                        precision="bf16")
        state_sds = sds[0]
        assert "master" in state_sds["opt_state"]
        assert all(s.dtype == jnp.float32 for s in
                   state_sds["opt_state"]["master"])
        assert state_sds["loss_scale"]["scale"].dtype == jnp.float32
        assert state_sds["params"]["embed"].dtype == jnp.bfloat16
        with set_mesh(mesh):
            c = jax.jit(step, in_shardings=sh,
                        donate_argnums=don).lower(*sds).compile()
        counts = parse_collectives(c.as_text())["counts"]
        # pmin of the finite flag joins the loss pmean as scalar traffic;
        # the GRADIENT reduction is the bucketed a2a + shard update
        assert counts["all-to-all"] > 0, counts
        print("BF16_STEP_OK", counts)
    """, devices=8)
    assert "BF16_STEP_OK" in out


# ---------------------------------------------------------------------------
# fused Adam behind the Optimizer API (satellite: kernels/fused_adam.py)
# ---------------------------------------------------------------------------
def test_adam_fused_flag_parity(rng):
    """adam(fused=True) (the Pallas kernel, ref/interpret mode on CPU)
    tracks the pure-JAX adam leaf-for-leaf over several steps, including
    non-flat leaves and a schedule."""
    from repro.optim.optimizers import warmup_cosine

    tree = {"a": jax.random.normal(rng, (700,)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (13, 5)),
            "nest": {"c": jax.random.normal(jax.random.fold_in(rng, 2),
                                            (2, 3, 4))}}
    grads = jax.tree.map(lambda x: x * 0.1, tree)
    sched = warmup_cosine(1e-2, warmup=2, total_steps=10)
    pure, fused = adam(sched), adam(sched, fused=True)
    sp, sf = pure.init(tree), fused.init(tree)
    pp, pf = tree, tree
    for t in range(4):
        tt = jnp.asarray(t, jnp.int32)
        pp, sp = pure.update(grads, sp, pp, tt)
        pf, sf = fused.update(grads, sf, pf, tt)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), pp, pf)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), sp, sf)


def test_adam_fused_rejects_weight_decay():
    with pytest.raises(ValueError, match="weight_decay"):
        adam(1e-3, weight_decay=0.1, fused=True)


def test_adam_fused_runs_on_zero1_shards(mlp_problem):
    """The fused optimizer slots into the ZeRO-1 strategy (flat shard
    buckets) exactly like the pure one."""
    s_pure, _ = _train(ST.sync_zero1(bucket_bytes=4 * 50), mlp_problem,
                       None, steps=8, opt=adam(0.02))
    s_fused, _ = _train(ST.sync_zero1(bucket_bytes=4 * 50), mlp_problem,
                        None, steps=8, opt=adam(0.02, fused=True))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        s_pure["params"], s_fused["params"])


# ---------------------------------------------------------------------------
# serving: bf16 KV cache end-to-end
# ---------------------------------------------------------------------------
def test_decode_engine_bf16_cache():
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve.engine import DecodeEngine, Request

    cfg = dataclasses.replace(get_config("gemma3-1b").reduced(),
                              num_layers=2, d_model=64, vocab_size=64)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(params, cfg, batch_slots=2, max_seq=16,
                       cache_dtype="bfloat16")
    leaves = jax.tree.leaves(eng.cache)
    assert any(x.dtype == jnp.bfloat16 for x in leaves)  # KV narrowed
    eng.submit(Request(rid=0, prompt=np.array([1, 2, 3], np.int32),
                       max_new_tokens=4))
    done = eng.run(max_steps=64)
    assert len(done) == 1 and len(done[0].generated) == 4
    f32_eng = DecodeEngine(params, cfg, batch_slots=2, max_seq=16,
                           cache_dtype="float32")
    nbytes = lambda c: sum(x.size * x.dtype.itemsize  # noqa: E731
                           for x in jax.tree.leaves(c))
    assert nbytes(eng.cache) < nbytes(f32_eng.cache)
