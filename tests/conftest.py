import jax
import pytest

# Smoke tests and benches see the single real CPU device; ONLY the dry-run
# (launch/dryrun.py) sets xla_force_host_platform_device_count.
jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "bf16: strategy-equivalence sweep under the bf16 precision policy "
        "(CI runs `pytest -m bf16` as its own job; the marks also run in "
        "the plain tier-1 sweep)")
    config.addinivalue_line(
        "markers",
        "accum: microbatched-train-step sweep (gradient accumulation, "
        "donation, prefetch — DESIGN.md §8); CI runs `pytest -m accum` as "
        "its own matrix entry, and the marks also run in plain tier-1")
    config.addinivalue_line(
        "markers",
        "serving: paged KV cache / paged-attention serving tier "
        "(DESIGN.md §10); CI runs `pytest -m serving` as its own matrix "
        "entry, and the marks also run in plain tier-1")
    config.addinivalue_line(
        "markers",
        "lint: static-analysis linter tier (repro.analysis, DESIGN.md "
        "§11) — rule positives/negatives, report-schema validation and "
        "the LINT.json artifact check; CI runs `pytest -m lint` as its "
        "own matrix entry, and the marks also run in plain tier-1")
    config.addinivalue_line(
        "markers",
        "tp: tensor-parallelism tier (models/tensor_parallel.py, "
        "DESIGN.md §12) — split/unsplit round-trip, bitwise forward and "
        "sub-layer backward vs the blocked reference, the \"tp\" "
        "collective contract and its HLO budget; CI runs `pytest -m tp` "
        "as its own matrix entry, and the marks also run in plain tier-1")
    config.addinivalue_line(
        "markers",
        "chaos: elastic fault-tolerance tier (launch/elastic.py, "
        "core/chaos.py, DESIGN.md §13) — fleet-view membership, bitwise "
        "in-memory ZeRO re-partitioning vs the checkpoint round-trip, "
        "straggler demotion/promotion, and the seeded chaos controller "
        "runs; CI runs `pytest -m chaos` as its own matrix entry, and "
        "the marks also run in plain tier-1")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
