"""Fused Pallas compression on the Fabric path (DESIGN.md §2/§3).

The production dispatch (``Fabric(fused=True)``, the default) must be
BITWISE identical to the pure-jnp wire codec it replaces — encode, decode,
error-feedback residual and DGC velocity masking, on padded and unpadded
buckets, on both Comm realizations — and must emit NO separate XLA pack
op (the uint8 sign bytes come out of the kernel)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core.comm import LocalComm
from repro.core.compression import (dgc_init, ef_init, get_compressor,
                                    pack_signs, packed_nbytes, wire_bytes)
from repro.core.fabric import Fabric, wire_nbytes
from repro.kernels import ops, ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
W = 4

COMPRESSORS = [
    ("onebit", {"block": 64}),
    ("topk", {"ratio": 0.1, "block": 64}),
]


@pytest.fixture(scope="module")
def tree(rng):
    # "c" (300) is NOT a multiple of any block used here — padded tail
    # blocks exercised on every test; "b" (8*16=128) divides evenly
    return {"b": jax.random.normal(rng, (W, 8, 16)),
            "c": jax.random.normal(jax.random.fold_in(rng, 2), (W, 300))}


def _fabrics():
    return (Fabric(LocalComm(W), bucket_bytes=1 << 12, fused=True),
            Fabric(LocalComm(W), bucket_bytes=1 << 12, fused=False))


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# kernel vs jnp wire codec, bitwise
# ---------------------------------------------------------------------------
def test_onebit_packed_kernel_bitwise(rng):
    nb, block = 13, 64
    g = jax.random.normal(rng, (nb, block))
    r = jax.random.normal(jax.random.fold_in(rng, 1), (nb, block)) * 0.1
    packed, scale, newr = ops.onebit_quant_packed(g, r)
    s, sc, _ = ref.onebit_quant_ref(g, r)
    want_packed = pack_signs(s.reshape(-1)).reshape(nb, block // 8)
    want_scale = sc.astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(want_packed))
    np.testing.assert_array_equal(np.asarray(scale, np.float32),
                                  np.asarray(want_scale, np.float32))
    # residual accounts for the bf16 scale the receivers decode with
    t = g + r
    dec = jnp.where(t >= 0, 1.0, -1.0) * want_scale.astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(newr), np.asarray(t - dec))


def test_topk_encode_ef_kernel_bitwise(rng):
    nb, block, k = 13, 64, 5
    g = jax.random.normal(rng, (nb, block))
    r = jax.random.normal(jax.random.fold_in(rng, 1), (nb, block)) * 0.1
    vals, idx, newr = ops.topk_encode_ef(g, r, k)
    t = g + r
    rvals, ridx, rdense = ref.topk_sparsify_ref(t, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_array_equal(np.asarray(newr), np.asarray(t - rdense))


# ---------------------------------------------------------------------------
# Fabric dispatch parity (LocalComm simulator, padded + unpadded buckets)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,kw", COMPRESSORS)
def test_exchange_parity_bitwise(name, kw, tree):
    comp = get_compressor(name, **kw)
    assert comp.fused_encode is not None
    fa, fb = _fabrics()
    res = ef_init(tree)
    ma, ra, meta_a = fa.exchange(tree, res, comp)
    mb, rb, meta_b = fb.exchange(tree, res, comp)
    _assert_tree_equal(ma, mb)
    _assert_tree_equal(ra, rb)
    assert float(meta_a["wire_bytes"]) == float(meta_b["wire_bytes"])
    # second round: nonzero residual feeds the fused t = g + r
    ma2, ra2, _ = fa.exchange(tree, ra, comp)
    mb2, rb2, _ = fb.exchange(tree, rb, comp)
    _assert_tree_equal(ma2, mb2)
    _assert_tree_equal(ra2, rb2)


@pytest.mark.parametrize("name,kw", COMPRESSORS)
def test_dgc_parity_bitwise(name, kw, tree):
    comp = get_compressor(name, **kw)
    fa, fb = _fabrics()
    sa = sb = dgc_init(tree)
    for _ in range(2):  # round 2: nonzero velocity AND residual
        ga, sa, _ = fa.exchange_dgc(tree, sa, comp, momentum=0.9)
        gb, sb, _ = fb.exchange_dgc(tree, sb, comp, momentum=0.9)
        _assert_tree_equal(ga, gb)
        _assert_tree_equal(sa["velocity"], sb["velocity"])
        _assert_tree_equal(sa["residual"], sb["residual"])


@pytest.mark.parametrize("name,kw", COMPRESSORS)
def test_compress_no_collective_parity(name, kw, tree):
    comp = get_compressor(name, **kw)
    fa, fb = _fabrics()
    res = ef_init(tree)
    ca, ra, wa = fa.compress(tree, res, comp)
    cb, rb, wb = fb.compress(tree, res, comp)
    _assert_tree_equal(ca, cb)
    _assert_tree_equal(ra, rb)
    assert wa == wb


def test_fused_dispatch_is_default(tree):
    fab = Fabric(LocalComm(W))
    assert fab.fused
    assert get_compressor("onebit").fused_encode is not None
    assert get_compressor("topk").fused_encode is not None
    # int8 has no fused kernel: the jnp path must still serve it
    comp = get_compressor("int8", block=64)
    assert comp.fused_encode is None
    m, r, _ = fab.exchange(tree, ef_init(tree), comp)
    assert jax.tree.structure(m) == jax.tree.structure(tree)


# ---------------------------------------------------------------------------
# no separate pack op on the fused path
# ---------------------------------------------------------------------------
def test_fused_path_emits_no_separate_pack_op(tree, monkeypatch):
    """The fused dispatch must never reach the XLA ``pack_signs`` codec —
    the uint8 bytes come out of the kernel — and its jaxpr must contain
    the pallas_call; the jnp codec is the control.  (The abstract
    ``packed_nbytes`` accounting also touches ``pack_signs`` under
    ``eval_shape``, so the counter is scoped to the encode paths.)"""
    calls = {"n": 0}
    orig = C.pack_signs

    def counting(sign):
        calls["n"] += 1
        return orig(sign)

    monkeypatch.setattr(C, "pack_signs", counting)
    comp = get_compressor("onebit", block=64)
    g = jax.random.normal(jax.random.PRNGKey(0), (W, 300))
    r = jnp.zeros((W, 300))

    def encode(gg, rr):  # drop the (non-jax-typed) widen closure
        arrs, _, new_r = comp.fused_encode(gg, rr)
        return arrs, new_r

    jx = str(jax.make_jaxpr(encode)(g, r))
    assert calls["n"] == 0
    assert "pallas_call" in jx

    def jnp_codec(t):
        wire, _ = comp.compress(t)
        return C._narrow_wire(comp.name, wire)[0]

    jax.make_jaxpr(jnp_codec)(g[0])
    assert calls["n"] > 0

    # full exchange graphs: the kernel appears on the fused dispatch only
    res = ef_init(tree)
    fused, unfused = _fabrics()
    assert "pallas_call" in str(jax.make_jaxpr(
        lambda t, rr: fused.exchange(t, rr, comp))(tree, res))
    assert "pallas_call" not in str(jax.make_jaxpr(
        lambda t, rr: unfused.exchange(t, rr, comp))(tree, res))


# ---------------------------------------------------------------------------
# parity on the sharded realization (subprocess: needs >1 device)
# ---------------------------------------------------------------------------
def test_shardcomm_fused_parity_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.comm import ShardComm
        from repro.core.compression import get_compressor, ef_init
        from repro.core.fabric import Fabric
        from repro.core.jax_compat import make_mesh, set_mesh, shard_map

        W = 4
        mesh = make_mesh((W,), ("w",))
        g = {"a": jax.random.normal(jax.random.PRNGKey(0), (W, 8, 16)),
             "c": jax.random.normal(jax.random.PRNGKey(1), (W, 300))}
        r = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
        for name, kw in (("onebit", {"block": 64}),
                         ("topk", {"ratio": 0.1, "block": 64})):
            comp = get_compressor(name, **kw)
            outs = {}
            for fused in (True, False):
                def body(gg, rr):
                    fab = Fabric(ShardComm("w", W), bucket_bytes=1 << 12,
                                 fused=fused)
                    m, nr, _ = fab.exchange(gg, rr, comp)
                    return m, nr
                fn = shard_map(body, mesh=mesh, axis_names={"w"},
                               in_specs=(P("w"), P("w")),
                               out_specs=(P("w"), P("w")), check_vma=False)
                with set_mesh(mesh):
                    outs[fused] = jax.jit(fn)(g, r)
            for a, b in zip(jax.tree.leaves(outs[True]),
                            jax.tree.leaves(outs[False])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            print("SHARD_PARITY_OK", name)
    """)], capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.count("SHARD_PARITY_OK") == 2


# ---------------------------------------------------------------------------
# wire-byte accounting under block padding (exact, both layers)
# ---------------------------------------------------------------------------
def test_wire_bytes_exact_on_padded_buckets():
    """300 elems / block 64 → 5 blocks: the padded tail block ships a full
    scale (onebit) / k values + k indices (topk).  ``compression.
    wire_bytes`` must charge them, matching ``fabric.wire_nbytes``."""
    tree = {"w": jnp.zeros((300,))}
    onebit = get_compressor("onebit", block=64)
    # 5 blocks * 64/8 sign bytes + 5 * 2 bf16 scale bytes
    assert wire_bytes(onebit, tree) == 5 * 8 + 5 * 2
    assert wire_nbytes(onebit, 300) == wire_bytes(onebit, tree)

    topk = get_compressor("topk", ratio=0.125, block=64)  # k = 8
    # 5 blocks * 8 * (4 value + 2 index) bytes
    assert wire_bytes(topk, tree) == 5 * 8 * 6
    assert wire_nbytes(topk, 300) == wire_bytes(topk, tree)

    # exact accounting charges the padded tail: 300 elems cost the same
    # wire as 5 full blocks, and differ from the analytic per-element rate
    assert wire_bytes(onebit, {"w": jnp.zeros((320,))}) == \
        wire_bytes(onebit, tree)
    assert wire_bytes(onebit, tree) != 300 * onebit.wire_bits_per_element / 8


def test_wire_bytes_matches_shipped_buffer():
    """The accounting equals the byte size of the buffer an exchange
    actually packs (per leaf), padded and unpadded."""
    for name, kw, n in (("onebit", {"block": 64}, 300),
                        ("onebit", {"block": 64}, 256),
                        ("topk", {"ratio": 0.1, "block": 64}, 300),
                        ("int8", {"block": 64}, 100)):
        comp = get_compressor(name, **kw)
        x = jax.random.normal(jax.random.PRNGKey(0), (n,))
        wire, _ = comp.compress(x)
        arrs, _ = C._narrow_wire(comp.name, wire)
        buf, _ = C._pack(arrs)
        assert packed_nbytes(comp, n) == buf.size, (name, n)
        assert wire_bytes(comp, {"x": x}) == buf.size


def test_wire_bytes_none_unchanged():
    tree = {"w": jnp.zeros((1000,))}
    assert wire_bytes(get_compressor("none"), tree) == 4000.0


# ---------------------------------------------------------------------------
# backend-aware interpret default (kernels/ops.py helper)
# ---------------------------------------------------------------------------
def test_default_interpret_backend_aware(monkeypatch):
    assert ops.default_interpret() == (jax.default_backend()
                                       not in ("tpu", "gpu"))
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert not ops.default_interpret()
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    assert not ops.default_interpret()
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert ops.default_interpret()


# ---------------------------------------------------------------------------
# fused Adam at the ZeRO-1 shard-bucket boundary
# ---------------------------------------------------------------------------
def test_zero1_fused_adam_parity(rng):
    from repro.core.strategies import get_strategy
    from repro.optim import adam
    from repro.train.loop import init_train_state, make_replica_train_step

    w = 2
    comm = LocalComm(w)
    params = {"w1": jax.random.normal(rng, (16, 32)) * 0.1,
              "b1": jnp.zeros((32,))}
    params = comm.replicate(params)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (w, 4, 16))

    def loss_fn(p, xb):
        return jnp.mean((xb @ p["w1"] + p["b1"]) ** 2)

    states = {}
    for fused in (False, True):
        opt = adam(1e-3, fused=fused)
        strat = get_strategy("sync_zero1")
        state = init_train_state(params, opt, strat, comm)
        step = make_replica_train_step(loss_fn, opt, strat, comm,
                                       donate=False)
        for _ in range(3):
            state, metrics = step(state, x)
        states[fused] = state
    for a, b in zip(jax.tree.leaves(states[True]["params"]),
                    jax.tree.leaves(states[False]["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)
    assert float(metrics["loss"]) > 0
