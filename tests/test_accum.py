"""Microbatched train step (gradient accumulation) — ISSUE 5 acceptance:

  (a) equivalence sweep: ``accum_steps=k`` over ``k`` microbatches is
      BITWISE-identical (f32, sync + sync_zero1) to the unfused jitted
      reference (k per-microbatch gradients, tree-mean, one strategy
      update) and loss-equivalent — within floating-point reduction-order
      tolerance for f32, looser for bf16 — to one k-sized batch,
  (b) HLO proof: with ``accum_steps=4`` the lowered boundary step carries
      exactly one exchange's worth of collectives (≤ n_buckets, the
      fused-Fabric bound) — the scan body is collective-free — on both
      the dense sync and ZeRO-1 production paths,
  (c) error-feedback / DGC state advances ONCE per boundary,
  (d) local-step strategies (``exchange_at_boundary=False``) count
      optimizer steps, not microbatches,
  (e) the data pipeline's jitted synthesis (one trace per config), the
      microbatch stack's stream identity, and the double-buffered
      prefetch order,
  (f) ``donate_argnums``: the consumed train state really is donated
      (and ``donate=False`` opts out).

All tests carry the ``accum`` marker; CI runs them as their own tier-1
matrix entry (``pytest -m accum``) alongside the bf16 job.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import retrace
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.core.compression import get_compressor
from repro.core.fabric import Fabric
from repro.core.precision import get_policy
from repro.data.pipeline import (DataConfig, microbatch_stack,
                                 prefetch_batches, sample_batch,
                                 worker_batches)
from repro.optim import adam, sgd
from repro.train.loop import (init_train_state, jit_cache_size,
                              make_replica_train_step)

pytestmark = pytest.mark.accum

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
W, K = 2, 4


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.fixture(scope="module")
def micro_problem():
    """(base params, (X, Y) shaped (K, W, b, d), loss_fn) — K microbatches
    whose concatenation along the batch dim is the reference big batch."""
    key = jax.random.PRNGKey(0)
    dims = (10, 12, 1)
    base = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                       (a, b)) * 0.4
            for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))}
    X = jax.random.normal(jax.random.fold_in(key, 7), (K, W, 8, dims[0]))
    Y = jnp.sum(X, axis=-1, keepdims=True)

    def loss_fn(p, batch):
        x, y = batch
        h = x
        for i in range(len(dims) - 1):
            h = h @ p[f"w{i}"].astype(h.dtype)
            if i < len(dims) - 2:
                h = jnp.tanh(h)
        return jnp.mean((h.astype(jnp.float32) - y.astype(jnp.float32)) ** 2)

    return base, (X, Y), loss_fn


# ---------------------------------------------------------------------------
# (a) equivalence sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("opt_name", ["sgd", "adam"])
@pytest.mark.parametrize("strat_fn", [
    lambda: ST.sync(),
    lambda: ST.sync_zero1(bucket_bytes=4 * 40),
], ids=["sync", "sync_zero1"])
def test_accum_bitwise_vs_unfused_reference(strat_fn, opt_name,
                                            micro_problem):
    """The scanned bucket-space accumulator is BITWISE the jitted unfused
    reference: k separate per-microbatch gradients, tree-summed in scan
    order, divided once, one strategy update."""
    base, (X, Y), loss_fn = micro_problem
    make_opt = {"sgd": lambda: sgd(0.05), "adam": lambda: adam(0.02)}[opt_name]

    comm = LocalComm(W)
    opt = make_opt()
    strat = strat_fn()
    state = init_train_state(comm.replicate(base), opt, strat, comm)
    step = make_replica_train_step(loss_fn, opt, strat, comm, accum_steps=K)
    for _ in range(3):
        state, m = step(state, (X, Y))

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))
    opt2 = make_opt()
    strat2 = strat_fn()

    @jax.jit
    def ref_step(state, XY):
        X, Y = XY
        acc = None
        for j in range(K):
            _, g = grad_fn(state["params"], (X[j], Y[j]))
            acc = g if acc is None else jax.tree.map(
                lambda a, b: a + b, acc, g)
        gm = jax.tree.map(lambda a: a / K, acc)
        p, o, c, _ = strat2.update(state["params"], gm, state["opt_state"],
                                   state["comm_state"], state["step"],
                                   opt2, comm)
        return {"params": p, "opt_state": o, "comm_state": c,
                "step": state["step"] + 1}

    ref = init_train_state(comm.replicate(base), opt2, strat2, comm)
    for _ in range(3):
        ref = ref_step(ref, (X, Y))
    for k in base:
        a = np.asarray(state["params"][k])
        b = np.asarray(ref["params"][k])
        np.testing.assert_array_equal(a.view(np.int32), b.view(np.int32),
                                      err_msg=f"{strat_fn().name}/{k}")
    assert float(m["replica_divergence"]) == 0.0


@pytest.mark.parametrize("strat_fn", [
    lambda: ST.sync(),
    lambda: ST.sync_zero1(bucket_bytes=4 * 40),
], ids=["sync", "sync_zero1"])
def test_accum_loss_equivalent_to_one_big_batch(strat_fn, micro_problem):
    """k microbatches accumulated ≡ one k-sized batch up to f32
    reduction-order tolerance (bitwise equality is impossible across the
    different matmul contraction splits), and the wire bytes of the accum
    run are 1/k of the big-batch-per-microbatch run."""
    base, (X, Y), loss_fn = micro_problem

    def train(accum):
        comm = LocalComm(W)
        opt = adam(0.02)
        strat = strat_fn()
        state = init_train_state(comm.replicate(base), opt, strat, comm)
        step = make_replica_train_step(loss_fn, opt, strat, comm,
                                       accum_steps=K if accum else 1)
        if accum:
            batch = (X, Y)
        else:  # the SAME samples as one k-sized batch: concat on batch dim
            batch = (jnp.swapaxes(X, 0, 1).reshape(W, -1, X.shape[-1]),
                     jnp.swapaxes(Y, 0, 1).reshape(W, -1, Y.shape[-1]))
        m = {}
        for _ in range(10):
            state, m = step(state, batch)
        return state, m

    s_acc, m_acc = train(True)
    s_big, m_big = train(False)
    for k in base:
        np.testing.assert_allclose(np.asarray(s_acc["params"][k]),
                                   np.asarray(s_big["params"][k]),
                                   atol=1e-5)
    np.testing.assert_allclose(float(m_acc["loss"]), float(m_big["loss"]),
                               rtol=1e-5)
    # identical wire bytes PER OPTIMIZER STEP, k x the samples per step:
    # bytes per sample shrink by exactly k
    assert float(m_acc["wire_bytes"]) == float(m_big["wire_bytes"])


@pytest.mark.bf16
def test_accum_bf16_loss_equivalent(micro_problem):
    """Under the bf16 policy (f32 master, loss scaling) the accumulated
    boundary tracks the one-big-batch step to bf16 tolerance."""
    base, (X, Y), loss_fn = micro_problem
    pol = get_policy("bf16")

    def train(accum):
        comm = LocalComm(W)
        opt = adam(0.02)
        strat = ST.sync(policy=pol)
        params = pol.cast_to_param(comm.replicate(base))
        state = init_train_state(params, opt, strat, comm, policy=pol)
        step = make_replica_train_step(loss_fn, opt, strat, comm, policy=pol,
                                       accum_steps=K if accum else 1)
        if accum:
            batch = (X, Y)
        else:
            batch = (jnp.swapaxes(X, 0, 1).reshape(W, -1, X.shape[-1]),
                     jnp.swapaxes(Y, 0, 1).reshape(W, -1, Y.shape[-1]))
        m = {}
        for _ in range(10):
            state, m = step(state, batch)
        return state, m

    s_acc, m_acc = train(True)
    s_big, m_big = train(False)
    assert float(m_acc.get("overflow", 0.0)) == 0.0
    assert np.isfinite(float(m_acc["loss"]))
    np.testing.assert_allclose(float(m_acc["loss"]), float(m_big["loss"]),
                               rtol=0.05)
    for k in base:
        np.testing.assert_allclose(
            np.asarray(s_acc["master"][k]), np.asarray(s_big["master"][k]),
            atol=5e-2)


# ---------------------------------------------------------------------------
# (b) HLO proof: one exchange per boundary on the production step
# ---------------------------------------------------------------------------
def test_accum_production_step_one_exchange_per_boundary():
    """make_sharded_train_step(accum_steps=4): the scan body is
    collective-free, so the whole boundary carries ≤ n_buckets exchange
    collectives — reduce-scatters on the ZeRO-1 path, gradient all-reduces
    on the dense path (+1 scalar loss pmean) — exactly the fused-Fabric
    bound of the unaccumulated step."""
    out = _run("""
        import jax
        from repro.core.fabric import BucketLayout
        from repro.core.jax_compat import make_mesh, set_mesh
        from repro.launch.specs import (build_step, model_sds,
                                        resolve_config, truncate)
        from repro.roofline.analysis import parse_collectives

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = truncate(resolve_config("gemma3-1b", "train_4k"), 1)
        lay = BucketLayout.build(model_sds(cfg))

        def counts_for(**kw):
            step, sds, sh, don = build_step(cfg, "train_4k", mesh, **kw)
            with set_mesh(mesh):
                c = jax.jit(step, in_shardings=sh,
                            donate_argnums=don).lower(*sds).compile()
            return parse_collectives(c.as_text())["counts"]

        z = counts_for(partition_grads=True, accum_steps=4)
        assert 0 < z["reduce-scatter"] <= lay.n_buckets, z
        assert z["all-reduce"] <= 1, z  # scalar loss pmean only
        z1 = counts_for(partition_grads=True, accum_steps=1)
        assert z["reduce-scatter"] == z1["reduce-scatter"], (z, z1)

        d = counts_for(accum_steps=4)
        # n_buckets gradient all-reduces + the scalar loss pmean
        assert 0 < d["all-reduce"] <= lay.n_buckets + 1, d
        assert d["reduce-scatter"] == 0, d
        print("ACCUM_STEP_OK", z, d)
    """, devices=8)
    assert "ACCUM_STEP_OK" in out


# ---------------------------------------------------------------------------
# (c) EF / DGC state advances once per boundary
# ---------------------------------------------------------------------------
def test_ef_residual_advances_once_per_boundary(micro_problem):
    """sync + onebit with accumulation: the boundary's comm_state equals
    ONE fabric exchange of the microbatch-mean gradients — bitwise — not
    k exchanges."""
    base, (X, Y), loss_fn = micro_problem
    comm = LocalComm(W)
    opt = sgd(0.05)
    comp = get_compressor("onebit", block=16)
    strat = ST.sync(compressor=comp)
    state0 = init_train_state(comm.replicate(base), opt, strat, comm)
    step = make_replica_train_step(loss_fn, opt, strat, comm, accum_steps=K)
    state, m = step(state0, (X, Y))
    assert float(m["comm_events"]) == 1.0  # one exchange, k microbatches

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    @jax.jit
    def ref_residual(params, XY):
        X, Y = XY
        acc = None
        for j in range(K):
            _, g = grad_fn(params, (X[j], Y[j]))
            acc = g if acc is None else jax.tree.map(
                lambda a, b: a + b, acc, g)
        gm = jax.tree.map(lambda a: a / K, acc)
        fab = Fabric(comm)
        _, res, _ = fab.exchange(gm, jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params), comp)
        return res

    res_ref = ref_residual(init_train_state(
        comm.replicate(base), opt, strat, comm)["params"], (X, Y))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)),
        state["comm_state"]["residual"], res_ref)


def test_dgc_state_advances_once_per_boundary(micro_problem):
    """sync_dgc with accumulation: velocity/residual see ONE momentum-
    corrected exchange of the boundary-mean gradients."""
    base, (X, Y), loss_fn = micro_problem
    comm = LocalComm(W)
    opt = sgd(0.05)
    comp = get_compressor("topk", ratio=0.25, block=16)
    strat = ST.sync_dgc(comp, momentum=0.9)
    state = init_train_state(comm.replicate(base), opt, strat, comm)
    step = make_replica_train_step(loss_fn, opt, strat, comm, accum_steps=K)
    state, m = step(state, (X, Y))
    assert float(m["comm_events"]) == 1.0

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    @jax.jit
    def ref_state(params, XY):
        X, Y = XY
        acc = None
        for j in range(K):
            _, g = grad_fn(params, (X[j], Y[j]))
            acc = g if acc is None else jax.tree.map(
                lambda a, b: a + b, acc, g)
        gm = jax.tree.map(lambda a: a / K, acc)
        from repro.core.compression import dgc_init
        _, st, _ = Fabric(comm).exchange_dgc(gm, dgc_init(params), comp, 0.9)
        return st

    st_ref = ref_state(init_train_state(
        comm.replicate(base), opt, strat, comm)["params"], (X, Y))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state["comm_state"]["dgc"], st_ref)


# ---------------------------------------------------------------------------
# (d) local-step strategies count optimizer steps, not microbatches
# ---------------------------------------------------------------------------
def test_local_step_strategies_count_optimizer_steps(micro_problem):
    """local_sgd(sync_every=2) under accum_steps=4: the averaging schedule
    sees the boundary counter — 3 sync events in 6 optimizer steps (24
    microbatches), exactly as without accumulation."""
    base, (X, Y), loss_fn = micro_problem
    assert not ST.local_sgd().exchange_at_boundary
    assert ST.sync().exchange_at_boundary
    for accum in (False, True):
        comm = LocalComm(W)
        opt = sgd(0.05)
        strat = ST.local_sgd(sync_every=2)
        state = init_train_state(comm.replicate(base), opt, strat, comm)
        step = make_replica_train_step(loss_fn, opt, strat, comm,
                                       accum_steps=K if accum else 1)
        batch = (X, Y) if accum else (X[0], Y[0])
        events = 0.0
        for _ in range(6):
            state, m = step(state, batch)
            events += float(m["comm_events"])
        assert events == 3.0, (accum, events)


# ---------------------------------------------------------------------------
# (e) pipeline: jitted synthesis, stream identity, prefetch order
# ---------------------------------------------------------------------------
def test_sample_batch_jitted_once_per_config():
    """sample_batch is jitted with static cfg and TRACED (worker, step):
    many steps reuse one compilation."""
    cfg = DataConfig(vocab_size=64, seq_len=8, batch_per_worker=2, seed=3)
    for t in range(5):
        b = sample_batch(cfg, 0, t)
        assert b.shape == (2, 8) and b.dtype == jnp.int32
    if jit_cache_size(sample_batch) != -1:
        res = retrace([jit_cache_size(sample_batch)])
        assert res.status == "pass", res.findings
    # worker/step as traced operands: the jitted callable accepts arrays
    b2 = sample_batch(cfg, jnp.int32(1), jnp.int32(7))
    np.testing.assert_array_equal(np.asarray(b2),
                                  np.asarray(sample_batch(cfg, 1, 7)))


def test_microbatch_stack_matches_plain_stream():
    """Microbatch j of optimizer step T is plain step T*k + j — the
    accumulated run consumes the IDENTICAL token stream."""
    cfg = DataConfig(vocab_size=32, seq_len=8, batch_per_worker=2, seed=1)
    k, w = 3, 2
    stack = microbatch_stack(cfg, w, 5, k)
    assert stack.shape == (k, w, 2, 8)
    for j in range(k):
        np.testing.assert_array_equal(
            np.asarray(stack[j]), np.asarray(worker_batches(cfg, w, 5 * k + j)))


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_prefetch_batches_order_and_values(depth):
    cfg = DataConfig(vocab_size=32, seq_len=8, batch_per_worker=2, seed=2)
    got = list(prefetch_batches(cfg, 2, 5, depth=depth))
    assert [t for t, _ in got] == list(range(5))
    for t, b in got:
        np.testing.assert_array_equal(np.asarray(b),
                                      np.asarray(worker_batches(cfg, 2, t)))
    acc = list(prefetch_batches(cfg, 2, 3, accum_steps=2, depth=depth))
    for t, b in acc:
        assert b.shape[0] == 2
        np.testing.assert_array_equal(
            np.asarray(b), np.asarray(microbatch_stack(cfg, 2, t, 2)))


# ---------------------------------------------------------------------------
# (f) donation
# ---------------------------------------------------------------------------
def test_step_donates_train_state(micro_problem):
    """donate_argnums=(0,) really consumes the input state (in-place
    buffer reuse for params/opt/accumulator); donate=False opts out for
    callers that re-step from a saved state."""
    base, (X, Y), loss_fn = micro_problem
    comm = LocalComm(W)
    opt = adam(0.02)
    strat = ST.sync()
    state = init_train_state(comm.replicate(base), opt, strat, comm)
    step = make_replica_train_step(loss_fn, opt, strat, comm, accum_steps=K)
    new_state, _ = step(state, (X, Y))
    jax.block_until_ready(new_state["params"])
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(state["params"]["w0"])  # donated away

    state2 = init_train_state(comm.replicate(base), opt, strat, comm)
    step_nd = make_replica_train_step(loss_fn, opt, strat, comm,
                                      accum_steps=K, donate=False)
    out_a, _ = step_nd(state2, (X, Y))
    out_b, _ = step_nd(state2, (X, Y))  # re-step from the kept state: fine
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), out_a["params"], out_b["params"])


def test_accum_steps_validated():
    with pytest.raises(ValueError, match="accum_steps"):
        make_replica_train_step(lambda p, b: 0.0, sgd(0.1), ST.sync(),
                                LocalComm(2), accum_steps=0)


def test_accum_with_hierarchical_comm():
    """The bucket accumulator rides the (P, W, ...) two-tier layout: it
    borrows the inner tier's lead_axes, so no microbatch ever mixes
    replicas across pods OR workers."""
    from repro.core.comm import LocalHierComm

    pods, wk, dim = 2, 2, 6
    comm = LocalHierComm(pods, wk)
    strat = ST.hierarchical(ST.sync(), ST.gossip(mix_every=2))
    opt = sgd(0.05)
    params = {"w": jnp.zeros((pods, wk, dim))}
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (K, pods, wk, 8, dim))
    Y = jnp.sum(X, -1, keepdims=True)

    def loss_fn(p, batch):
        x, y = batch  # per-pod view: w (wk, dim), x (wk, 8, dim)
        pred = jnp.einsum("wbd,wd->wb", x, p["w"])[..., None]
        return jnp.mean((pred - y) ** 2)

    state = init_train_state(params, opt, strat, comm)
    step = make_replica_train_step(loss_fn, opt, strat, comm, accum_steps=K)
    losses = []
    for _ in range(6):
        state, m = step(state, (X, Y))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] and np.isfinite(losses).all()
