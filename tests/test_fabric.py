"""Fabric tests (DESIGN.md §3): bucket layout, fused collectives, packed
wire formats, and the lowering proof that the exchange really is fused —
≤ n_buckets cross-worker collectives where the per-leaf path emitted one
per parameter leaf, with wire_bytes matching the packed buffers."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import LocalComm, LocalHierComm
from repro.core.compression import get_compressor
from repro.core.fabric import (BucketLayout, Fabric, wire_nbytes)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
W = 4


@pytest.fixture(scope="module")
def tree(rng):
    return {"a": jax.random.normal(rng, (W, 12)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (W, 8, 16)),
            "c": jax.random.normal(jax.random.fold_in(rng, 2), (W, 300)),
            "d": jax.random.normal(jax.random.fold_in(rng, 3), (W, 40))}


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------
def test_layout_roundtrip(tree):
    lay = BucketLayout.build(tree, bucket_bytes=4 * 200, lead_axes=1)
    assert lay.n_leaves == 4
    assert lay.n_buckets < lay.n_leaves  # genuinely fused
    assert sum(lay.bucket_sizes) == sum(
        x[0].size for x in jax.tree.leaves(tree))
    rt = lay.debucketize(lay.bucketize(tree))
    for k in tree:
        np.testing.assert_allclose(np.asarray(rt[k]), np.asarray(tree[k]))


def test_layout_respects_cap(tree):
    cap_elems = 100
    lay = BucketLayout.build(tree, bucket_bytes=4 * cap_elems, lead_axes=1)
    for b in range(lay.n_buckets):
        leaves_in = [lay.sizes[i] for i in range(lay.n_leaves)
                     if lay.bucket_of[i] == b]
        # a bucket only exceeds the cap when a single leaf does
        assert sum(leaves_in) <= cap_elems or len(leaves_in) == 1


def test_layout_single_bucket_when_uncapped(tree):
    lay = BucketLayout.build(tree, bucket_bytes=1 << 30, lead_axes=1)
    assert lay.n_buckets == 1


# ---------------------------------------------------------------------------
# fused collectives ≡ per-leaf reference (LocalComm)
# ---------------------------------------------------------------------------
def test_fabric_collectives_match_per_leaf(tree):
    fab = Fabric(LocalComm(W), bucket_bytes=4 * 200)
    ref_mean = jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape),
        tree)
    got = fab.all_mean(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(got[k]),
                                   np.asarray(ref_mean[k]), atol=1e-6)
    got = fab.ppermute(tree, shift=1)
    for k in tree:
        np.testing.assert_allclose(np.asarray(got[k]),
                                   np.asarray(jnp.roll(tree[k], 1, 0)),
                                   atol=1e-6)
    got = fab.all_sum(tree)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(got[k]),
            np.asarray(jnp.broadcast_to(jnp.sum(tree[k], 0, keepdims=True),
                                        tree[k].shape)), atol=1e-5)


# ---------------------------------------------------------------------------
# compression on the flat buffer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,kw", [
    ("onebit", {"block": 16}), ("int8", {"block": 16}),
    ("topk", {"ratio": 0.25, "block": 16}),
])
def test_exchange_error_feedback_invariant(name, kw, tree):
    """decoded + residual == target per replica: nothing silently lost."""
    comp = get_compressor(name, **kw)
    fab = Fabric(LocalComm(W), bucket_bytes=4 * 200)
    res = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)
    # compress() exposes the per-replica decode (no collective)
    g_hat, new_r, nbytes = fab.compress(tree, res, comp)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(g_hat[k].astype(jnp.float32) + new_r[k]),
            np.asarray(tree[k]), atol=1e-4)
    assert 0 < nbytes < fab.flat_bytes(tree)


def test_exchange_mean_of_decodes(tree):
    """exchange() == all-mean of the per-replica wire-faithful decodes."""
    comp = get_compressor("int8", block=16)
    fab = Fabric(LocalComm(W), bucket_bytes=4 * 200)
    res = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)
    g_hat, _, _ = fab.compress(tree, res, comp)
    mean_ref = jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.mean(x.astype(jnp.float32), 0,
                                            keepdims=True), x.shape), g_hat)
    got, _, m = fab.exchange(tree, res, comp)
    for k in tree:
        np.testing.assert_allclose(np.asarray(got[k]),
                                   np.asarray(mean_ref[k]), atol=1e-5)
    # reported bytes are the exact packed size of every bucket, all replicas
    lay = fab.layout(tree)
    expect = W * sum(wire_nbytes(comp, n) for n in lay.bucket_sizes)
    assert float(m["wire_bytes"]) == pytest.approx(expect, rel=1e-6)


def test_wire_nbytes_is_exact_packed_size():
    """The accounting helper equals the real uint8 buffer the fabric
    ships, for every codec (acceptance: within 1%; here: exact)."""
    from repro.core.fabric import _narrow_wire, _pack
    n = 300
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    for name, kw in [("onebit", {"block": 16}), ("int8", {"block": 32}),
                     ("topk", {"ratio": 0.1, "block": 64})]:
        comp = get_compressor(name, **kw)
        wire, _ = comp.compress(x)
        arrs, _ = _narrow_wire(comp.name, wire)
        buf, _ = _pack(arrs)
        assert buf.dtype == jnp.uint8
        assert buf.size == wire_nbytes(comp, n), name
        # genuinely packed: 1-bit signs ⇒ far below 1 byte/element
        if name == "onebit":
            assert buf.size < n  # < 8 bits/element incl. scales


def test_wire_roundtrip_decode_matches_direct():
    """Packing narrows scales to bf16 (the wire format); decode through
    the packed buffer must match decode of the narrowed wire exactly."""
    from repro.core.fabric import _narrow_wire, _pack, _unpack
    x = jax.random.normal(jax.random.PRNGKey(1), (256,))
    for name, kw in [("onebit", {"block": 16}), ("int8", {"block": 32}),
                     ("topk", {"ratio": 0.25, "block": 32})]:
        comp = get_compressor(name, **kw)
        wire, meta = comp.compress(x)
        arrs, widen = _narrow_wire(comp.name, wire)
        buf, specs = _pack(arrs)
        dec = comp.decompress(widen(_unpack(buf, specs)), meta,
                              x.shape, jnp.float32)
        dec_direct = comp.decompress(widen(arrs), meta, x.shape, jnp.float32)
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(dec_direct))
        # and the bf16 scale narrowing costs < 1% relative error
        dec_full = comp.decompress(wire, meta, x.shape, jnp.float32)
        denom = float(jnp.max(jnp.abs(dec_full))) + 1e-9
        assert float(jnp.max(jnp.abs(dec - dec_full))) / denom < 1e-2


# ---------------------------------------------------------------------------
# hierarchy: fabric over both tiers
# ---------------------------------------------------------------------------
def test_fabric_over_hier_tiers(rng):
    pods, wk = 2, 3
    t = {"a": jax.random.normal(rng, (pods, wk, 12)),
         "b": jax.random.normal(jax.random.fold_in(rng, 1), (pods, wk, 50))}
    hc = LocalHierComm(pods, wk)
    fin, fout = Fabric(hc.inner, 4 * 40), Fabric(hc.outer, 4 * 40)
    # inner: complete within each pod (mean over axis 1)
    got = fin.all_mean(t)
    for k in t:
        np.testing.assert_allclose(
            np.asarray(got[k]),
            np.asarray(jnp.broadcast_to(jnp.mean(t[k], 1, keepdims=True),
                                        t[k].shape)), atol=1e-6)
    # outer: partial ring across pods (roll over axis 0)
    got = fout.ppermute(t, shift=1)
    for k in t:
        np.testing.assert_allclose(np.asarray(got[k]),
                                   np.asarray(jnp.roll(t[k], 1, 0)),
                                   atol=1e-6)


def test_hier_compression_blocks_do_not_mix_replicas(rng):
    """lead_axes=2: a compression block must see ONE (pod, worker) slice.
    With per-replica constant inputs, block scales are exact per replica —
    decode is lossless; any cross-replica mixing would break this."""
    pods, wk = 2, 2
    base = jnp.arange(1.0, 1.0 + pods * wk).reshape(pods, wk, 1)
    t = {"w": jnp.broadcast_to(base, (pods, wk, 64)).copy()}
    hc = LocalHierComm(pods, wk)
    fab = Fabric(hc.inner, bucket_bytes=1 << 20)
    res = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    g_hat, _, _ = fab.compress(t, res, get_compressor("onebit", block=16))
    np.testing.assert_allclose(np.asarray(g_hat["w"]), np.asarray(t["w"]),
                               rtol=1e-2)  # bf16 wire scale only


# ---------------------------------------------------------------------------
# lowering proof of fusion (subprocess: needs >1 device)
# ---------------------------------------------------------------------------
def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_exchange_lowering_is_fused_and_bytes_match():
    """Acceptance check: for a multi-layer tree the compiled exchange HLO
    contains at most n_buckets cross-worker collectives (one per leaf
    before the fabric), and the HLO's gathered bytes equal the fabric's
    reported packed wire size within 1%."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.analysis import collective_budget
        from repro.core.comm import ShardComm
        from repro.core.compression import get_compressor
        from repro.core.fabric import BucketLayout, Fabric, wire_nbytes
        from repro.core.jax_compat import make_mesh, set_mesh, shard_map
        from repro.launch.exchange import build_exchange
        from repro.roofline.analysis import collective_count, parse_collectives

        PODS, LAYERS = 4, 6
        mesh = make_mesh((PODS,), ("pod",))
        g = {f"l{i}": {"w": jax.ShapeDtypeStruct((PODS, 64, 32), jnp.float32),
                       "b": jax.ShapeDtypeStruct((PODS, 32), jnp.float32)}
             for i in range(LAYERS)}
        n_leaves = 2 * LAYERS
        bucket_bytes = 4 * 8000
        # layout of the per-pod view (leading pod dim becomes 1)
        view = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((1,) + s.shape[1:], jnp.float32), g)
        lay = BucketLayout.build(view, bucket_bytes, lead_axes=0)
        assert 1 < lay.n_buckets < n_leaves, (lay.n_buckets, n_leaves)

        results = {}
        for name in ("none", "onebit", "int8"):
            comp = None if name == "none" else get_compressor(name)
            fn = shard_map(build_exchange(comp, bucket_bytes), mesh=mesh,
                           axis_names={"pod"},
                           in_specs=(P("pod"), P("pod")),
                           out_specs=(P("pod"), P("pod")), check_vma=False)
            with set_mesh(mesh):
                c = jax.jit(fn).lower(g, g).compile()
            pc = parse_collectives(c.as_text())
            ncoll = collective_count(c.as_text())
            # rule API: compressed wire = one packed all-gather per
            # bucket; uncompressed = one all-reduce per bucket
            profile = "dense" if comp is None else "compressed"
            contract = Fabric(ShardComm("pod", PODS),
                              bucket_bytes).collective_contract(lay, profile)
            res = collective_budget(c.as_text(), contract)
            assert res.status == "pass", (name, res.findings)
            results[name] = {"ncoll": ncoll,
                             "bytes": sum(pc["bytes"].values())}
            if comp is not None:
                # all-gather output = (PODS, nbytes) u8 per bucket
                expect = PODS * sum(wire_nbytes(comp, n)
                                    for n in lay.bucket_sizes)
                got = pc["bytes"]["all-gather"]
                assert abs(got - expect) / expect < 0.01, (name, got, expect)
        assert results["onebit"]["bytes"] * 5 < results["none"]["bytes"]
        print("FUSED_OK", json.dumps(results))
    """)
    assert "FUSED_OK" in out


def test_pod_compressed_train_step_lowers_via_fabric():
    """The in-step exchange site (train/loop.py) — the old per-leaf
    pod_compressed_grads is gone — lowers through the fabric: the
    all-gather count is bounded by the bucket count, not the leaf count."""
    out = _run("""
        import re
        import jax
        from repro.core.compression import get_compressor
        from repro.core.fabric import BucketLayout
        from repro.core.jax_compat import make_mesh, set_mesh
        from repro.launch.specs import build_step, model_sds, resolve_config, truncate

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = truncate(resolve_config("gemma3-1b", "train_4k"), 1)
        comp = get_compressor("onebit")
        step, sds, sh, don = build_step(cfg, "train_4k", mesh,
                                        pod_compressor=comp)
        with set_mesh(mesh):
            c = jax.jit(step, in_shardings=sh,
                        donate_argnums=don).lower(*sds).compile()
        params_sds = model_sds(cfg)
        n_leaves = len(jax.tree.leaves(params_sds))
        lay = BucketLayout.build(params_sds)  # default bucket_bytes
        # the packed wire buffers are the only u8 all-gathers in the step
        ng = len(re.findall(r"= u8\\[[\\d,]*\\]\\S* all-gather", c.as_text()))
        assert 0 < ng <= lay.n_buckets < n_leaves, \
            (ng, lay.n_buckets, n_leaves)
        print(f"POD_STEP_OK gathers={ng} buckets={lay.n_buckets} "
              f"leaves={n_leaves}")
    """, devices=8)
    assert "POD_STEP_OK" in out
