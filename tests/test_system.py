"""End-to-end system tests: real (reduced) transformer + spectrum
strategies + data pipeline + serving — the full FAST-JAX stack."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.data.pipeline import DataConfig, bayes_entropy, worker_batches
from repro.models import transformer as T
from repro.optim import adam
from repro.serve.engine import DecodeEngine, Request, greedy_generate
from repro.train.loop import (init_train_state, make_loss_fn,
                              make_replica_train_step)

W = 2


def _tiny_cfg():
    import dataclasses
    return dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=64)


def _train(strategy, steps=60, seed=0):
    cfg = _tiny_cfg()
    comm = LocalComm(W)
    opt = adam(3e-3)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      batch_per_worker=4, seed=seed)
    params = comm.replicate(T.init_model(jax.random.PRNGKey(seed), cfg))
    state = init_train_state(params, opt, strategy, comm)
    lf = make_loss_fn(cfg, remat=False)

    def loss_fn(p, toks):
        return lf(p, {"tokens": toks, "labels": toks})

    step = make_replica_train_step(loss_fn, opt, strategy, comm)
    losses = []
    for t in range(steps):
        state, m = step(state, worker_batches(dcfg, W, t))
        losses.append(float(m["loss"]))
    return losses, state, dcfg, cfg, comm


@pytest.mark.parametrize("strategy", [
    ST.sync(), ST.ssp(staleness=2), ST.gossip(), ST.local_sgd(sync_every=4),
])
def test_lm_trains_under_every_spectrum_point(strategy):
    losses, *_ = _train(strategy)
    assert losses[-1] < losses[0] - 0.3, (strategy.name, losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_lm_approaches_entropy_floor():
    """The sync-trained LM must beat uniform by a wide margin (the data has
    structure: §data pipeline)."""
    losses, state, dcfg, cfg, comm = _train(ST.sync(), steps=150)
    floor = bayes_entropy(dcfg)
    uniform = np.log(cfg.vocab_size)
    assert losses[-1] < 0.7 * uniform
    assert losses[-1] > floor - 0.1  # can't beat the generating entropy


def test_spectrum_equivalence_on_lm():
    """Paper §3: points 1–3 'not significantly distinguishable' in
    convergence on homogeneous fabric."""
    l_sync, *_ = _train(ST.sync(), steps=80)
    l_ssp, *_ = _train(ST.ssp(staleness=2), steps=80)
    l_dp, *_ = _train(ST.downpour(push_every=2), steps=80)
    final = np.array([l_sync[-1], l_ssp[-1], l_dp[-1]])
    assert final.max() - final.min() < 0.35 * final.mean()


def test_generation_roundtrip():
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = greedy_generate(params, cfg, np.array([1, 2, 3], np.int32),
                           max_new_tokens=5)
    assert len(toks) == 5
    assert all(0 <= t < cfg.vocab_size for t in toks)


def test_greedy_generate_matches_forward_argmax():
    """Generation must be consistent with teacher-forced forward argmax."""
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(1), cfg)
    prompt = np.array([5, 9, 2, 7], np.int32)
    gen = greedy_generate(params, cfg, prompt, max_new_tokens=4)
    seq = list(prompt)
    for _ in range(4):
        logits, _ = T.forward(params, cfg,
                              tokens=jnp.asarray(seq)[None])
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert gen == seq[len(prompt):]


def test_decode_engine_batched():
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(params, cfg, batch_slots=2, max_seq=48)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.array([1 + i, 2, 3], np.int32),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.generated) == 4 for r in done)


def test_engine_bounds_overlong_prompt():
    """A prompt longer than max_seq must not write past the cache: the
    tail is kept at submit and any slot terminates when the cache fills."""
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    max_seq = 16
    eng = DecodeEngine(params, cfg, batch_slots=2, max_seq=max_seq)
    long_prompt = (np.arange(40) % cfg.vocab_size).astype(np.int32)
    eng.submit(Request(rid=0, prompt=long_prompt, max_new_tokens=8))
    eng.submit(Request(rid=1, prompt=np.array([1, 2, 3], np.int32),
                       max_new_tokens=4))
    done = eng.run()
    assert len(done) == 2
    assert (eng.pos <= max_seq).all()  # never past the cache
    r0 = next(r for r in done if r.rid == 0)
    assert np.array_equal(r0.prompt, long_prompt[-(max_seq - 1):])
    assert len(r0.generated) >= 1  # produced something, then hit the edge
    r1 = next(r for r in done if r.rid == 1)
    assert len(r1.generated) == 4  # short request unaffected


def test_engine_matches_single_sequence():
    """Batched engine output for one request == reference generation."""
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(2), cfg)
    prompt = np.array([4, 8, 15], np.int32)
    ref = greedy_generate(params, cfg, prompt, max_new_tokens=5)
    eng = DecodeEngine(params, cfg, batch_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    out = eng.run()[0].generated
    assert out == ref


def test_engine_empty_prompt_completes_immediately():
    """A zero-length prompt has nothing to condition on and no first token
    to feed the admit path — it must complete at submit, not IndexError."""
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(params, cfg, batch_slots=2, max_seq=32)
    eng.submit(Request(rid=0, prompt=np.array([], np.int32),
                       max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=np.array([1, 2], np.int32),
                       max_new_tokens=3))
    done = eng.run()
    assert len(done) == 2
    r0 = next(r for r in done if r.rid == 0)
    assert r0.done and r0.generated == [] and not r0.preempted
    r1 = next(r for r in done if r.rid == 1)
    assert r1.done and len(r1.generated) == 3


def test_engine_max_steps_drains_in_flight():
    """run(max_steps=) must hand back in-flight requests (preempted, with
    their partial generations) instead of silently dropping them, and
    leave the engine usable."""
    cfg = _tiny_cfg()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(params, cfg, batch_slots=2, max_seq=64)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=np.array([1 + i, 2, 3], np.int32),
                           max_new_tokens=40))
    done = eng.run(max_steps=6)
    assert len(done) == 2  # nothing dropped
    assert all(r.preempted and not r.done for r in done)
    assert all(p == "idle" for p in eng.phase)
    assert all(s is None for s in eng.slot)
    # drained slots leave the engine serviceable for fresh work
    eng.submit(Request(rid=9, prompt=np.array([5], np.int32),
                       max_new_tokens=2))
    done2 = eng.run()
    r9 = next(r for r in done2 if r.rid == 9)
    assert r9.done and not r9.preempted and len(r9.generated) == 2
