"""ZeRO-2/3 tests (core/strategies.py, train/loop.py, DESIGN.md §12).

Acceptance (ISSUE 9):

  * ``sync_zero2`` / ``sync_zero3`` train BITWISE-equal to ``sync`` for
    sgd and adam at ``accum_steps=1`` on the LocalComm rig; under
    accumulation ZeRO-2's shard accumulator matches to float tolerance
    (sum-of-means vs mean-of-sums re-association only),
  * ZeRO-3's parameter train state is 1/W per worker — the W× shrink
    ``step_state_peak_bytes`` models — and ``gather_params``
    reconstructs the replicated tree exactly,
  * ZeRO-3 checkpoints written sharded at W restore re-sharded at W′,
  * the sharded production path (``build_train_step(zero_stage=2|3)``)
    lowers and compiles on a (pod, data, model) mesh.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import read_meta, restore_checkpoint, save_checkpoint
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.core.fabric import Fabric
from repro.optim import adam, sgd
from repro.roofline import analysis as RA
from repro.train.loop import init_train_state, make_replica_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
W = 4
BB = 4 * 50  # small buckets so every tree spans several


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.fixture(scope="module")
def mlp_problem():
    key = jax.random.PRNGKey(0)
    dims = (12, 16, 8, 1)
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i),
                                         (a, b)) * 0.3
              for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))}
    X = jax.random.normal(jax.random.fold_in(key, 9), (W, 32, dims[0]))
    Y = jnp.sum(X, axis=-1, keepdims=True)

    def loss_fn(p, batch):
        x, y = batch
        h = x
        for i in range(len(dims) - 1):
            h = h @ p[f"w{i}"]
            if i < len(dims) - 2:
                h = jnp.tanh(h)
        return jnp.mean((h - y) ** 2)

    return params, (X, Y), loss_fn


def _train(strat, opt, base, batches, loss_fn, steps=12, accum=1):
    comm = LocalComm(W)
    params = comm.replicate(base)
    state = init_train_state(params, opt, strat, comm)
    step = make_replica_train_step(loss_fn, opt, strat, comm,
                                   accum_steps=accum, bucket_bytes=BB)
    for _ in range(steps):
        state, m = step(state, batches)
    return state, m, comm


def _full_params(state, strat, comm):
    p = state["params"]
    if getattr(strat, "owns_params", False):
        p = strat.gather_params(p, comm)
    return p


# ---------------------------------------------------------------------------
# bitwise equivalence to sync at accum=1
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("stage", ["sync_zero2", "sync_zero3"])
@pytest.mark.parametrize("opt_name", ["sgd", "adam"])
def test_zero23_bitwise_vs_sync(stage, opt_name, mlp_problem):
    base, batches, loss_fn = mlp_problem
    make_opt = {"sgd": lambda: sgd(0.05), "adam": lambda: adam(0.02)}[opt_name]
    finals = {}
    for name in ("sync", stage):
        strat = ST.get_strategy(name, bucket_bytes=BB) if name != "sync" \
            else ST.sync()
        state, m, comm = _train(strat, make_opt(), base, batches, loss_fn)
        finals[name] = _full_params(state, strat, comm)
        assert float(m["replica_divergence"]) == 0.0
    for k in base:
        np.testing.assert_allclose(np.asarray(finals[stage][k]),
                                   np.asarray(finals["sync"][k]), atol=0,
                                   err_msg=k)


def test_zero2_accum_matches_sync(mlp_problem):
    """Under accumulation the ZeRO-2 shard accumulator holds the sum of
    per-microbatch reduce-scattered means — the same floats as sync's
    mean-of-sums up to re-association (~1e-7)."""
    base, (X, Y), loss_fn = mlp_problem
    accum = 4
    Xa = jnp.stack([X * (0.5 + 0.25 * i) for i in range(accum)])
    Ya = jnp.stack([Y] * accum)
    finals = {}
    for name in ("sync", "sync_zero2"):
        strat = ST.get_strategy(name, bucket_bytes=BB) if name != "sync" \
            else ST.sync()
        state, _, comm = _train(strat, adam(0.02), base, (Xa, Ya),
                                loss_fn, steps=8, accum=accum)
        finals[name] = state["params"]
    for k in base:
        np.testing.assert_allclose(np.asarray(finals["sync_zero2"][k]),
                                   np.asarray(finals["sync"][k]),
                                   atol=2e-6, err_msg=k)


# ---------------------------------------------------------------------------
# the W× state shrink
# ---------------------------------------------------------------------------
def test_zero3_param_state_is_one_over_w(mlp_problem):
    base, batches, loss_fn = mlp_problem
    strat = ST.sync_zero3(bucket_bytes=BB)
    state, _, comm = _train(strat, adam(0.02), base, batches, loss_fn,
                            steps=2)
    n_dense = sum(x.size for x in jax.tree.leaves(base))
    # stacked replica rig: leaves are (W, shard) — per-worker share is
    # total/W, equal to the dense count up to bucket padding
    n_total = sum(x.size for x in jax.tree.leaves(state["params"]))
    per_worker = n_total / W
    assert n_dense <= n_total < n_dense + W * BB
    assert per_worker == pytest.approx(n_dense / W, rel=0.25)
    # gather reconstructs the dense tree exactly (shapes and dtypes)
    full = strat.gather_params(state["params"], comm)
    for k in base:
        assert full[k].shape[1:] == base[k].shape


def test_roofline_zero_accounting():
    """step_state_peak_bytes applies the stage factors: 1 shards opt
    state, 2 shards the accumulator, 3 shards the parameters."""
    n = 1_000_000
    p = RA.param_bytes(n)           # 4 MB dense f32
    o = RA.opt_state_bytes(n, 2)    # adam: 8 MB
    peak = {z: RA.step_state_peak_bytes(p, o, n, accum_steps=4, w=W,
                                        zero_stage=z)
            for z in (0, 1, 2, 3)}
    acc = RA.accum_state_bytes(n, 4)
    assert peak[0] == p + o + acc
    assert peak[1] == p + o / W + acc
    assert peak[2] == p + o / W + acc / W
    assert peak[3] == p / W + o / W + acc / W
    # stage-3 param sharding also shows up in param_bytes itself
    assert RA.param_bytes(n, w=W, zero_stage=3) == p / W
    # TP combine wire: zero at degree 1, ring-scaled above
    assert RA.tp_wire_bytes(1e6, 1, 24) == 0.0
    assert RA.tp_wire_bytes(1e6, 2, 24) == 24 * 4 * 1.0 * 1e6
    assert RA.tp_wire_bytes(1e6, 4, 24) == 24 * 4 * 1.5 * 1e6


# ---------------------------------------------------------------------------
# checkpoint: sharded save at W, restore re-sharded at W'
# ---------------------------------------------------------------------------
def test_zero3_ckpt_restores_resharded(tmp_path, mlp_problem):
    """Save the ZeRO-3 PARAM shard buckets at W=4, restore re-sharded at
    W'=2: the reassembled full parameters are bitwise identical."""
    d = str(tmp_path)
    base, batches, loss_fn = mlp_problem
    strat4 = ST.sync_zero3(bucket_bytes=BB)
    state4, _, comm4 = _train(strat4, adam(0.02), base, batches, loss_fn,
                              steps=5)
    fab4 = Fabric(comm4, BB)
    # same layout init_params recorded (built over the replicated tree)
    play4 = fab4.partitioned_layout(comm4.replicate(base))
    shards4 = state4["params"]
    save_checkpoint(d, 0, {"param_shards": shards4},
                    partition=play4.spec())
    assert read_meta(d)["partitions"]["0"]["n_parts"] == W

    comm2 = LocalComm(2)
    fab2 = Fabric(comm2, BB)
    rep2 = comm2.replicate(base)
    play2 = fab2.partitioned_layout(rep2)
    template = jax.tree.map(jnp.zeros_like, fab2.shard_params(rep2, play2))
    restored = restore_checkpoint(d, 0, {"param_shards": template},
                                  repartition=True)["param_shards"]
    full4 = fab4.unpartition(shards4, play4)
    full2 = fab2.unpartition(jax.tree.map(jnp.asarray, restored), play2)
    for k in base:
        np.testing.assert_allclose(np.asarray(full2[k][0]),
                                   np.asarray(full4[k][0]), atol=0)


# ---------------------------------------------------------------------------
# production sharded path lowers for stages 2 and 3
# ---------------------------------------------------------------------------
def test_sharded_step_lowers_zero23():
    out = _run("""
        import dataclasses, jax
        from repro.configs.base import get_config
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import ShapeSpec, build_train_step
        cfg = dataclasses.replace(
            get_config("qwen2-1.5b").reduced(),
            num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
            head_dim=16, d_ff=64, vocab_size=64)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeSpec("train_tiny", 16, 4, "train")
        for zs in (2, 3):
            with mesh:
                fn, sds, sh, donate = build_train_step(
                    cfg, shape, mesh, zero_stage=zs, accum_steps=2)
                jax.jit(fn, in_shardings=sh,
                        donate_argnums=donate).lower(*sds).compile()
            print(f"zero_stage={zs}: compiled OK")
    """)
    assert "zero_stage=2: compiled OK" in out
    assert "zero_stage=3: compiled OK" in out


def test_trainer_cli_zero_stage_flag():
    """--zero-stage wires the strategy and the sharded checkpoint path
    end-to-end (the smallest real training run)."""
    out = _run("""
        from repro.launch.train import main
        main(["--arch", "qwen2-1.5b", "--reduced", "--workers", "4",
              "--steps", "2", "--seq-len", "32", "--batch-per-worker", "2",
              "--zero-stage", "3", "--log-every", "1"])
    """, devices=1)
    assert "loss" in out
