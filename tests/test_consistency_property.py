"""Property tests for Statement 1 (paper §3) via hypothesis.

  * Complete delivery, ANY order/delay ⇒ replicas consistent after drain.
  * Dropped updates (partial communication) ⇒ replicas diverge.
  * Momentum ⇒ consistency breaks (the "without momentum" qualifier).
  * Consistent ≠ equal-to-sequential (the paper's explicit caveat).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.consistency import ConsistencySim

DIM = 5


def _grad(rng):
    return rng.normal(size=(DIM,))


@st.composite
def delivery_schedules(draw, max_workers=4, max_rounds=6):
    n = draw(st.integers(2, max_workers))
    rounds = draw(st.integers(1, max_rounds))
    # delays[t][src][dst] ∈ [0, 10]
    delays = draw(st.lists(
        st.lists(st.lists(st.integers(0, 10), min_size=n, max_size=n),
                 min_size=n, max_size=n),
        min_size=rounds, max_size=rounds))
    return n, rounds, delays


@given(delivery_schedules(), st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_statement1_complete_delivery_implies_consistency(sched, seed):
    """Statement 1: whatever the delays, drain ⇒ consistent replicas."""
    n, rounds, delays = sched
    sim = ConsistencySim(n, DIM, lr=0.1, seed=seed)
    rng = np.random.default_rng(seed)
    seq = 0
    for t in range(rounds):
        for src in range(n):
            d = {dst: delays[t][src][dst] for dst in range(n) if dst != src}
            sim.produce(src, _grad(rng), seq, delays=d)
            seq += 1
        sim.step()
    sim.drain()
    assert sim.consistent(atol=1e-9), sim.max_divergence()


@given(st.integers(2, 5), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_partial_communication_breaks_consistency(n, seed):
    """Dropping updates (paper's point 4) abandons consistency."""
    sim = ConsistencySim(n, DIM, lr=0.1, seed=seed)
    rng = np.random.default_rng(seed)
    for t in range(4):
        for src in range(n):
            # drop every delivery to worker (src+1) % n
            d = {dst: (None if dst == (src + 1) % n else 0)
                 for dst in range(n) if dst != src}
            sim.produce(src, _grad(rng), t * n + src, delays=d)
        sim.step()
    sim.drain()
    assert sim.dropped > 0
    assert not sim.consistent(atol=1e-9)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_momentum_breaks_order_invariance(seed):
    """With momentum the update is a non-commutative function of arrival
    order — the paper's 'without momentum' qualifier is load-bearing."""
    rng = np.random.default_rng(seed)
    grads = [_grad(rng) for _ in range(4)]

    def run(order, beta):
        sim = ConsistencySim(1, DIM, lr=0.1, momentum=beta, seed=seed)
        for i, gi in enumerate(order):
            sim.produce(0, grads[gi], i)
        return sim.weights()[0]

    fwd = run([0, 1, 2, 3], beta=0.9)
    rev = run([3, 2, 1, 0], beta=0.9)
    # plain SGD is order-invariant …
    assert np.allclose(run([0, 1, 2, 3], 0.0), run([3, 2, 1, 0], 0.0))
    # … momentum SGD is not (unless grads degenerate)
    if not np.allclose(grads[0], grads[3]):
        assert not np.allclose(fwd, rev)


def test_consistent_but_not_sequential():
    """Paper: 'having consistent model replicas does not mean the result is
    the same as the sequential implementation'."""
    rng = np.random.default_rng(0)
    grads = [[_grad(rng) for _ in range(3)] for _ in range(2)]

    # distributed: 2 workers, delayed cross-delivery
    sim = ConsistencySim(2, DIM, lr=0.1, seed=1)
    for t in range(3):
        for w in range(2):
            sim.produce(w, grads[w][t], t, delays={1 - w: 5})
        sim.step()
    sim.drain()
    assert sim.consistent()

    # sequential: same 6 gradients, but each computed on the running weights
    # would differ — here even simple interleaving gives identical sums since
    # grads are constants; the *point* is replicas agree with each other.
    total = sum(g for ws in grads for g in ws)
    w_seq = sim.replicas[0].w + 0  # replicas agree
    np.testing.assert_allclose(
        sim.replicas[0].w, sim.replicas[1].w, atol=1e-12)
    # and the drained state equals w0 - lr * Σ g (vector-sum commutativity)
    w0 = ConsistencySim(2, DIM, lr=0.1, seed=1).replicas[0].w
    np.testing.assert_allclose(w_seq, w0 - 0.1 * total, atol=1e-9)
