"""The paper's §3 experiment: train the SAME model under every point of the
communication-completeness spectrum and compare convergence + consistency.

Expected outcome (= the paper's argument):
  * sync / ssp / downpour (complete communication): near-identical loss.
  * gossip (partial): trains, but replicas genuinely diverge.
  * compression: same loss at a fraction of the wire bytes.

    PYTHONPATH=src python examples/spectrum_comparison.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import strategies as ST
from repro.core.comm import LocalComm
from repro.core.compression import get_compressor
from repro.data.pipeline import DataConfig, bayes_entropy, worker_batches
from repro.models import transformer as T
from repro.optim import adam
from repro.train.loop import (init_train_state, make_loss_fn,
                              make_replica_train_step)

W, STEPS = 4, 120
cfg = dataclasses.replace(
    get_config("qwen2-1.5b").reduced(), num_layers=2, d_model=64,
    num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=64)
comm = LocalComm(W)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_per_worker=4)
lf = make_loss_fn(cfg, remat=False)
loss_fn = lambda p, toks: lf(p, {"tokens": toks, "labels": toks})  # noqa: E731

print(f"{'strategy':22s} {'pt':>2s} {'final_loss':>10s} {'divergence':>11s} "
      f"{'wireB/step':>10s}")
for name, strat in [
    ("sync (pt 1)", ST.sync()),
    ("sync + 1-bit", ST.sync(compressor=get_compressor("onebit"))),
    ("ssp s=4 (pt 2)", ST.ssp(staleness=4)),
    ("downpour (pt 3)", ST.downpour(push_every=4)),
    ("gossip (pt 4)", ST.gossip()),
    ("local_sgd H=8", ST.local_sgd(sync_every=8)),
]:
    opt = adam(3e-3)
    params = comm.replicate(T.init_model(jax.random.PRNGKey(0), cfg))
    state = init_train_state(params, opt, strat, comm)
    step = make_replica_train_step(loss_fn, opt, strat, comm)
    losses, wire = [], 0.0
    for t in range(STEPS):
        state, m = step(state, worker_batches(dcfg, W, t))
        losses.append(float(m["loss"]))
        wire += float(m["wire_bytes"])
    print(f"{name:22s} {strat.spectrum_point:2d} "
          f"{np.mean(losses[-10:]):10.4f} "
          f"{float(m['replica_divergence']):11.2e} {wire/STEPS:10.0f}")

print(f"\nuniform baseline: {np.log(cfg.vocab_size):.4f}   "
      f"generating-process floor: {bayes_entropy(dcfg):.4f}")
