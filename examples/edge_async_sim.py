"""The paper's edge scenario (§1, §3): loosely-coupled heterogeneous
workers where communication is costly — hierarchical strategy with
complete synchronization inside each "site" and partial (gossip)
communication across sites, plus 1-bit compression on the slow tier.

    PYTHONPATH=src python examples/edge_async_sim.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import strategies as ST
from repro.core.comm import LocalHierComm
from repro.data.pipeline import DataConfig, sample_batch
from repro.models import transformer as T
from repro.optim import adam
from repro.train.loop import make_loss_fn

PODS, WORKERS, STEPS = 3, 2, 100

cfg = dataclasses.replace(
    get_config("qwen2-1.5b").reduced(), num_layers=2, d_model=64,
    num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=64)
comm = LocalHierComm(PODS, WORKERS)
strat = ST.hierarchical(ST.sync(), ST.gossip(mix_every=4))
opt = adam(3e-3)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_per_worker=4)
lf = make_loss_fn(cfg, remat=False)
loss_fn = lambda p, toks: lf(p, {"tokens": toks, "labels": toks})  # noqa: E731

base = T.init_model(jax.random.PRNGKey(0), cfg)
params = jax.tree.map(
    lambda x: jnp.broadcast_to(x, (PODS, WORKERS) + x.shape).copy(), base)
state = {"params": params, "opt_state": opt.init(params),
         "comm_state": strat.init(params, comm), "step": jnp.int32(0)}
grad_fn = jax.jit(jax.vmap(jax.vmap(jax.value_and_grad(loss_fn))))


@jax.jit
def step(state, batches):
    loss, grads = jax.vmap(jax.vmap(jax.value_and_grad(loss_fn)))(
        state["params"], batches)
    p, o, c, m = strat.update(state["params"], grads, state["opt_state"],
                              state["comm_state"], state["step"], opt, comm)
    return {"params": p, "opt_state": o, "comm_state": c,
            "step": state["step"] + 1}, (jnp.mean(loss), m)


for t in range(STEPS):
    batches = jnp.stack([
        jnp.stack([sample_batch(dcfg, pod * WORKERS + w, t)
                   for w in range(WORKERS)]) for pod in range(PODS)])
    state, (loss, m) = step(state, batches)
    if t % 20 == 0 or t == STEPS - 1:
        w = state["params"]["final_norm"]["scale"]
        intra = float(jnp.max(jnp.abs(w[:, 0] - w[:, 1])))
        cross = float(jnp.max(jnp.abs(w[0] - w[1])))
        print(f"step {t:3d} loss {float(loss):.4f}  "
              f"intra-site divergence {intra:.1e}  cross-site {cross:.1e}")

print("\nintra-site replicas consistent (complete sync tier); "
      "cross-site divergence bounded by gossip mixing — the paper's edge "
      "deployment story.")
