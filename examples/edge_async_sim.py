"""The paper's edge scenario (§1, §3): loosely-coupled heterogeneous
workers where communication is costly — in two acts.

Act 1: hierarchical strategy with complete synchronization inside each
"site" and partial (gossip) communication across sites.

Act 2 (DESIGN.md §13): the same edge fleet under CHAOS — a seeded
fault schedule (slowdown → straggler demotion → kill → graceful
degradation → rejoin) driven through the elastic controller, printing
the per-boundary event log.  Edge workers don't just communicate
loosely; they disappear.

    PYTHONPATH=src python examples/edge_async_sim.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import strategies as ST
from repro.core.comm import LocalHierComm
from repro.data.pipeline import DataConfig, sample_batch
from repro.models import transformer as T
from repro.optim import adam
from repro.train.loop import make_loss_fn

PODS, WORKERS, STEPS = 3, 2, 100

cfg = dataclasses.replace(
    get_config("qwen2-1.5b").reduced(), num_layers=2, d_model=64,
    num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=64)
comm = LocalHierComm(PODS, WORKERS)
strat = ST.hierarchical(ST.sync(), ST.gossip(mix_every=4))
opt = adam(3e-3)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_per_worker=4)
lf = make_loss_fn(cfg, remat=False)
loss_fn = lambda p, toks: lf(p, {"tokens": toks, "labels": toks})  # noqa: E731

base = T.init_model(jax.random.PRNGKey(0), cfg)
params = jax.tree.map(
    lambda x: jnp.broadcast_to(x, (PODS, WORKERS) + x.shape).copy(), base)
state = {"params": params, "opt_state": opt.init(params),
         "comm_state": strat.init(params, comm), "step": jnp.int32(0)}
grad_fn = jax.jit(jax.vmap(jax.vmap(jax.value_and_grad(loss_fn))))


@jax.jit
def step(state, batches):
    loss, grads = jax.vmap(jax.vmap(jax.value_and_grad(loss_fn)))(
        state["params"], batches)
    p, o, c, m = strat.update(state["params"], grads, state["opt_state"],
                              state["comm_state"], state["step"], opt, comm)
    return {"params": p, "opt_state": o, "comm_state": c,
            "step": state["step"] + 1}, (jnp.mean(loss), m)


for t in range(STEPS):
    batches = jnp.stack([
        jnp.stack([sample_batch(dcfg, pod * WORKERS + w, t)
                   for w in range(WORKERS)]) for pod in range(PODS)])
    state, (loss, m) = step(state, batches)
    if t % 20 == 0 or t == STEPS - 1:
        w = state["params"]["final_norm"]["scale"]
        intra = float(jnp.max(jnp.abs(w[:, 0] - w[:, 1])))
        cross = float(jnp.max(jnp.abs(w[0] - w[1])))
        print(f"step {t:3d} loss {float(loss):.4f}  "
              f"intra-site divergence {intra:.1e}  cross-site {cross:.1e}")

print("\nintra-site replicas consistent (complete sync tier); "
      "cross-site divergence bounded by gossip mixing — the paper's edge "
      "deployment story.")

# ---------------------------------------------------------------------------
# Act 2: the chaos rig — the fleet survives the schedule, not just the math
# ---------------------------------------------------------------------------
from repro.core.chaos import ChaosEvent, ChaosSchedule, FleetClock  # noqa: E402
from repro.core.staleness import StragglerPolicy  # noqa: E402
from repro.launch.elastic import ElasticFleet  # noqa: E402

print("\n--- chaos rig: elastic fleet under a seeded fault schedule ---")
CHAOS_STEPS, W = 24, 4
schedule = ChaosSchedule((
    ChaosEvent(3, "slowdown", 1, 5.0),   # worker 1 turns straggler
    ChaosEvent(7, "flake", 0),           # one transient exchange failure
    ChaosEvent(10, "kill", 3),           # worker 3 dies mid-boundary
    ChaosEvent(14, "restore", 1),        # worker 1 recovers speed
    ChaosEvent(18, "rejoin", 3),         # worker 3 comes back
))


def chaos_batch_fn(view, t):
    # batches keyed by STABLE worker id: a resize regenerates the rows
    # for exactly the members present this boundary
    toks = jnp.stack([sample_batch(dcfg, w, t) for w in view.members])
    return toks


fleet = ElasticFleet(base, loss_fn, adam(3e-3), workers=W,
                     straggler_policy=StragglerPolicy(patience=2,
                                                      recovery=2),
                     resync_every=4, chaos=schedule,
                     clock=FleetClock(W, jitter=0.0, seed=0),
                     retries=2, backoff_s=1e-4)
for _ in range(CHAOS_STEPS):
    lg = fleet.run_boundary(chaos_batch_fn)
    note = "; ".join(
        [f"{e['kind']}(w{e['worker']})" for e in lg["events"]]
        + ([f"demoted {lg['demoted']}"] if "demoted" in lg else [])
        + ([f"promoted {lg['promoted']}"] if "promoted" in lg else [])
        + ([f"DROPPED {lg['dropped']} after {lg['attempts']} attempts"]
           if "dropped" in lg else [])
        + ([f"retried x{lg['attempts']}"]
           if lg["attempts"] and "dropped" not in lg else []))
    print(f"boundary {lg['t']:2d} epoch {lg['epoch_after']} "
          f"W={lg['size_after']} loss {lg['loss']:.4f}"
          + (f"  [{note}]" if note else ""))

print(f"\nfleet finished all {CHAOS_STEPS} boundaries: membership epoch "
      f"{fleet.view.epoch}, final W={fleet.view.size}, demoted="
      f"{list(fleet.view.demoted)} — every fault in the schedule was "
      "absorbed at an optimizer boundary (DESIGN.md §13).")
