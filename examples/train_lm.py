"""End-to-end driver: train a ~110M-parameter decoder LM for a few hundred
steps with the full stack — data pipeline, spectrum strategy, optimizer,
checkpointing.

Default scale is CPU-feasible smoke (--scale tiny); the deliverable run is

    PYTHONPATH=src python examples/train_lm.py --scale 110m --steps 300 \
        --strategy sync --workers 2 --out train_lm_110m.json

(~110M params; a few hours of single-core CPU — the loss curve is recorded
in EXPERIMENTS.md §End-to-end.)
"""

import argparse
import json
import time

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import ModelConfig
from repro.core.comm import LocalComm
from repro.core.compression import get_compressor
from repro.core.strategies import get_strategy
from repro.data.pipeline import DataConfig, bayes_entropy, worker_batches
from repro.models import transformer as T
from repro.optim import adam, warmup_cosine
from repro.train.loop import (init_train_state, make_loss_fn,
                              make_replica_train_step)

SCALES = {
    # ~110M: 12L d768 ff2048 (GQA 12/4) vocab 32k — a GPT-2-small-class model
    "110m": ModelConfig(name="lm-110m", num_layers=12, d_model=768,
                        num_heads=12, num_kv_heads=4, d_ff=2048,
                        vocab_size=32_768, tie_embeddings=True),
    "10m": ModelConfig(name="lm-10m", num_layers=4, d_model=256,
                       num_heads=4, num_kv_heads=2, d_ff=1024,
                       vocab_size=8_192, tie_embeddings=True),
    "tiny": ModelConfig(name="lm-tiny", num_layers=2, d_model=64,
                        num_heads=2, num_kv_heads=1, d_ff=128,
                        vocab_size=256, tie_embeddings=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--strategy", default="sync")
    ap.add_argument("--compressor", default="none")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch-per-worker", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = SCALES[args.scale]
    comm = LocalComm(args.workers)
    comp = None if args.compressor == "none" else get_compressor(args.compressor)
    kw = {"compressor": comp} if args.strategy in ("sync", "ssp", "downpour") else {}
    strategy = get_strategy(args.strategy, **kw)
    opt = adam(warmup_cosine(args.lr, warmup=max(1, args.steps // 20),
                             total_steps=args.steps))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      batch_per_worker=args.batch_per_worker,
                      active_vocab=min(256, cfg.vocab_size))

    params = comm.replicate(T.init_model(jax.random.PRNGKey(0), cfg))
    n = sum(x.size for x in jax.tree.leaves(params)) // args.workers
    print(f"model {cfg.name}: {n:,} params | strategy {strategy.name} | "
          f"W={args.workers} | entropy floor {bayes_entropy(dcfg):.3f} | "
          f"uniform {np.log(cfg.vocab_size):.3f}")

    state = init_train_state(params, opt, strategy, comm)
    lf = make_loss_fn(cfg, remat=False)
    step = make_replica_train_step(
        lambda p, toks: lf(p, {"tokens": toks, "labels": toks}),
        opt, strategy, comm)

    hist = []
    t0 = time.time()
    for t in range(args.steps):
        state, m = step(state, worker_batches(dcfg, args.workers, t))
        if t % 10 == 0 or t == args.steps - 1:
            rec = {"step": t, "loss": float(m["loss"]),
                   "div": float(m["replica_divergence"]),
                   "elapsed_s": round(time.time() - t0, 1)}
            hist.append(rec)
            tok_s = (t + 1) * args.workers * args.batch_per_worker * args.seq_len \
                / (time.time() - t0)
            print(f"step {t:4d}  loss {rec['loss']:.4f}  "
                  f"div {rec['div']:.1e}  {tok_s:,.0f} tok/s")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps,
                        {"params": comm.replica(state["params"], 0)})
    if args.out:
        json.dump(hist, open(args.out, "w"), indent=1)
    return hist


if __name__ == "__main__":
    main()
