"""Batched serving demo: wave-scheduled decode engine over a reduced
gemma3 (sliding-window) model.

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine, Request, greedy_generate

cfg = get_config("gemma3-1b").reduced()
params = T.init_model(jax.random.PRNGKey(0), cfg)

engine = DecodeEngine(params, cfg, batch_slots=4, max_seq=64)
rng = np.random.default_rng(0)
for i in range(10):
    lp = int(rng.integers(2, 6))
    engine.submit(Request(
        rid=i, prompt=rng.integers(0, cfg.vocab_size, lp).astype(np.int32),
        max_new_tokens=int(rng.integers(4, 9))))

t0 = time.perf_counter()
done = engine.run()
dt = time.perf_counter() - t0
tokens = sum(len(r.generated) for r in done)
print(f"served {len(done)} requests, {tokens} tokens, "
      f"{engine.steps} decode steps in {dt:.1f}s "
      f"({tokens/dt:.1f} tok/s on CPU interpret)")
for r in done[:3]:
    print(f"  req {r.rid}: prompt {list(r.prompt)} -> {r.generated}")

# sanity: single-request path agrees with the reference generator
ref = greedy_generate(params, cfg, done[0].prompt,
                      max_new_tokens=len(done[0].generated))
print("engine matches reference:", ref == done[0].generated)
