"""Serving demo: paged KV cache + chunked prefill (DESIGN.md §10).

Runs the same request batch through the dense seed engine (one token
per slot per step, a (B, max_seq) KV arena) and the paged engine
(fixed-size token pages behind block tables, whole prompt chunks per
step), checks token parity, and reports the step-count/throughput win
plus the page-pool memory for the chosen ``--cache-dtype``:

    PYTHONPATH=src python examples/serve_decode.py --cache-dtype bfloat16
    PYTHONPATH=src python examples/serve_decode.py --cache-dtype int8

int8 pages quantize K/V per token per head on write (f32 scale pools
ride next to the pages) and dequantize on the gather path; bf16/f32
pages are attended in their stored dtype, which is what makes the
paged engine token-identical to the dense one under greedy decoding.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine, PagedDecodeEngine, Request

ap = argparse.ArgumentParser()
ap.add_argument("--cache-dtype", default="bfloat16",
                choices=["float32", "bfloat16", "int8"],
                help="page-pool dtype (int8 adds per-token scale pools "
                     "and forces the gather/dequant path)")
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--max-seq", type=int, default=64)
ap.add_argument("--page-size", type=int, default=8)
ap.add_argument("--chunk-size", type=int, default=16)
args = ap.parse_args()

cfg = get_config("gemma3-1b").reduced()  # sliding-window + global mix
params = T.init_model(jax.random.PRNGKey(0), cfg)


def requests():
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(4, 40)))
                    .astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 9)))
            for i in range(10)]


def serve(engine):
    # compile both phases outside the timed region, then reset counters
    engine.submit(Request(rid=-1, prompt=np.full(20, 1, np.int32),
                          max_new_tokens=2))
    engine.run()
    engine.finished.clear()
    engine.steps = 0
    for r in requests():
        engine.submit(r)
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    return done, toks, engine.steps, dt


dense = DecodeEngine(params, cfg, batch_slots=args.slots,
                     max_seq=args.max_seq)
paged = PagedDecodeEngine(params, cfg, batch_slots=args.slots,
                          max_seq=args.max_seq, page_size=args.page_size,
                          chunk_size=args.chunk_size,
                          cache_dtype=args.cache_dtype)

pool_bytes = sum(x.size * x.dtype.itemsize
                 for x in jax.tree.leaves(paged.cache))
dense_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(dense.cache))
npages = paged.kv.allocator.num_pages
print(f"paged pool: {npages} pages x {args.page_size} tokens, "
      f"dtype={paged.cache_dtype}, {pool_bytes:,} bytes "
      f"(dense {dense.cache_dtype} arena: {dense_bytes:,} bytes)")
print(f"decode attention path: "
      f"{'Pallas kernel' if paged.use_kernel else 'jnp gather'} "
      f"(backend={jax.default_backend()})")

d_done, d_toks, d_steps, d_dt = serve(dense)
p_done, p_toks, p_steps, p_dt = serve(paged)

print(f"dense: {d_toks} tokens in {d_steps} steps, {d_dt:.2f}s "
      f"({d_toks/d_dt:.1f} tok/s)")
print(f"paged: {p_toks} tokens in {p_steps} steps, {p_dt:.2f}s "
      f"({p_toks/p_dt:.1f} tok/s)  [chunked prefill: "
      f"{d_steps/p_steps:.1f}x fewer steps]")
print(f"page pool drained clean: "
      f"{paged.kv.allocator.num_allocated == 0}")

gens_d = {r.rid: r.generated for r in d_done}
gens_p = {r.rid: r.generated for r in p_done}
if args.cache_dtype != "int8":
    # stored-dtype attention ⇒ exact greedy token parity with the dense
    # engine (its cache is cfg.compute_dtype; match to compare exactly)
    exact = (paged.cache_dtype == dense.cache_dtype)
    same = gens_d == gens_p
    print(f"paged == dense token-for-token: {same}"
          + ("" if exact else f"  (paged pages are {args.cache_dtype}; "
             "rounding may flip ties vs the dense "
             f"{dense.cache_dtype} arena)"))
else:
    agree = np.mean([a == b for rid in gens_d
                     for a, b in zip(gens_d[rid], gens_p[rid])])
    print(f"int8 pages vs dense {dense.cache_dtype}: "
          f"{agree:.0%} token agreement (lossy quantization)")
for r in p_done[:3]:
    print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.generated}")
