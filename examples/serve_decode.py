"""Batched serving demo: wave-scheduled decode engine over a reduced
gemma3 (sliding-window) model, serving with a bf16 KV cache end-to-end
(``--cache-dtype float32`` to compare).

    PYTHONPATH=src python examples/serve_decode.py [--cache-dtype bfloat16]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine, Request, greedy_generate

ap = argparse.ArgumentParser()
ap.add_argument("--cache-dtype", default="bfloat16",
                choices=["float32", "bfloat16", "float16"],
                help="decode-cache dtype (plumbed into DecodeEngine)")
args = ap.parse_args()

cfg = get_config("gemma3-1b").reduced()
params = T.init_model(jax.random.PRNGKey(0), cfg)

engine = DecodeEngine(params, cfg, batch_slots=4, max_seq=64,
                      cache_dtype=args.cache_dtype)
cache_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(engine.cache))
print(f"decode cache: dtype={engine.cache_dtype} "
      f"bytes={cache_bytes:,}")
rng = np.random.default_rng(0)
for i in range(10):
    lp = int(rng.integers(2, 6))
    engine.submit(Request(
        rid=i, prompt=rng.integers(0, cfg.vocab_size, lp).astype(np.int32),
        max_new_tokens=int(rng.integers(4, 9))))

t0 = time.perf_counter()
done = engine.run()
dt = time.perf_counter() - t0
tokens = sum(len(r.generated) for r in done)
print(f"served {len(done)} requests, {tokens} tokens, "
      f"{engine.steps} decode steps in {dt:.1f}s "
      f"({tokens/dt:.1f} tok/s on CPU interpret)")
for r in done[:3]:
    print(f"  req {r.rid}: prompt {list(r.prompt)} -> {r.generated}")

# sanity: single-request path agrees with the reference generator (the
# reference prefill caches in compute dtype, so exact agreement is only
# guaranteed when the engine cache matches it)
ref = greedy_generate(params, cfg, done[0].prompt,
                      max_new_tokens=len(done[0].generated))
agree = sum(a == b for a, b in zip(ref, done[0].generated)) / max(len(ref), 1)
if args.cache_dtype == cfg.compute_dtype:
    print("engine matches reference:", ref == done[0].generated)
else:
    print(f"engine vs f32-cache reference agreement: {agree:.0%} "
          f"(cache rounded to {args.cache_dtype})")
