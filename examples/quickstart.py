"""Quickstart: train a tiny LM with the FAST-JAX public API, then generate.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.comm import LocalComm
from repro.core.strategies import sync
from repro.data.pipeline import DataConfig, worker_batches
from repro.models import transformer as T
from repro.optim import adam
from repro.serve.engine import greedy_generate
from repro.train.loop import (init_train_state, make_loss_fn,
                              make_replica_train_step)

W, STEPS = 2, 80

cfg = dataclasses.replace(
    get_config("qwen2-1.5b").reduced(),
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=64)
comm = LocalComm(W)
strategy = sync()
opt = adam(3e-3)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_per_worker=4)

params = comm.replicate(T.init_model(jax.random.PRNGKey(0), cfg))
state = init_train_state(params, opt, strategy, comm)
lf = make_loss_fn(cfg, remat=False)
step = make_replica_train_step(
    lambda p, toks: lf(p, {"tokens": toks, "labels": toks}),
    opt, strategy, comm)

for t in range(STEPS):
    state, m = step(state, worker_batches(dcfg, W, t))
    if t % 20 == 0 or t == STEPS - 1:
        print(f"step {t:3d}  loss {float(m['loss']):.4f}  "
              f"replica divergence {float(m['replica_divergence']):.1e}")

tokens = greedy_generate(comm.replica(state["params"], 0), cfg,
                         np.array([1, 2, 3], np.int32), max_new_tokens=8)
print("generated:", tokens)
print("OK")
